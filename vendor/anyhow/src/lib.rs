//! Vendored minimal `anyhow` — just the surface this workspace uses.
//!
//! The real crates.io `anyhow` is unavailable in the offline build
//! environment, so this drop-in provides the same names with compatible
//! semantics: [`Error`], [`Result`], the [`Context`] extension trait for
//! `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Error values keep a context *chain* (outermost first). `{}` displays the
//! outermost message; `{:#}` displays the whole chain joined by `": "`,
//! matching anyhow's alternate formatting that `main.rs` relies on.

use std::fmt;

/// A dynamic error with a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { chain: vec![m.to_string()] }
    }

    /// Push an outer context message onto the chain.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context/cause messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

// NOTE: like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes the blanket `From` below coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context()` / `.with_context()` to `Result` and
/// `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    Error: From<E>,
{
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Bail with the given message unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_chain_and_alternate_display() {
        let r: Result<()> = Err(io_err()).context("open config");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "open config");
        assert_eq!(format!("{e:#}"), "open config: missing");
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        assert!(v.context("nothing").is_err());
        let f = || -> Result<()> {
            ensure!(1 + 1 == 2, "math is broken");
            bail!("boom {}", 42);
        };
        assert_eq!(format!("{}", f().unwrap_err()), "boom 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let f = || -> Result<usize> { Ok("12".parse::<usize>()?) };
        assert_eq!(f().unwrap(), 12);
        let g = || -> Result<usize> { Ok("x".parse::<usize>()?) };
        assert!(g().is_err());
    }
}
