//! `MpkEngine` session behavior at the application level:
//!
//! * a `ChebyshevPropagator` on the **threads** executor must match the
//!   **sim** executor bitwise while reusing one persistent rank pool
//!   across ≥ 3 `step()` calls (no per-sweep thread spawning);
//! * tail-block plans are built once and cached (the old code rebuilt a
//!   temporary plan twice per time step — once per complex plane);
//! * a custom `BackendSpec` reaches every SpMV of the poly-CG solver,
//!   preconditioner sweeps and the CG loop's own `A·p` alike.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use dlb_mpk::apps::chebyshev::{wave_packet, ChebyshevConfig, ChebyshevPropagator};
use dlb_mpk::apps::poly_cg::{pcg, ChebyshevPreconditioner};
use dlb_mpk::distsim::DistMatrix;
use dlb_mpk::engine::{BackendSpec, EngineConfig, MpkEngine, Variant};
use dlb_mpk::exec::ExecutorKind;
use dlb_mpk::matrix::anderson::{anderson, AndersonConfig};
use dlb_mpk::matrix::{gen, CsrMatrix};
use dlb_mpk::mpk::dlb::{DlbOptions, Recurrence};
use dlb_mpk::mpk::SpmvBackend;
use dlb_mpk::partition::{partition, Method};

fn assert_state_bitwise(a: &dlb_mpk::apps::chebyshev::State, b: &dlb_mpk::apps::chebyshev::State) {
    for (i, (u, v)) in a.re.iter().zip(&b.re).enumerate() {
        assert!(u.to_bits() == v.to_bits(), "re[{i}]: {u:?} != {v:?} (bitwise)");
    }
    for (i, (u, v)) in a.im.iter().zip(&b.im).enumerate() {
        assert!(u.to_bits() == v.to_bits(), "im[{i}]: {u:?} != {v:?} (bitwise)");
    }
}

/// Acceptance check: propagator on threads executor == sim executor,
/// bitwise, over ≥ 3 steps, with one rank pool serving every sweep.
#[test]
fn propagator_threads_pool_matches_sim_bitwise_over_three_steps() {
    let acfg = AndersonConfig::isotropic(8, 1.5, 21);
    let h = anderson(&acfg);
    let np = 4;
    let part = partition(&h, np, Method::Block);
    let dist = DistMatrix::build(&h, &part);
    let mk = |executor: ExecutorKind| ChebyshevConfig {
        dt: 0.4,
        p_m: 4,
        engine: EngineConfig {
            variant: Variant::Dlb(DlbOptions { cache_bytes: 64 << 10, s_m: 50, async_remainder: false }),
            executor,
            backend: BackendSpec::Native,
            trace: false,
            inner_threads: 1,
            ..EngineConfig::default()
        },
    };
    let mut sim = ChebyshevPropagator::new(&h, &dist, mk(ExecutorKind::Sim)).unwrap();
    let mut thr =
        ChebyshevPropagator::new(&h, &dist, mk(ExecutorKind::Threads { n: 0 })).unwrap();
    assert!(sim.engine().pool_stats().is_none());

    let psi0 = wave_packet(&acfg, 2.0, [std::f64::consts::FRAC_PI_2, 0.0, 0.0]);
    let steps = 3;
    let mut psi_sim = psi0.clone();
    let mut psi_thr = psi0.clone();
    for s in 0..steps {
        psi_sim = sim.step(&psi_sim);
        psi_thr = thr.step(&psi_thr);
        assert_state_bitwise(&psi_sim, &psi_thr);
        // the pool never re-spawns: thread count constant, sweep count grows
        let pool = thr.engine().pool_stats().expect("threads executor keeps a pool");
        assert_eq!(pool.threads, np, "step {s}: pool must keep one thread per rank");
        assert_eq!(
            pool.sweeps,
            thr.engine().sweeps_run(),
            "step {s}: every sweep goes through the same pool"
        );
    }
    let pool = thr.engine().pool_stats().unwrap();
    assert!(pool.sweeps >= steps, "≥ 1 sweep per step expected, got {}", pool.sweeps);
    // identical comm accounting on both executors
    assert_eq!(sim.comm, thr.comm);
    // tail plans cached: at most primary + one tail length, regardless of steps
    assert!(
        thr.engine().plans_built() <= 2,
        "plans must be cached across steps, built {}",
        thr.engine().plans_built()
    );
    assert_eq!(sim.engine().plans_built(), thr.engine().plans_built());
}

/// Regression for the old per-step tail-plan rebuild: step() used to build
/// a temporary DLB plan **twice per time step** (once per complex plane)
/// whenever `n_terms % p_m != 0`. With the engine cache the count must be
/// exactly primary(1) + tail(1) after any number of steps.
#[test]
fn tail_plan_construction_count_is_constant_in_steps() {
    let acfg = AndersonConfig::isotropic(6, 1.0, 9);
    let h = anderson(&acfg);
    let part = partition(&h, 2, Method::Block);
    let dist = DistMatrix::build(&h, &part);
    // pick p_m so a tail block exists: n_terms >= p_m + 1 and we force a
    // mismatch by choosing p_m = n_terms_estimate - 1 if needed; simplest
    // robust choice: probe the propagator for its n_terms first.
    let probe = ChebyshevPropagator::new(
        &h,
        &dist,
        ChebyshevConfig { dt: 0.5, p_m: 4, engine: EngineConfig::default() },
    )
    .unwrap();
    let n_terms = probe.n_terms;
    // choose p_m that does NOT divide n_terms (guaranteed: n_terms >= 2,
    // and one of {n_terms - 1, n_terms + 1 adjusted} won't divide it; use
    // p_m = n_terms - 1 >= 1, for which n_terms % p_m == 1 when p_m >= 2)
    let p_m = (n_terms - 1).max(2);
    let ccfg = ChebyshevConfig {
        dt: 0.5,
        p_m,
        engine: EngineConfig {
            variant: Variant::Dlb(DlbOptions { cache_bytes: 32 << 10, s_m: 50, async_remainder: false }),
            ..EngineConfig::default()
        },
    };
    let mut prop = ChebyshevPropagator::new(&h, &dist, ccfg).unwrap();
    // the propagator clamps n_terms to >= p_m + 1, so a tail block exists
    assert!(prop.n_terms % prop.cfg.p_m != 0, "test needs a tail block");
    let psi0 = wave_packet(&acfg, 2.0, [0.5, 0.0, 0.0]);
    let mut psi = psi0.clone();
    let mut counts = Vec::new();
    for _ in 0..4 {
        psi = prop.step(&psi);
        counts.push(prop.engine().plans_built());
    }
    assert_eq!(
        counts,
        vec![2, 2, 2, 2],
        "exactly primary + one tail plan, constant across steps"
    );
}

/// A backend counting its `spmv_range` calls, wrapping the native kernel.
struct CountingBackend {
    calls: Arc<AtomicUsize>,
}

impl SpmvBackend for CountingBackend {
    fn spmv_range(&mut self, a: &CsrMatrix, lo: usize, hi: usize, x: &[f64], y: &mut [f64]) {
        self.calls.fetch_add(1, Ordering::Relaxed);
        a.spmv_range(lo, hi, x, y);
    }

    fn name(&self) -> &'static str {
        "counting"
    }
}

/// The whole poly-CG solver — preconditioner sweeps *and* the CG loop's
/// own `A·p` — must route through the engine's configured backend.
#[test]
fn pcg_routes_all_spmvs_through_engine_backend() {
    let a = gen::stencil_2d_5pt(16, 16);
    let part = partition(&a, 2, Method::Block);
    let dist = DistMatrix::build(&a, &part);
    let n = 16f64;
    let lmin = 8.0 * (std::f64::consts::PI / (2.0 * (n + 1.0))).sin().powi(2);
    let lmax = a.inf_norm();

    let calls = Arc::new(AtomicUsize::new(0));
    let calls_in_factory = calls.clone();
    let cfg = EngineConfig {
        variant: Variant::Dlb(DlbOptions { cache_bytes: 1 << 20, s_m: 50, async_remainder: false }),
        executor: ExecutorKind::Sim,
        backend: BackendSpec::Custom(Arc::new(move || {
            Box::new(CountingBackend { calls: calls_in_factory.clone() })
        })),
        trace: false,
        inner_threads: 1,
        ..EngineConfig::default()
    };
    let mut pre = ChebyshevPreconditioner::new(&dist, lmin, lmax, 4, &cfg).unwrap();
    let b = vec![1.0; a.n_rows()];
    let (x, iters, rn) = pcg(&a, &b, &mut pre, 1e-9, 200);
    assert!(iters < 200 && rn < 1e-6, "pcg converges ({iters} iters, resid {rn})");
    let mut ax = vec![0.0; b.len()];
    a.spmv(&x, &mut ax);
    for (u, v) in ax.iter().zip(&b) {
        assert!((u - v).abs() < 1e-6, "{u} vs {v}");
    }
    // every sweep row-range product AND every CG A·p went through the
    // counting backend: at least one call per CG iteration plus the
    // preconditioner sweeps.
    let total = calls.load(Ordering::Relaxed);
    assert!(total > iters, "custom backend saw {total} calls over {iters} iterations");
}

/// Same rank pool also serves engine users directly: ≥ 3 sweeps, constant
/// thread count, sweeps counter advancing — on the TRAD variant for
/// contrast with the propagator test above.
#[test]
fn direct_engine_pool_reuse_across_sweeps() {
    let a = gen::stencil_2d_5pt(10, 10);
    let part = partition(&a, 3, Method::Block);
    let dist = DistMatrix::build(&a, &part);
    let mut eng = MpkEngine::builder(&dist)
        .p_m(3)
        .variant(Variant::Trad)
        .executor(ExecutorKind::Threads { n: 0 })
        .build()
        .unwrap();
    let x = vec![1.0; a.n_rows()];
    let first = eng.sweep(&x, None, Recurrence::Power);
    for s in 2..=4 {
        let again = eng.sweep(&x, None, Recurrence::Power);
        assert_eq!(first.powers, again.powers, "sweep {s} must be identical");
        assert_eq!(first.comm, again.comm, "sweep {s} stats must not accumulate");
        assert_eq!(eng.pool_stats().unwrap().threads, 3);
        assert_eq!(eng.pool_stats().unwrap().sweeps, s);
    }
}
