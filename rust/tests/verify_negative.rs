//! Adversarial tests for the static verifier (`dlb_mpk::verify`).
//!
//! Positive direction: every configuration the executor-equivalence suite
//! runs (TRAD/CA/DLB × rank counts × p_m × async remainder × inner splits)
//! verifies clean. Negative direction: hand-mutated plans — merged
//! dependent batches, dropped send/recv plans, a row moved between
//! segment peers, a reused tag — are each rejected with the documented
//! stable rule ID, never a panic.

use dlb_mpk::distsim::DistMatrix;
use dlb_mpk::matrix::{gen, CsrMatrix};
use dlb_mpk::mpk::{ca, dlb};
use dlb_mpk::partition::{partition, Method};
use dlb_mpk::verify::{comm, Verifier};

fn dist(np: usize) -> (CsrMatrix, DistMatrix) {
    let a = gen::stencil_2d_5pt(16, 16);
    let part = partition(&a, np, Method::Block);
    let d = DistMatrix::build(&a, &part);
    (a, d)
}

/// DLB plan over its (permuted) dist — mutations work on owned copies.
fn dlb_setup(np: usize, p_m: usize, async_remainder: bool) -> (DistMatrix, dlb::DlbPlan) {
    let (_, d) = dist(np);
    let opts = dlb::DlbOptions { async_remainder, ..dlb::DlbOptions::default() };
    let plan = dlb::plan(&d, p_m, &opts);
    ((*plan.dist).clone(), plan)
}

#[test]
fn exec_equivalence_configurations_verify_clean() {
    for np in [1usize, 2, 4] {
        for p_m in [1usize, 2, 4] {
            for k in [1usize, 2] {
                let v = Verifier::with_inner_threads(k);
                let (a, d) = dist(np);
                let rep = v.check_trad(&d, p_m);
                assert!(rep.is_ok(), "trad np={np} p_m={p_m} k={k}:\n{rep}");
                assert!(rep.checks > 0, "trad report ran no checks");
                let rep = v.check_ca(&d, &ca::ca_exec_plan(&a, &d, p_m));
                assert!(rep.is_ok(), "ca np={np} p_m={p_m} k={k}:\n{rep}");
                for async_remainder in [false, true] {
                    let (pd, plan) = dlb_setup(np, p_m, async_remainder);
                    let rep = v.check_all(&pd, &plan.ranks, p_m);
                    assert!(
                        rep.is_ok(),
                        "dlb np={np} p_m={p_m} k={k} async={async_remainder}:\n{rep}"
                    );
                    assert!(rep.checks > 0, "dlb report ran no checks");
                }
            }
        }
    }
}

#[test]
fn merged_dependent_batches_are_rejected() {
    let (d, mut plan) = dlb_setup(2, 4, false);
    let pl = plan
        .ranks
        .iter_mut()
        .find(|pl| pl.batches.len() >= 2)
        .expect("a rank with >= 2 batches");
    // Consecutive wavefront fronts are dependent by construction; merging
    // them puts dependent steps in one "parallel" batch.
    let merged = pl.batches.remove(1);
    pl.batches[0].extend(merged);
    let rep = Verifier::new().check_all(&d, &plan.ranks, 4);
    assert!(
        rep.has_rule("SCHED_BATCH_ADJ_LEVELS")
            || rep.has_rule("SCHED_BATCH_ROW_OVERLAP")
            || rep.has_rule("SCHED_BATCH_SAME_GROUP"),
        "expected a batch-independence rule, got:\n{rep}"
    );
}

#[test]
fn swapped_schedule_steps_are_rejected() {
    let (d, mut plan) = dlb_setup(2, 3, false);
    let pl = plan
        .ranks
        .iter_mut()
        .find(|pl| pl.schedule.len() >= 2)
        .expect("a rank with >= 2 steps");
    let last = pl.schedule.len() - 1;
    pl.schedule.swap(0, last);
    let rep = Verifier::new().check_all(&d, &plan.ranks, 3);
    assert!(
        rep.has_rule("SCHED_DEP_UNMET") || rep.has_rule("SCHED_POWER_JUMP"),
        "expected an order rule, got:\n{rep}"
    );
}

#[test]
fn dropped_recv_plan_is_rejected() {
    let (mut d, plan) = dlb_setup(3, 2, false);
    let rank = d.ranks.iter().position(|r| !r.recv.is_empty()).unwrap();
    d.ranks[rank].recv.remove(0);
    let rep = Verifier::new().check_all(&d, &plan.ranks, 2);
    assert!(rep.has_rule("COMM_SEND_UNMATCHED"), "{rep}");
    assert!(rep.has_rule("COMM_SLOT_GAP"), "{rep}");
}

#[test]
fn dropped_send_plan_deadlocks() {
    let (_, mut d) = dist(2);
    let rank = d.ranks.iter().position(|r| !r.send.is_empty()).unwrap();
    d.ranks[rank].send.remove(0);
    let rep = Verifier::new().check_trad(&d, 3);
    assert!(rep.has_rule("COMM_RECV_UNMATCHED"), "{rep}");
    assert!(rep.has_rule("COMM_DEADLOCK"), "{rep}");
}

#[test]
fn corrupted_send_length_is_rejected() {
    let (_, mut d) = dist(2);
    let sp = d
        .ranks
        .iter_mut()
        .flat_map(|r| r.send.iter_mut())
        .find(|s| !s.rows.is_empty())
        .unwrap();
    sp.rows.pop();
    let rep = Verifier::new().check_trad(&d, 2);
    assert!(rep.has_rule("COMM_LEN_MISMATCH"), "{rep}");
}

#[test]
fn moved_segment_row_is_rejected() {
    let (d, mut plan) = dlb_setup(3, 3, true);
    let rank = plan
        .ranks
        .iter()
        .position(|pl| pl.seg_rows.len() >= 2 && pl.seg_rows.iter().any(|s| !s.is_empty()))
        .expect("a rank with >= 2 peers and a non-empty segment");
    let pl = &mut plan.ranks[rank];
    let from = pl.seg_rows.iter().position(|s| !s.is_empty()).unwrap();
    let to = (from + 1) % pl.seg_rows.len();
    // The row's halo reads still point at peer `from`, so under peer
    // `to`'s segment it would advance before its inputs arrive.
    let row = pl.seg_rows[from].remove(0);
    pl.seg_rows[to].push(row);
    pl.seg_rows[to].sort_unstable();
    let rep = Verifier::new().check_all(&d, &plan.ranks, 3);
    assert!(rep.has_rule("DLB_SEG_FOREIGN_SLOT"), "{rep}");
}

#[test]
fn cross_sweep_tag_reuse_is_rejected() {
    // The modeled async tag discipline is safe as generated...
    assert!(comm::check_tag_rounds(&comm::dlb_rounds(4, true)).is_empty());
    // ...reusing a live tag is not...
    let mut rounds = comm::dlb_rounds(4, true);
    rounds[2].tag = rounds[1].tag;
    let diags = comm::check_tag_rounds(&rounds);
    assert!(diags.iter().any(|dg| dg.rule.id() == "COMM_TAG_REUSE"));
    // ...and dropping the sweep-final barrier lets this sweep's in-flight
    // messages match the next sweep's identical tags.
    let mut rounds = comm::dlb_rounds(4, true);
    rounds.last_mut().unwrap().barrier_after = false;
    let diags = comm::check_tag_rounds(&rounds);
    assert!(diags.iter().any(|dg| dg.rule.id() == "COMM_NO_FINAL_BARRIER"));
}

#[test]
fn dropped_ca_recv_is_rejected() {
    let (a, d) = dist(3);
    let mut plan = ca::ca_exec_plan(&a, &d, 3);
    let rank = plan.recvs.iter().position(|r| !r.is_empty()).unwrap();
    plan.recvs[rank].remove(0);
    let rep = Verifier::new().check_ca(&d, &plan);
    assert!(rep.has_rule("COMM_SEND_UNMATCHED"), "{rep}");
    assert!(rep.has_rule("CA_EXT_COVERAGE"), "{rep}");
}
