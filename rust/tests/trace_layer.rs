//! Acceptance tests for the span-tracing layer (`crate::trace`):
//!
//! * tracing is **invisible to results** — every variant × executor
//!   produces bitwise-identical powers and identical merged
//!   [`dlb_mpk::distsim::CommStats`] with tracing on and off;
//! * the chrome-trace export is structurally sound (balanced B/E per
//!   rank) and covers ≥ 2 ranks with wavefront, remainder, and
//!   comm-wait spans;
//! * metrics flows reproduce the CommStats totals exactly — received
//!   bytes and messages are accounted on the same (receiver) side.

use dlb_mpk::distsim::DistMatrix;
use dlb_mpk::engine::{MpkEngine, SweepResult, Variant};
use dlb_mpk::exec::ExecutorKind;
use dlb_mpk::matrix::gen;
use dlb_mpk::mpk::dlb::{DlbOptions, Recurrence};
use dlb_mpk::partition::{partition, Method};
use dlb_mpk::trace::validate_chrome_trace;

fn dist(np: usize) -> DistMatrix {
    let a = gen::stencil_2d_5pt(14, 12);
    let part = partition(&a, np, Method::Block);
    DistMatrix::build(&a, &part)
}

fn input(n: usize) -> Vec<f64> {
    (0..n).map(|i| ((i % 17) as f64 - 8.0) / 9.0).collect()
}

fn variants() -> Vec<Variant> {
    vec![
        Variant::Trad,
        Variant::Ca,
        Variant::Dlb(DlbOptions { cache_bytes: 8 << 10, s_m: 50, async_remainder: false }),
    ]
}

fn sweep_once(d: &DistMatrix, v: Variant, ex: ExecutorKind, trace: bool) -> (MpkEngine, SweepResult) {
    let mut eng = MpkEngine::builder(d)
        .p_m(4)
        .variant(v)
        .executor(ex)
        .trace(trace)
        .build()
        .expect("engine builds");
    let x = input(d.n_global);
    let res = eng.sweep(&x, None, Recurrence::Power);
    (eng, res)
}

fn assert_bitwise(a: &SweepResult, b: &SweepResult, what: &str) {
    assert_eq!(a.powers.len(), b.powers.len(), "{what}: power count");
    for (p, (pa, pb)) in a.powers.iter().zip(&b.powers).enumerate() {
        for (i, (u, v)) in pa.iter().zip(pb).enumerate() {
            assert!(
                u.to_bits() == v.to_bits(),
                "{what}: powers[{p}][{i}] differs bitwise: {u:?} vs {v:?}"
            );
        }
    }
    assert_eq!(a.comm, b.comm, "{what}: comm stats");
    assert_eq!(a.flop_nnz, b.flop_nnz, "{what}: flop count");
}

/// Acceptance: enabling tracing changes nothing about the computation —
/// bitwise-identical sweeps on both executors, for every variant.
#[test]
fn tracing_is_bitwise_invisible() {
    let d = dist(3);
    for v in variants() {
        for ex in [ExecutorKind::Sim, ExecutorKind::Threads { n: 0 }] {
            let (mut off, res_off) = sweep_once(&d, v, ex, false);
            let (mut on, res_on) = sweep_once(&d, v, ex, true);
            let what = format!("{} on {ex}", v.label());
            assert_bitwise(&res_off, &res_on, &what);
            assert!(!off.is_tracing() && on.is_tracing());
            assert!(off.metrics().is_none(), "{what}: no metrics without tracing");
            assert!(off.chrome_trace_json().is_none());
            assert!(on.metrics().is_some(), "{what}: metrics with tracing");
        }
    }
}

/// Acceptance: the chrome trace from a threads-executor DLB sweep covers
/// every rank with balanced spans including wavefront levels, remainder
/// rounds, and comm waits.
#[test]
fn chrome_trace_covers_ranks_and_phases() {
    let d = dist(3);
    let (mut eng, _res) = sweep_once(
        &d,
        Variant::Dlb(DlbOptions { cache_bytes: 8 << 10, s_m: 50, async_remainder: false }),
        ExecutorKind::Threads { n: 0 },
        true,
    );
    let json = eng.chrome_trace_json().expect("tracing enabled");
    let check = validate_chrome_trace(&json).expect("export must validate");
    assert!(check.n_ranks() >= 2, "trace covers {} rank(s)", check.n_ranks());
    assert_eq!(check.n_ranks(), d.n_ranks(), "every rank contributes spans");
    for (tid, spans) in &check.spans_per_rank {
        assert!(*spans > 0, "rank {tid} has no closed spans");
    }
    assert!(check.has_name_prefix("dlb.wavefront"), "names: {:?}", check.names);
    assert!(check.has_name_prefix("dlb.remainder"), "names: {:?}", check.names);
    assert!(check.has_name_prefix("comm.wait"), "names: {:?}", check.names);
    assert!(check.has_name_prefix("comm.recv"), "names: {:?}", check.names);
    assert!(check.has_name_prefix("job.dispatch"), "names: {:?}", check.names);
}

/// The sequential executor exports a valid trace too, for every variant
/// (TRAD spmv spans, CA exchange + promote spans, DLB phases).
#[test]
fn sim_executor_traces_validate_per_variant() {
    let d = dist(3);
    for (v, want) in [
        (Variant::Trad, "trad.spmv"),
        (Variant::Ca, "ca.promote"),
        (Variant::Dlb(DlbOptions { cache_bytes: 8 << 10, s_m: 50, async_remainder: false }), "dlb.wavefront"),
    ] {
        let (mut eng, _res) = sweep_once(&d, v, ExecutorKind::Sim, true);
        let json = eng.chrome_trace_json().expect("tracing enabled");
        let check = validate_chrome_trace(&json)
            .unwrap_or_else(|e| panic!("{} trace invalid: {e}", v.label()));
        assert_eq!(check.n_ranks(), d.n_ranks(), "{}: rank coverage", v.label());
        assert!(check.has_name_prefix(want), "{}: names {:?}", v.label(), check.names);
        assert!(check.has_name_prefix("comm.wait"), "{}: names {:?}", v.label(), check.names);
    }
}

/// Acceptance: metrics flows are accounted on the same receiver side as
/// [`dlb_mpk::distsim::CommStats`], so the totals agree exactly — for
/// every variant on both executors.
#[test]
fn metrics_flows_match_comm_stats() {
    let d = dist(3);
    for v in variants() {
        for ex in [ExecutorKind::Sim, ExecutorKind::Threads { n: 0 }] {
            let (mut eng, res) = sweep_once(&d, v, ex, true);
            let m = eng.metrics().expect("tracing enabled");
            let what = format!("{} on {ex}", v.label());
            assert_eq!(m.per_rank.len(), d.n_ranks(), "{what}: rank coverage");
            assert_eq!(m.total_bytes, res.comm.bytes, "{what}: received bytes");
            assert_eq!(m.total_messages, res.comm.messages, "{what}: received messages");
            let per_rank_bytes: usize = m.per_rank.iter().map(|r| r.bytes).sum();
            assert_eq!(per_rank_bytes, res.comm.bytes, "{what}: per-rank bytes sum");
            // one comm.wait span per rank per round
            for r in &m.per_rank {
                assert_eq!(
                    r.wait_by_round.len(),
                    res.comm.rounds,
                    "{what}: rank {} wait spans vs rounds",
                    r.rank
                );
            }
            // the flat summary is parseable JSON
            assert!(dlb_mpk::util::json::Json::parse(&m.to_json()).is_ok(), "{what}");
        }
    }
}

/// Metrics accumulate across sweeps of one engine session: after `k`
/// identical sweeps the totals are `k ×` one sweep's stats.
#[test]
fn metrics_accumulate_across_sweeps() {
    let d = dist(2);
    let x = input(d.n_global);
    let mut eng = MpkEngine::builder(&d)
        .p_m(3)
        .variant(Variant::Trad)
        .executor(ExecutorKind::Threads { n: 0 })
        .trace(true)
        .build()
        .unwrap();
    let one = eng.sweep(&x, None, Recurrence::Power);
    let k = 3;
    for _ in 1..k {
        eng.sweep(&x, None, Recurrence::Power);
    }
    let m = eng.metrics().unwrap();
    assert_eq!(m.total_bytes, k * one.comm.bytes);
    assert_eq!(m.total_messages, k * one.comm.messages);
}
