//! Integration: the three-layer composition — rust-built ELL chunks executed
//! by the AOT Pallas/JAX artifacts via PJRT, validated against the native
//! CRS reference. Requires `make artifacts` (skips otherwise).

use std::path::Path;

use dlb_mpk::matrix::{gen, EllChunk};
use dlb_mpk::runtime::backend::XlaChebStep;
use dlb_mpk::runtime::{Runtime, XlaSpmv};
use dlb_mpk::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    if !cfg!(feature = "xla") {
        // Runtime::load is a stub that always fails without the feature —
        // skip even if artifacts have been built.
        eprintln!("skipping: built without the `xla` feature");
        return None;
    }
    let d = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    d.join("manifest.json").exists().then_some(d)
}

#[test]
fn xla_spmv_matches_native_on_demo_stencil() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(&dir).expect("load runtime");
    assert_eq!(rt.platform(), "cpu");

    // demo artifact: 4096 rows, width 5, xlen 4096 = 64x64 5pt stencil
    let a = gen::stencil_2d_5pt(64, 64);
    let ell = EllChunk::from_csr_rows(&a, 0, a.n_rows(), 256, 5);
    assert_eq!((ell.rows, ell.width), (4096, 5));
    let xla = XlaSpmv::new(&rt, 4096, 5, 4096).unwrap();

    let mut rng = Rng::new(42);
    for _ in 0..3 {
        let x: Vec<f64> = (0..4096).map(|_| rng.normal()).collect();
        let got = xla.spmv(&ell, &x).unwrap();
        let mut want = vec![0.0; 4096];
        a.spmv(&x, &mut want);
        for (u, v) in got.iter().zip(&want) {
            assert!((u - v).abs() < 1e-12, "{u} vs {v}");
        }
    }
}

#[test]
fn xla_cheb_step_matches_native() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(&dir).expect("load runtime");
    // anderson 32^3 artifact
    let cfg = dlb_mpk::matrix::anderson::AndersonConfig::isotropic(32, 1.0, 7);
    let h = dlb_mpk::matrix::anderson::anderson(&cfg);
    let n = h.n_rows();
    let ell = EllChunk::from_csr_rows(&h, 0, n, 256, 7);
    assert_eq!((ell.rows, ell.width), (32768, 7));
    let step = XlaChebStep::new(&rt, n, 7, n).unwrap();

    let mut rng = Rng::new(3);
    let mk = |rng: &mut Rng| (0..n).map(|_| rng.normal()).collect::<Vec<f64>>();
    let (vr, vi, pr, pi) = (mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng));
    let (gr, gi) = step.step(&ell, &vr, &vi, &pr, &pi).unwrap();

    let mut hr = vec![0.0; n];
    let mut hi = vec![0.0; n];
    h.spmv(&vr, &mut hr);
    h.spmv(&vi, &mut hi);
    for r in 0..n {
        assert!((gr[r] - (2.0 * hr[r] - pr[r])).abs() < 1e-11);
        assert!((gi[r] - (2.0 * hi[r] - pi[r])).abs() < 1e-11);
    }
}
