//! Property-based invariant tests (seeded-random sweeps; proptest itself is
//! unavailable offline, so this uses the crate's own RNG with many cases —
//! same coverage philosophy: random structures, checked invariants).

use dlb_mpk::distsim::DistMatrix;
use dlb_mpk::graph::levels::bfs_reorder;
use dlb_mpk::graph::Levels;
use dlb_mpk::matrix::{gen, CooMatrix, CsrMatrix};
use dlb_mpk::mpk::dlb::{self, DlbOptions};
use dlb_mpk::mpk::{ca, trad_mpk, NativeBackend};
use dlb_mpk::partition::{partition, Method, PartitionStats};
use dlb_mpk::race::schedule::{validate_schedule, wavefront};
use dlb_mpk::race::group_levels;
use dlb_mpk::util::rng::Rng;

/// Random connected-ish symmetric matrix with given size bounds.
fn random_matrix(rng: &mut Rng) -> CsrMatrix {
    let n = rng.range(8, 200);
    let extra = rng.range(0, 4 * n);
    let mut coo = CooMatrix::new(n, n);
    for i in 0..n {
        coo.push(i, i, 2.0 + rng.f64());
        if i + 1 < n {
            // chain keeps the graph connected
            let v = -rng.f64();
            coo.push(i, i + 1, v);
            coo.push(i + 1, i, v);
        }
    }
    for _ in 0..extra {
        let a = rng.below(n);
        let b = rng.below(n);
        if a != b {
            let v = rng.range_f64(-0.5, 0.5);
            coo.push(a, b, v);
            coo.push(b, a, v);
        }
    }
    coo.to_csr()
}

#[test]
fn prop_bfs_levels_satisfy_invariant() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..60 {
        let a = random_matrix(&mut rng);
        let root = rng.below(a.n_rows());
        let (b, lv) = bfs_reorder(&a, root);
        lv.validate(&b).unwrap_or_else(|e| panic!("case {case}: {e}"));
        // permutation bijective
        let mut seen = vec![false; a.n_rows()];
        for &p in &lv.perm {
            assert!(!seen[p], "case {case}: duplicate perm entry");
            seen[p] = true;
        }
        // levels tile the rows
        assert_eq!(*lv.level_ptr.last().unwrap(), a.n_rows());
    }
}

#[test]
fn prop_wavefront_schedules_valid_for_random_budgets() {
    let mut rng = Rng::new(0xCAFE);
    for case in 0..40 {
        let a = random_matrix(&mut rng);
        let (b, lv) = bfs_reorder(&a, 0);
        let p_m = rng.range(1, 7);
        let budget = rng.range(1, b.crs_bytes() + 1);
        let s_m = rng.range(1, 80);
        let g = group_levels(&b, &lv, p_m, budget, s_m);
        g.validate(b.n_rows()).unwrap();
        let s = wavefront(&g, lv.n_levels(), p_m);
        validate_schedule(&g, lv.n_levels(), p_m, &s)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
    }
}

#[test]
fn prop_partitions_cover_and_stats_consistent() {
    let mut rng = Rng::new(0xD00D);
    for case in 0..40 {
        let a = random_matrix(&mut rng);
        let np = rng.range(1, a.n_rows().min(9));
        let method = [Method::Block, Method::GreedyGrow, Method::RecursiveBisect][rng.below(3)];
        let p = partition(&a, np, method);
        p.validate(a.n_rows()).unwrap_or_else(|e| panic!("case {case} {method:?}: {e}"));
        let st = PartitionStats::compute(&a, &p);
        // halo never exceeds edgecut (distinct columns <= cut entries)
        assert!(st.halo_elements <= st.edgecut.max(1), "case {case}");
        // O_MPI consistent with DistMatrix
        let d = DistMatrix::build(&a, &p);
        assert_eq!(d.total_halo(), st.halo_elements, "case {case}");
    }
}

#[test]
fn prop_three_variants_agree_everywhere() {
    let mut rng = Rng::new(0xF00D);
    for case in 0..25 {
        let a = random_matrix(&mut rng);
        let np = rng.range(1, a.n_rows().min(7));
        let p_m = rng.range(1, 6);
        let cache = rng.range(1, 1 << 16);
        let part = partition(&a, np, Method::GreedyGrow);
        let d = DistMatrix::build(&a, &part);
        let x: Vec<f64> = (0..a.n_rows()).map(|_| rng.range_f64(-1.0, 1.0)).collect();

        let want = trad_mpk(&d, &x, p_m, &mut NativeBackend);
        let got_dlb = dlb::dlb_mpk(
            &d,
            &x,
            p_m,
            &DlbOptions { cache_bytes: cache, s_m: 50, async_remainder: false },
            &mut NativeBackend,
        );
        let got_ca = ca::ca_mpk_with(&a, &d, &x, p_m);

        for (label, got) in [("dlb", &got_dlb.result), ("ca", &got_ca.result)] {
            for (p, (gp, wp)) in got.powers.iter().zip(&want.powers).enumerate() {
                for (r, (u, v)) in gp.iter().zip(wp).enumerate() {
                    assert!(
                        (u - v).abs() < 1e-9 * (1.0 + v.abs()),
                        "case {case} {label} np={np} p_m={p_m} power={} row={r}: {u} vs {v}",
                        p + 1
                    );
                }
            }
        }
        // DLB: identical comm + flops as TRAD
        assert_eq!(got_dlb.result.comm.bytes, want.comm.bytes, "case {case}");
        assert_eq!(got_dlb.result.flop_nnz, want.flop_nnz, "case {case}");
        // CA: never less work, never more rounds
        assert!(got_ca.result.flop_nnz >= want.flop_nnz, "case {case}");
        assert!(got_ca.result.comm.rounds <= 1, "case {case}");
    }
}

#[test]
fn prop_dlb_overheads_bounded() {
    let mut rng = Rng::new(0xAB);
    for _ in 0..20 {
        let a = random_matrix(&mut rng);
        let np = rng.range(1, a.n_rows().min(6));
        let p_m = rng.range(1, 8);
        let part = partition(&a, np, Method::RecursiveBisect);
        let d = DistMatrix::build(&a, &part);
        let o = dlb_mpk::mpk::overheads::dlb_overhead(
            &d,
            p_m,
            &DlbOptions { cache_bytes: 1 << 14, s_m: 50, async_remainder: false },
        );
        assert!((0.0..=1.0).contains(&o), "O_DLB = {o}");
        if np == 1 {
            assert_eq!(o, 0.0);
        }
    }
}

#[test]
fn prop_ell_spmv_matches_csr() {
    let mut rng = Rng::new(0x7777);
    for _ in 0..30 {
        let a = random_matrix(&mut rng);
        let align = [1usize, 8, 64, 256][rng.below(4)];
        let ell = dlb_mpk::matrix::EllChunk::from_csr(&a, align);
        let x: Vec<f64> = (0..a.n_rows()).map(|_| rng.normal()).collect();
        let mut y1 = vec![0.0; a.n_rows()];
        let mut y2 = vec![0.0; a.n_rows()];
        a.spmv(&x, &mut y1);
        ell.spmv(&x, &mut y2);
        for (u, v) in y1.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-12);
        }
    }
}

#[test]
fn prop_levels_from_level_of_is_stable_sort() {
    let mut rng = Rng::new(0x51);
    for _ in 0..30 {
        let n = rng.range(1, 300);
        let n_levels = rng.range(1, 12);
        let level_of: Vec<u32> = (0..n).map(|_| rng.below(n_levels) as u32).collect();
        let lv = Levels::from_level_of(&level_of, n_levels);
        // stability: within a level, original order preserved
        for l in 0..n_levels {
            let rows: Vec<usize> = lv.rows(l).map(|r| lv.perm[r]).collect();
            let mut sorted = rows.clone();
            sorted.sort_unstable();
            assert_eq!(rows, sorted);
            for &r in &rows {
                assert_eq!(level_of[r] as usize, l);
            }
        }
    }
}

#[test]
fn prop_mm_roundtrip_random() {
    let mut rng = Rng::new(0x99);
    let dir = std::env::temp_dir().join("dlbmpk_prop_mm");
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..10 {
        let a = random_matrix(&mut rng);
        let p = dir.join(format!("m{case}.mtx"));
        dlb_mpk::matrix::mm::write_matrix_market(&a, &p).unwrap();
        let b = dlb_mpk::matrix::mm::read_matrix_market(&p).unwrap();
        assert_eq!(a, b, "case {case}");
    }
    let _ = gen::tridiag(2);
}
