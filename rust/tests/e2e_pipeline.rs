//! End-to-end pipeline integration: coordinator drivers, CLI-equivalent
//! configs, cache-sim traffic sanity, Chebyshev physics.

use dlb_mpk::cachesim::{replay, LruCache, MpkTrace};
use dlb_mpk::coordinator::{self, MatrixSpec, RunConfig};
use dlb_mpk::graph::levels::bfs_reorder;
use dlb_mpk::partition::Method;
use dlb_mpk::race::{group_levels, wavefront};

#[test]
fn coordinator_full_pipeline_all_specs() {
    for (matrix, ranks) in [
        (MatrixSpec::Stencil2D { nx: 20, ny: 20 }, 2),
        (MatrixSpec::Stencil3D { nx: 8, ny: 8, nz: 8 }, 3),
        (MatrixSpec::Banded { n: 500, nnzr: 10, band: 40, seed: 2 }, 4),
        (MatrixSpec::Anderson { l: 8, w: 1.5, seed: 5 }, 2),
        (MatrixSpec::Suite { name: "af_shell10-s".into(), scale: 0.02 }, 2),
    ] {
        let cfg = RunConfig {
            matrix,
            n_ranks: ranks,
            partitioner: Method::RecursiveBisect,
            p_m: 3,
            cache_bytes: 64 << 10,
            s_m: 50,
            reps: 1,
            validate: true,
            ..Default::default()
        };
        let out = coordinator::run(&cfg).expect("pipeline");
        assert_eq!(out.reports[1].validated, Some(true));
        assert!(out.reports.iter().all(|r| r.gflops > 0.0));
    }
}

#[test]
fn file_spec_roundtrip() {
    let a = dlb_mpk::matrix::gen::stencil_2d_5pt(12, 12);
    let dir = std::env::temp_dir().join("dlbmpk_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("m.mtx");
    dlb_mpk::matrix::mm::write_matrix_market(&a, &path).unwrap();
    let cfg = RunConfig {
        matrix: MatrixSpec::File { path },
        n_ranks: 2,
        reps: 1,
        p_m: 2,
        ..Default::default()
    };
    let out = coordinator::run(&cfg).unwrap();
    assert_eq!(out.reports[1].validated, Some(true));
}

#[test]
fn cache_traffic_ratio_tracks_pm() {
    // DLB traffic stays ~flat in p_m, TRAD grows linearly — the core
    // cache-blocking claim, on the simulator.
    let a = dlb_mpk::matrix::gen::random_banded_sym(6_000, 14, 80, 5);
    let (b, lv) = bfs_reorder(&a, 0);
    let cache = 128 << 10;
    let mut prev_ratio = 0.0;
    for p_m in [2usize, 4, 8] {
        let g = group_levels(&b, &lv, p_m, cache / 2, 50);
        let s = wavefront(&g, lv.n_levels(), p_m);
        let mut c1 = LruCache::new(cache, 64, 8);
        let trad = replay(&MpkTrace::trad(&b, p_m), &mut c1);
        let mut c2 = LruCache::new(cache, 64, 8);
        let dlb = replay(&MpkTrace::wavefront(&b, &g.ranges, &s), &mut c2);
        let ratio = trad.mem_traffic as f64 / dlb.mem_traffic as f64;
        assert!(ratio > prev_ratio, "traffic ratio must grow with p_m: {ratio}");
        prev_ratio = ratio;
    }
    // at p_m = 8 the ratio should approach p_m (ideal blocking)
    assert!(prev_ratio > 4.0, "expected strong blocking, got {prev_ratio}");
}

#[test]
fn chebyshev_boomerang_localized_vs_delocalized() {
    use dlb_mpk::apps::chebyshev::*;
    use dlb_mpk::apps::observables::center_of_mass;
    use dlb_mpk::distsim::DistMatrix;
    use dlb_mpk::engine::{EngineConfig, Variant};
    use dlb_mpk::matrix::anderson::{anderson, AndersonConfig};
    use dlb_mpk::mpk::dlb::DlbOptions;
    use dlb_mpk::partition::partition;

    let run = |t_perp: f64| {
        let cfg = AndersonConfig { lx: 128, ly: 4, lz: 4, w: 2.5, t: 1.0, t_perp, seed: 77 };
        let h = anderson(&cfg);
        let part = partition(&h, 2, Method::Block);
        let dist = DistMatrix::build(&h, &part);
        let ccfg = ChebyshevConfig {
            dt: 2.0,
            p_m: 4,
            engine: EngineConfig {
                variant: Variant::Dlb(DlbOptions { cache_bytes: 1 << 20, s_m: 50, async_remainder: false }),
                ..EngineConfig::default()
            },
        };
        let mut prop = ChebyshevPropagator::new(&h, &dist, ccfg).expect("engine builds");
        let mut psi = wave_packet(&cfg, 6.0, [std::f64::consts::FRAC_PI_2, 0.0, 0.0]);
        let mut peak: f64 = 0.0;
        let mut last = 0.0;
        for _ in 0..20 {
            psi = prop.step(&psi);
            last = center_of_mass(&cfg, &psi.density())[0];
            peak = peak.max(last);
        }
        assert!((psi.norm2() - 1.0).abs() < 1e-8, "unitarity lost: {}", psi.norm2());
        (peak, last)
    };
    let (peak_loc, final_loc) = run(0.001);
    let (_, final_deloc) = run(0.5);
    // localized: packet turned back from its peak (boomerang)
    assert!(
        final_loc < 0.7 * peak_loc,
        "no boomerang: peak {peak_loc} final {final_loc}"
    );
    // delocalized travels at least as far as the localized final position
    assert!(final_deloc > final_loc, "deloc {final_deloc} vs loc {final_loc}");
}
