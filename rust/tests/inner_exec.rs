//! Acceptance tests for the within-rank inner executor (`crate::inner`):
//!
//! * `inner_threads(k)` is **bitwise invisible to results** — for every
//!   variant × executor × `p_m` × recurrence, `k ∈ {2, 4}` produces the
//!   same powers, merged [`dlb_mpk::distsim::CommStats`], and flop count
//!   as the serial `k = 1` engine;
//! * one `inner_threads(2)` engine is reusable across ≥ 3 sweeps (the
//!   inner pools persist with the rank pool — no per-sweep spawning);
//! * tracing with inner threads stays invisible, exports a valid chrome
//!   trace whose `inner.task(g,p)` spans land on per-worker lanes, and
//!   keeps the metrics flow totals equal to the CommStats.

use dlb_mpk::distsim::DistMatrix;
use dlb_mpk::engine::{MpkEngine, SweepResult, Variant};
use dlb_mpk::exec::ExecutorKind;
use dlb_mpk::matrix::gen;
use dlb_mpk::mpk::dlb::{DlbOptions, Recurrence};
use dlb_mpk::partition::{partition, Method};
use dlb_mpk::trace::validate_chrome_trace;

fn dist(np: usize) -> DistMatrix {
    let a = gen::stencil_2d_5pt(14, 12);
    let part = partition(&a, np, Method::Block);
    DistMatrix::build(&a, &part)
}

fn input(n: usize) -> Vec<f64> {
    (0..n).map(|i| ((i % 17) as f64 - 8.0) / 9.0).collect()
}

fn variants() -> Vec<Variant> {
    vec![
        Variant::Trad,
        Variant::Ca,
        Variant::Dlb(DlbOptions { cache_bytes: 8 << 10, s_m: 50, async_remainder: false }),
    ]
}

fn build(d: &DistMatrix, v: Variant, ex: ExecutorKind, p_m: usize, k: usize) -> MpkEngine {
    MpkEngine::builder(d)
        .p_m(p_m)
        .variant(v)
        .executor(ex)
        .inner_threads(k)
        .build()
        .expect("engine builds")
}

fn assert_bitwise(a: &SweepResult, b: &SweepResult, what: &str) {
    assert_eq!(a.powers.len(), b.powers.len(), "{what}: power count");
    for (p, (pa, pb)) in a.powers.iter().zip(&b.powers).enumerate() {
        for (i, (u, v)) in pa.iter().zip(pb).enumerate() {
            assert!(
                u.to_bits() == v.to_bits(),
                "{what}: powers[{p}][{i}] differs bitwise: {u:?} vs {v:?}"
            );
        }
    }
    assert_eq!(a.comm, b.comm, "{what}: comm stats");
    assert_eq!(a.flop_nnz, b.flop_nnz, "{what}: flop count");
}

/// Acceptance: `inner_threads(k)` never changes a sweep — bitwise-equal
/// powers, comm stats, and flops against the serial engine for every
/// variant on both executors, at `p_m ∈ {1, 4}`.
#[test]
fn inner_threads_are_bitwise_equal_to_serial() {
    let d = dist(3);
    let x = input(d.n_global);
    for v in variants() {
        for ex in [ExecutorKind::Sim, ExecutorKind::Threads { n: 0 }] {
            for p_m in [1usize, 4] {
                let base = build(&d, v, ex, p_m, 1).sweep(&x, None, Recurrence::Power);
                for k in [2usize, 4] {
                    let mut eng = build(&d, v, ex, p_m, k);
                    assert_eq!(eng.inner_threads(), k);
                    let got = eng.sweep(&x, None, Recurrence::Power);
                    let what = format!("{} on {ex}, p_m={p_m}, k={k}", v.label());
                    assert_bitwise(&base, &got, &what);
                }
            }
        }
    }
}

/// The three-term Chebyshev recurrence (prev2 feeds every row update)
/// splits just as cleanly: same-batch tasks never read a power that a
/// concurrent task writes.
#[test]
fn inner_threads_match_serial_on_chebyshev_recurrence() {
    let d = dist(2);
    let x = input(d.n_global);
    let xm1 = input(d.n_global).iter().map(|v| v * 0.5).collect::<Vec<_>>();
    for v in [Variant::Trad, Variant::Dlb(DlbOptions { cache_bytes: 8 << 10, s_m: 50, async_remainder: false })] {
        for ex in [ExecutorKind::Sim, ExecutorKind::Threads { n: 0 }] {
            let base = build(&d, v, ex, 4, 1).sweep(&x, Some(&xm1), Recurrence::Chebyshev);
            let got = build(&d, v, ex, 4, 2).sweep(&x, Some(&xm1), Recurrence::Chebyshev);
            assert_bitwise(&base, &got, &format!("chebyshev {} on {ex}", v.label()));
        }
    }
}

/// One hierarchical engine serves many sweeps: the rank pool and its inner
/// pools are spawned once, and every repeat of the same input is identical
/// (per-sweep stats never accumulate).
#[test]
fn hierarchical_engine_is_reusable_across_sweeps() {
    let d = dist(2);
    let x = input(d.n_global);
    let mut serial = build(
        &d,
        Variant::Dlb(DlbOptions { cache_bytes: 8 << 10, s_m: 50, async_remainder: false }),
        ExecutorKind::Threads { n: 0 },
        4,
        1,
    );
    let base = serial.sweep(&x, None, Recurrence::Power);
    let mut eng = build(
        &d,
        Variant::Dlb(DlbOptions { cache_bytes: 8 << 10, s_m: 50, async_remainder: false }),
        ExecutorKind::Threads { n: 0 },
        4,
        2,
    );
    for s in 1..=3 {
        let got = eng.sweep(&x, None, Recurrence::Power);
        assert_bitwise(&base, &got, &format!("sweep {s}"));
        let pool = eng.pool_stats().expect("threads executor keeps a pool");
        assert_eq!(pool.threads, d.n_ranks(), "sweep {s}: rank pool never re-spawns");
        assert_eq!(pool.sweeps, s, "sweep {s}: same pool serves every sweep");
    }
}

/// Tracing a hierarchical sweep stays invisible to results, and the
/// export carries the inner-task spans on per-worker lanes that map back
/// to their owning rank.
#[test]
fn traced_inner_threads_stay_invisible_and_export_lanes() {
    let d = dist(2);
    let x = input(d.n_global);
    for (v, ex) in [
        (Variant::Trad, ExecutorKind::Sim),
        (Variant::Ca, ExecutorKind::Threads { n: 0 }),
        (
            Variant::Dlb(DlbOptions { cache_bytes: 8 << 10, s_m: 50, async_remainder: false }),
            ExecutorKind::Threads { n: 0 },
        ),
    ] {
        let plain = build(&d, v, ex, 4, 2).sweep(&x, None, Recurrence::Power);
        let mut eng = MpkEngine::builder(&d)
            .p_m(4)
            .variant(v)
            .executor(ex)
            .inner_threads(2)
            .trace(true)
            .build()
            .expect("engine builds");
        let traced = eng.sweep(&x, None, Recurrence::Power);
        let what = format!("{} on {ex}", v.label());
        assert_bitwise(&plain, &traced, &what);
        let json = eng.chrome_trace_json().expect("tracing enabled");
        let check =
            validate_chrome_trace(&json).unwrap_or_else(|e| panic!("{what}: invalid trace: {e}"));
        assert_eq!(check.n_ranks(), d.n_ranks(), "{what}: every rank contributes spans");
        assert!(check.has_name_prefix("inner.task"), "{what}: names {:?}", check.names);
        let m = eng.metrics().expect("tracing enabled");
        assert_eq!(m.total_bytes, traced.comm.bytes, "{what}: received bytes");
        assert_eq!(m.total_messages, traced.comm.messages, "{what}: received messages");
    }
}
