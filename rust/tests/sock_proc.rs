//! Multi-process oracle for the SockComm transport: drives the real
//! `dlb-mpk` binary through the `launch` subcommand (separate OS process
//! per rank, Unix-domain socket halo exchange) and byte-compares `sweep`
//! dumps against a sequential-simulator run of the identical
//! configuration — the dump format deliberately excludes everything
//! executor-dependent, so the files must be **byte-identical**. Also
//! proves the failure-beats-deadlock rule: a rank dying mid-run makes the
//! whole launch fail fast instead of hanging the surviving peers.

use std::path::PathBuf;
use std::process::Command;
use std::time::{Duration, Instant};

const MATRIX: &str = "stencil2d:24,20";

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_dlb-mpk")
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dlb-mpk-sockproc-{}-{name}", std::process::id()))
}

/// Run the binary, asserting success and surfacing its output on failure.
fn run_ok(args: &[&str]) {
    let out = Command::new(bin()).args(args).output().expect("spawn dlb-mpk");
    assert!(
        out.status.success(),
        "dlb-mpk {args:?} failed ({}):\n--- stdout ---\n{}\n--- stderr ---\n{}",
        out.status,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );
}

/// TRAD / CA / DLB (plus inner-threaded and async-remainder DLB shapes)
/// under `launch --np 2`: the processes-executor dump must match the sim
/// dump byte for byte (powers are hex-encoded f64 bit patterns, so this
/// is a bitwise claim about every value of every power).
#[test]
fn process_sweeps_are_byte_identical_to_sim() {
    let cases: [(&str, &[&str]); 5] = [
        ("trad", &[]),
        ("ca", &[]),
        ("dlb", &[]),
        ("dlb", &["--inner-threads", "2"]),
        ("dlb", &["--async-remainder"]),
    ];
    for (i, (variant, extra)) in cases.iter().enumerate() {
        let sim_out = tmp(&format!("sim-{i}.json"));
        let proc_out = tmp(&format!("proc-{i}.json"));
        let common = ["sweep", "--matrix", MATRIX, "--ranks", "2", "--pm", "3", "--variant", variant];

        let mut sim_args: Vec<&str> = common.to_vec();
        sim_args.extend(*extra);
        sim_args.extend(["--executor", "sim", "--out", sim_out.to_str().unwrap()]);
        run_ok(&sim_args);

        let mut proc_args: Vec<&str> = vec!["launch", "--np", "2", "--"];
        proc_args.extend(common);
        proc_args.extend(*extra);
        proc_args.extend(["--executor", "processes", "--out", proc_out.to_str().unwrap()]);
        run_ok(&proc_args);

        let sim = std::fs::read(&sim_out).expect("sim dump written");
        let proc = std::fs::read(&proc_out).expect("process dump written (by rank 0)");
        assert!(!sim.is_empty(), "case {i} ({variant} {extra:?}): empty sim dump");
        assert_eq!(
            sim, proc,
            "case {i} ({variant} {extra:?}): sim and processes dumps differ"
        );
        let _ = std::fs::remove_file(&sim_out);
        let _ = std::fs::remove_file(&proc_out);
    }
}

/// Rank failure must not deadlock the world: `--die-rank 1` makes rank 1
/// exit(3) after the socket rendezvous, so rank 0 is left blocking on its
/// halo recv. The EOF (or, at worst, the per-operation timeout) must turn
/// that into a loud launch failure, quickly.
#[test]
fn dead_rank_fails_fast_without_hanging() {
    let out_path = tmp("die.json");
    let start = Instant::now();
    let out = Command::new(bin())
        .args([
            "launch", "--np", "2", "--timeout-ms", "3000", "--",
            "sweep", "--matrix", MATRIX, "--ranks", "2", "--pm", "3",
            "--executor", "processes", "--die-rank", "1",
            "--out", out_path.to_str().unwrap(),
        ])
        .output()
        .expect("spawn dlb-mpk launch");
    let elapsed = start.elapsed();
    assert!(
        !out.status.success(),
        "launch with a dead rank must fail, got success:\n{}",
        String::from_utf8_lossy(&out.stdout)
    );
    assert!(
        elapsed < Duration::from_secs(20),
        "dead rank took {elapsed:?} to surface — that is a hang, not a failure"
    );
    let _ = std::fs::remove_file(&out_path);
}
