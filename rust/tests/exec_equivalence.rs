//! Executor cross-validation: the threaded executor (one OS thread per
//! rank, real channel halo exchange) and the socket transport (`SockComm`,
//! real Unix-domain socket frames) must be *bitwise* identical to the
//! sequential lockstep simulator — same `powers`, same merged `CommStats`,
//! same flop counts — for all three MPK variants, across rank counts,
//! matrix structures, inner-pool widths, and remainder modes. Plus a
//! seeded-random ("proptest-style", see proptest_invariants.rs) sweep
//! checking the threaded halo exchange delivers every `SendPlan` row
//! exactly once.

use dlb_mpk::distsim::{merge_rank_stats, CommStats, DistMatrix};
use dlb_mpk::engine::{BackendSpec, MpkEngine, Variant};
use dlb_mpk::exec::{self, sim_comms, sock_comms, thread_comms, Communicator, ExecutorKind, RankRun};
use dlb_mpk::inner::InnerExec;
use dlb_mpk::matrix::{gen, CsrMatrix};
use dlb_mpk::mpk::dlb::{self, DlbOptions, Recurrence};
use dlb_mpk::mpk::{ca, trad, trad_mpk, NativeBackend, SpmvBackend};
use dlb_mpk::partition::{partition, Method};
use dlb_mpk::util::rng::Rng;
use std::time::Duration;

const RANKS: [usize; 4] = [1, 2, 4, 7];

fn test_vector(n: usize) -> Vec<f64> {
    (0..n).map(|i| ((i * 37 % 101) as f64 - 50.0) / 101.0).collect()
}

fn assert_bitwise(a: &[Vec<f64>], b: &[Vec<f64>], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: power count");
    for (p, (u, v)) in a.iter().zip(b).enumerate() {
        assert_eq!(u.len(), v.len(), "{tag}: power {} length", p + 1);
        for (r, (x, y)) in u.iter().zip(v).enumerate() {
            assert!(
                x.to_bits() == y.to_bits(),
                "{tag}: power {} row {r}: {x:?} != {y:?} (bitwise)",
                p + 1
            );
        }
    }
}

fn check_all_variants(a: &CsrMatrix, np: usize, p_m: usize, cache: usize) {
    let x = test_vector(a.n_rows());
    let part = partition(a, np, Method::Block);
    let d = DistMatrix::build(a, &part);
    let tag = format!("np={np} p_m={p_m}");

    // TRAD
    let sim = trad_mpk(&d, &x, p_m, &mut NativeBackend);
    let thr = exec::trad_threaded(&d, &x, None, p_m, Recurrence::Power);
    assert_bitwise(&sim.powers, &thr.powers, &format!("trad {tag}"));
    assert_eq!(sim.comm, thr.comm, "trad stats {tag}");
    assert_eq!(sim.flop_nnz, thr.flop_nnz, "trad flops {tag}");

    // DLB (same plan drives both executors)
    let opts = DlbOptions { cache_bytes: cache, s_m: 50, async_remainder: false };
    let plan = dlb::plan(&d, p_m, &opts);
    let sim = dlb::execute(&plan, &x, &mut NativeBackend);
    let thr = exec::dlb_threaded(&plan, &x, None, Recurrence::Power);
    assert_bitwise(&sim.powers, &thr.powers, &format!("dlb {tag}"));
    assert_eq!(sim.comm, thr.comm, "dlb stats {tag}");
    assert_eq!(sim.flop_nnz, thr.flop_nnz, "dlb flops {tag}");

    // CA
    let sim = ca::ca_mpk_with(a, &d, &x, p_m);
    let thr = exec::ca_threaded(a, &d, &x, p_m);
    assert_bitwise(&sim.result.powers, &thr.powers, &format!("ca {tag}"));
    assert_eq!(sim.result.comm, thr.comm, "ca stats {tag}");
    assert_eq!(sim.result.flop_nnz, thr.flop_nnz, "ca flops {tag}");
}

#[test]
fn sim_and_threads_agree_on_stencil() {
    let a = gen::stencil_2d_5pt(14, 11);
    for np in RANKS {
        for p_m in [1, 3, 4] {
            check_all_variants(&a, np, p_m, 8 << 10);
        }
    }
}

#[test]
fn sim_and_threads_agree_on_random_banded() {
    let a = gen::random_banded_sym(240, 9, 30, 5);
    for np in RANKS {
        check_all_variants(&a, np, 4, 4 << 10);
    }
}

#[test]
fn sim_and_threads_agree_on_chebyshev_recurrence() {
    use dlb_mpk::mpk::trad::trad_recurrence;
    let a = gen::stencil_2d_5pt(12, 12);
    let n = a.n_rows();
    let x = test_vector(n);
    let xm1: Vec<f64> = (0..n).map(|i| ((i * 13 % 29) as f64) / 29.0).collect();
    for np in [2, 4] {
        let part = partition(&a, np, Method::Block);
        let d = DistMatrix::build(&a, &part);
        let p_m = 3;
        let sim = trad_recurrence(&d, &x, Some(&xm1), p_m, Recurrence::Chebyshev, &mut NativeBackend);
        let thr = exec::trad_threaded(&d, &x, Some(&xm1), p_m, Recurrence::Chebyshev);
        assert_bitwise(&sim.powers, &thr.powers, "cheb trad");
        assert_eq!(sim.comm, thr.comm);

        let plan = dlb::plan(&d, p_m, &DlbOptions { cache_bytes: 8 << 10, s_m: 50, async_remainder: false });
        let sim = dlb::execute_recurrence(&plan, &x, Some(&xm1), Recurrence::Chebyshev, &mut NativeBackend);
        let thr = exec::dlb_threaded(&plan, &x, Some(&xm1), Recurrence::Chebyshev);
        assert_bitwise(&sim.powers, &thr.powers, "cheb dlb");
        assert_eq!(sim.comm, thr.comm);
    }
}

/// Engine-level Chebyshev sweeps (`x_m1 = Some(..)`): one sim-executor and
/// one threads-executor `MpkEngine` per variant must agree bitwise, powers
/// and merged stats alike.
#[test]
fn engine_sim_and_threads_agree_on_chebyshev_sweeps() {
    let a = gen::stencil_2d_5pt(13, 9);
    let n = a.n_rows();
    let x = test_vector(n);
    let xm1: Vec<f64> = (0..n).map(|i| ((i * 13 % 29) as f64) / 29.0).collect();
    for np in [1, 3] {
        let part = partition(&a, np, Method::Block);
        let d = DistMatrix::build(&a, &part);
        for variant in [
            Variant::Trad,
            Variant::Dlb(DlbOptions { cache_bytes: 8 << 10, s_m: 50, async_remainder: false }),
        ] {
            let mut sim_eng =
                MpkEngine::builder(&d).p_m(3).variant(variant).build().unwrap();
            let mut thr_eng = MpkEngine::builder(&d)
                .p_m(3)
                .variant(variant)
                .executor(ExecutorKind::Threads { n: 0 })
                .build()
                .unwrap();
            let sim = sim_eng.sweep(&x, Some(&xm1), Recurrence::Chebyshev);
            let thr = thr_eng.sweep(&x, Some(&xm1), Recurrence::Chebyshev);
            let tag = format!("engine cheb {} np={np}", variant.label());
            assert_bitwise(&sim.powers, &thr.powers, &tag);
            assert_eq!(sim.comm, thr.comm, "{tag}");
            assert_eq!(sim.flop_nnz, thr.flop_nnz, "{tag}");
        }
    }
}

/// Engine *reuse*: two back-to-back sweeps on one engine must be bitwise
/// identical to two fresh engines — catching workspace or pool state
/// leaking across sweeps, under both executors and all three variants.
#[test]
fn engine_reuse_matches_fresh_engines() {
    let a = gen::stencil_2d_5pt(11, 10);
    let n = a.n_rows();
    let x1 = test_vector(n);
    let x2: Vec<f64> = (0..n).map(|i| ((i * 7 % 23) as f64 - 11.0) / 5.0).collect();
    let part = partition(&a, 3, Method::Block);
    let d = DistMatrix::build(&a, &part);
    for executor in [ExecutorKind::Sim, ExecutorKind::Threads { n: 0 }] {
        for variant in [
            Variant::Trad,
            Variant::Dlb(DlbOptions { cache_bytes: 8 << 10, s_m: 50, async_remainder: false }),
            Variant::Ca,
        ] {
            let build = || {
                MpkEngine::builder(&d)
                    .p_m(3)
                    .variant(variant)
                    .executor(executor)
                    .build()
                    .unwrap()
            };
            // Chebyshev second sweep for TRAD/DLB stresses the y_{-1}
            // workspace path too; CA only supports the power recurrence.
            let (rec2, xm1) = match variant {
                Variant::Ca => (Recurrence::Power, None),
                _ => (Recurrence::Chebyshev, Some(&x1[..])),
            };

            let mut reused = build();
            let r1 = reused.sweep(&x1, None, Recurrence::Power);
            let r2 = reused.sweep(&x2, xm1, rec2);

            let f1 = build().sweep(&x1, None, Recurrence::Power);
            let f2 = build().sweep(&x2, xm1, rec2);

            let tag = format!("reuse {} @ {executor}", variant.label());
            assert_bitwise(&r1.powers, &f1.powers, &format!("{tag} sweep 1"));
            assert_bitwise(&r2.powers, &f2.powers, &format!("{tag} sweep 2"));
            assert_eq!(r1.comm, f1.comm, "{tag} sweep 1 stats");
            assert_eq!(r2.comm, f2.comm, "{tag} sweep 2 stats");
            assert_eq!(reused.sweeps_run(), 2);
        }
    }
}

#[test]
fn dispatcher_agrees_across_executors_for_all_variants() {
    use dlb_mpk::mpk::MpkVariant;
    let a = gen::stencil_2d_5pt(10, 10);
    let x = test_vector(a.n_rows());
    let part = partition(&a, 3, Method::Block);
    let d = DistMatrix::build(&a, &part);
    for variant in [
        MpkVariant::Trad,
        MpkVariant::Ca,
        MpkVariant::Dlb { cache_bytes: 8 << 10 },
    ] {
        let sim = exec::run(&d, &x, 3, variant, ExecutorKind::Sim);
        let thr = exec::run(&d, &x, 3, variant, ExecutorKind::Threads { n: 0 });
        assert_bitwise(&sim.powers, &thr.powers, &format!("dispatch {variant:?}"));
        assert_eq!(sim.comm, thr.comm, "dispatch {variant:?}");
    }
}

/// Proptest-style sweep: for random symmetric banded matrices and rank
/// counts, one threaded halo exchange must deliver the owner's value of
/// every `SendPlan` row to the matching halo slot exactly once — message
/// and byte counts equal the plan totals exactly (duplicates would trip
/// the ThreadComm pending-queue assertion and inflate the counters).
#[test]
fn threaded_exchange_delivers_every_send_plan_row_exactly_once() {
    let mut rng = Rng::new(0xD15C0);
    for case in 0..25 {
        let n = rng.range(20, 260);
        let nnzr = rng.range(3, 9);
        let band = rng.range(2, 1 + n / 3);
        let a = gen::random_banded_sym(n, nnzr, band, rng.next_u64());
        let np = rng.range(1, 8);
        let part = partition(&a, np, Method::Block);
        let d = DistMatrix::build(&a, &part);
        // unique sentinel per global row
        let x: Vec<f64> = (0..n).map(|g| 1.0 + g as f64).collect();
        let xs = d.scatter(&x);

        let comms = thread_comms(d.n_ranks());
        let outs: Vec<(Vec<f64>, CommStats)> = std::thread::scope(|s| {
            let joins: Vec<_> = comms
                .into_iter()
                .zip(&d.ranks)
                .zip(xs)
                .map(|((mut c, r), mut xv)| {
                    s.spawn(move || {
                        c.exchange(r, 0, &mut xv);
                        let st = c.stats().clone();
                        (xv, st)
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().expect("rank panicked")).collect()
        });

        let mut delivered = 0usize;
        for (r, (xv, _)) in d.ranks.iter().zip(&outs) {
            for (slot, &g) in r.halo_globals.iter().enumerate() {
                assert_eq!(
                    xv[r.n_local() + slot],
                    x[g],
                    "case {case}: rank {} halo slot {slot} (global {g})",
                    r.rank
                );
                delivered += 1;
            }
        }
        assert_eq!(delivered, d.total_halo(), "case {case}");

        let per_rank: Vec<CommStats> = outs.iter().map(|(_, s)| s.clone()).collect();
        let merged = merge_rank_stats(&per_rank);
        let planned_msgs: usize = d.ranks.iter().map(|r| r.recv.len()).sum();
        let planned_rows: usize = d.ranks.iter().flat_map(|r| &r.send).map(|sp| sp.rows.len()).sum();
        assert_eq!(merged.messages, planned_msgs, "case {case}: one message per plan");
        assert_eq!(merged.bytes, planned_rows * 8, "case {case}: every row exactly once");
        assert_eq!(merged.rounds, 1, "case {case}");
    }
}

/// Acceptance sweep for `DlbOptions::async_remainder`: across rank counts,
/// block sizes, executors, and inner-thread counts, the pipelined remainder
/// must be bitwise identical to the lockstep path — same powers, same
/// volume/round counters, same flop count.
#[test]
fn async_remainder_matches_sync_across_executors() {
    let a = gen::stencil_2d_5pt(16, 12);
    let x = test_vector(a.n_rows());
    for np in [2, 4] {
        let part = partition(&a, np, Method::Block);
        let d = DistMatrix::build(&a, &part);
        for p_m in [2, 4] {
            let opts = DlbOptions { cache_bytes: 8 << 10, s_m: 50, async_remainder: false };
            let mut base_eng =
                MpkEngine::builder(&d).p_m(p_m).variant(Variant::Dlb(opts)).build().unwrap();
            let base = base_eng.sweep(&x, None, Recurrence::Power);
            for executor in [ExecutorKind::Sim, ExecutorKind::Threads { n: 0 }] {
                for inner in [1, 2] {
                    let mut eng = MpkEngine::builder(&d)
                        .p_m(p_m)
                        .variant(Variant::Dlb(opts))
                        .async_remainder(true)
                        .executor(executor)
                        .inner_threads(inner)
                        .build()
                        .unwrap();
                    let got = eng.sweep(&x, None, Recurrence::Power);
                    let tag = format!("async np={np} p_m={p_m} {executor} inner={inner}");
                    assert_bitwise(&base.powers, &got.powers, &tag);
                    assert_eq!(base.comm, got.comm, "{tag} stats");
                    assert_eq!(base.flop_nnz, got.flop_nnz, "{tag} flops");
                }
            }
        }
    }
}

/// Adversarial out-of-order delivery on the channel transport: two peers
/// post sends in ascending vs. descending tag order; the receiver completes
/// them via `recv_any`/`try_recv`. Every `(from, tag)` message must arrive
/// exactly once with the right payload, in the documented
/// lowest-request-index completion order, with receiver-side counters equal
/// to a `SimComm` run of the identical traffic.
#[test]
fn thread_comm_out_of_order_sends_deliver_exactly_once() {
    fn payload(from: usize, tag: u64) -> Vec<f64> {
        vec![from as f64 * 100.0 + tag as f64; 3]
    }
    fn run_receiver(c: &mut dyn Communicator) -> (Vec<(usize, u64, Vec<f64>)>, CommStats) {
        let mut got = Vec::new();
        // A probe for a message nobody sends misses without consuming.
        assert_eq!(c.try_recv(1, 99), None);
        // Complete the *last* tag each peer posts first: the transport
        // must buffer the earlier-tag arrivals (per-sender FIFO channels
        // guarantee they are already in, so the drain below can't block).
        let (idx, pay) = c.recv_any(&[(1, 3)]);
        assert_eq!(idx, 0);
        got.push((1, 3, pay));
        let (idx, pay) = c.recv_any(&[(2, 1)]);
        assert_eq!(idx, 0);
        got.push((2, 1, pay));
        // Drain the buffered rest: all present, so completion order is
        // exactly lowest request index first.
        let mut reqs: Vec<(usize, u64)> = vec![(1, 1), (1, 2), (2, 2), (2, 3)];
        while !reqs.is_empty() {
            let (idx, pay) = c.recv_any(&reqs);
            let (from, tag) = reqs.remove(idx);
            got.push((from, tag, pay));
        }
        c.end_round();
        (got, c.stats().clone())
    }

    // Threaded: real concurrent senders racing the receiver.
    let mut comms = thread_comms(3);
    let mut c2 = comms.pop().unwrap();
    let mut c1 = comms.pop().unwrap();
    let mut c0 = comms.pop().unwrap();
    let (thr_got, thr_stats) = std::thread::scope(|s| {
        s.spawn(move || {
            for tag in [1u64, 2, 3] {
                c1.send(0, tag, payload(1, tag));
            }
            c1.end_round();
        });
        s.spawn(move || {
            for tag in [3u64, 2, 1] {
                c2.send(0, tag, payload(2, tag));
            }
            c2.end_round();
        });
        run_receiver(&mut c0)
    });

    // Lockstep simulator: same traffic, sequential.
    let mut sims = sim_comms(3);
    for tag in [1u64, 2, 3] {
        sims[1].send(0, tag, payload(1, tag));
    }
    for tag in [3u64, 2, 1] {
        sims[2].send(0, tag, payload(2, tag));
    }
    let (sim_got, sim_stats) = run_receiver(&mut sims[0]);
    sims[1].end_round();
    sims[2].end_round();

    let expect: Vec<(usize, u64, Vec<f64>)> = [(1, 3), (2, 1), (1, 1), (1, 2), (2, 2), (2, 3)]
        .into_iter()
        .map(|(f, t)| (f, t, payload(f, t)))
        .collect();
    assert_eq!(thr_got, expect, "threaded completion order/payloads");
    assert_eq!(sim_got, expect, "sim completion order/payloads");
    assert_eq!(thr_stats, sim_stats, "receiver-side counters match across transports");
    assert_eq!(thr_stats.messages, 6);
    assert_eq!(thr_stats.bytes, 6 * 3 * 8);
}

/// Proptest-style invariant behind the async remainder's bitwise claim:
/// for random matrices/partitions, (1) `seg_rows` + `multi_rows` exactly
/// partition class `I_1`, and (2) advancing the per-peer segments in *any*
/// completion permutation (plus the multi-peer rows) is bitwise identical
/// to one contiguous row sweep — rows are independent under `spmv_range`.
#[test]
fn remainder_segment_permutations_are_bitwise_identical() {
    let mut rng = Rng::new(0xA57C);
    for case in 0..12 {
        let n = rng.range(60, 220);
        let a = gen::random_banded_sym(n, rng.range(3, 8), rng.range(4, 1 + n / 4), rng.next_u64());
        let np = rng.range(2, 6);
        let part = partition(&a, np, Method::Block);
        let d = DistMatrix::build(&a, &part);
        let p_m = rng.range(2, 5);
        let opts = DlbOptions { cache_bytes: 4 << 10, s_m: 50, async_remainder: true };
        let plan = dlb::plan(&d, p_m, &opts);
        for (r, pl) in plan.dist.ranks.iter().zip(&plan.ranks) {
            let (lo, hi) = pl.class_ranges[0];
            assert_eq!(pl.seg_rows.len(), r.recv.len(), "case {case}: one segment per peer");

            // (1) partition: every I_1 row in exactly one bucket
            let mut seen = vec![false; hi - lo];
            for rows in pl.seg_rows.iter().chain(std::iter::once(&pl.multi_rows)) {
                for &row in rows {
                    let i = row as usize;
                    assert!((lo..hi).contains(&i), "case {case}: row {i} outside I_1");
                    assert!(!seen[i - lo], "case {case}: row {i} in two buckets");
                    seen[i - lo] = true;
                }
            }
            assert!(seen.iter().all(|&b| b), "case {case}: I_1 not covered");

            // (2) any permutation of segment advances == contiguous sweep
            let vl = r.vec_len();
            let prev: Vec<f64> =
                (0..vl).map(|j| ((j * 31 + case) % 17) as f64 / 7.0 - 1.0).collect();
            let mut want = vec![0.0; vl];
            NativeBackend.spmv_range(&r.a, lo, hi, &prev, &mut want);

            let mut order: Vec<usize> = (0..pl.seg_rows.len()).collect();
            rng.shuffle(&mut order);
            let mut got = vec![0.0; vl];
            let mut rows_done = 0usize;
            for &j in &order {
                for (rlo, rhi) in dlb::contiguous_runs(&pl.seg_rows[j]) {
                    NativeBackend.spmv_range(&r.a, rlo, rhi, &prev, &mut got);
                    rows_done += rhi - rlo;
                }
            }
            for (rlo, rhi) in dlb::contiguous_runs(&pl.multi_rows) {
                NativeBackend.spmv_range(&r.a, rlo, rhi, &prev, &mut got);
                rows_done += rhi - rlo;
            }
            assert_eq!(rows_done, hi - lo, "case {case}: every row advanced once");
            for i in lo..hi {
                assert_eq!(
                    want[i].to_bits(),
                    got[i].to_bits(),
                    "case {case}: row {i} differs under permuted completion"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// SockComm: the process-per-rank socket transport, exercised in-process.
// `sock_comms` builds one connected endpoint per rank over real Unix-domain
// sockets in a temp dir; each rank then runs the same kernel functions the
// multi-process engine path runs. Results must be bitwise identical to the
// lockstep simulator. (True multi-process coverage — separate address
// spaces, launcher, rank death — lives in sock_proc.rs.)
// ---------------------------------------------------------------------------

/// Run `f(rank, comm)` per rank over a real socket mesh, one thread per
/// endpoint, in a unique temp dir removed afterwards.
fn sock_ranks<F>(n: usize, f: F) -> Vec<(RankRun, CommStats)>
where
    F: Fn(usize, dlb_mpk::exec::SockComm) -> (RankRun, CommStats) + Sync,
{
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "dlb-mpk-eqsock-{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let comms = sock_comms(&dir, n, Duration::from_secs(20)).expect("socket rendezvous");
    let f = &f;
    let outs = std::thread::scope(|s| {
        let joins: Vec<_> = comms
            .into_iter()
            .enumerate()
            .map(|(i, c)| s.spawn(move || f(i, c)))
            .collect();
        joins
            .into_iter()
            .map(|j| j.join().expect("rank thread panicked"))
            .collect()
    });
    let _ = std::fs::remove_dir_all(&dir);
    outs
}

/// The deterministic rank-ascending merge (mirrors the executors'
/// crate-internal `assemble`).
fn merge_outs(
    d: &DistMatrix,
    p_m: usize,
    outs: &[(RankRun, CommStats)],
) -> (Vec<Vec<f64>>, CommStats, usize) {
    let per_rank: Vec<CommStats> = outs.iter().map(|(_, s)| s.clone()).collect();
    let comm = merge_rank_stats(&per_rank);
    let flop_nnz = outs.iter().map(|(run, _)| run.flop_nnz).sum();
    let mut powers = vec![vec![0.0; d.n_global]; p_m];
    for (r, (run, _)) in d.ranks.iter().zip(outs) {
        for (pw, ys) in powers.iter_mut().zip(run.ys.iter().skip(1)) {
            for (l, &g) in r.owned.iter().enumerate() {
                pw[g] = ys[l];
            }
        }
    }
    (powers, comm, flop_nnz)
}

/// TRAD / CA / DLB (sync and async remainder) × inner pools of 1 and 2
/// threads over the socket transport: bitwise-identical powers, identical
/// merged `CommStats`, identical flop counts vs the sequential simulator.
#[test]
fn sim_and_sockets_agree_for_all_variants() {
    let a = gen::stencil_2d_5pt(13, 11);
    let x = test_vector(a.n_rows());
    let p_m = 3;
    for np in [2usize, 4] {
        let part = partition(&a, np, Method::Block);
        let d = DistMatrix::build(&a, &part);
        let xs = d.scatter(&x);
        // Sync-remainder DLB is the baseline for both remainder modes (the
        // async pipeline's bitwise claim, cf. async_remainder_matches_sync).
        let dlb_base = {
            let opts = DlbOptions { cache_bytes: 8 << 10, s_m: 50, async_remainder: false };
            dlb::execute(&dlb::plan(&d, p_m, &opts), &x, &mut NativeBackend)
        };
        for inner_k in [1usize, 2] {
            // TRAD
            let sim = trad_mpk(&d, &x, p_m, &mut NativeBackend);
            let outs = sock_ranks(np, |i, mut c| {
                let mut backend = NativeBackend;
                let mut inner = InnerExec::new(inner_k, i, &BackendSpec::Native, None);
                let run = trad::trad_rank(
                    &d.ranks[i],
                    &xs[i],
                    None,
                    p_m,
                    Recurrence::Power,
                    &mut c,
                    &mut backend,
                    &mut inner,
                );
                let st = c.stats().clone();
                (run, st)
            });
            let (powers, comm, flop) = merge_outs(&d, p_m, &outs);
            let tag = format!("sock trad np={np} inner={inner_k}");
            assert_bitwise(&sim.powers, &powers, &tag);
            assert_eq!(sim.comm, comm, "{tag} stats");
            assert_eq!(sim.flop_nnz, flop, "{tag} flops");

            // DLB, sync and async remainder
            for async_rem in [false, true] {
                let opts =
                    DlbOptions { cache_bytes: 8 << 10, s_m: 50, async_remainder: async_rem };
                let plan = dlb::plan(&d, p_m, &opts);
                let outs = sock_ranks(np, |i, mut c| {
                    let mut backend = NativeBackend;
                    let mut inner = InnerExec::new(inner_k, i, &BackendSpec::Native, None);
                    let run = dlb::dlb_rank(
                        &d.ranks[i],
                        &plan.ranks[i],
                        p_m,
                        &xs[i],
                        None,
                        Recurrence::Power,
                        &mut c,
                        &mut backend,
                        &mut inner,
                    );
                    let st = c.stats().clone();
                    (run, st)
                });
                let (powers, comm, flop) = merge_outs(&d, p_m, &outs);
                let tag = format!("sock dlb np={np} inner={inner_k} async={async_rem}");
                assert_bitwise(&dlb_base.powers, &powers, &tag);
                assert_eq!(dlb_base.comm, comm, "{tag} stats");
                assert_eq!(dlb_base.flop_nnz, flop, "{tag} flops");
            }

            // CA
            let sim = ca::ca_mpk_with(&a, &d, &x, p_m);
            let plan = ca::ca_exec_plan(&a, &d, p_m);
            let outs = sock_ranks(np, |i, mut c| {
                let mut inner = InnerExec::new(inner_k, i, &BackendSpec::Native, None);
                let run = ca::ca_rank(
                    &a,
                    &d.ranks[i],
                    &plan.sends[i],
                    &plan.recvs[i],
                    &plan.ext[i],
                    &xs[i],
                    p_m,
                    &mut c,
                    &mut inner,
                );
                let st = c.stats().clone();
                (run, st)
            });
            let (powers, comm, flop) = merge_outs(&d, p_m, &outs);
            let tag = format!("sock ca np={np} inner={inner_k}");
            assert_bitwise(&sim.result.powers, &powers, &tag);
            assert_eq!(sim.result.comm, comm, "{tag} stats");
            assert_eq!(sim.result.flop_nnz, flop, "{tag} flops");
        }
    }
}

/// Chebyshev recurrence (`x_m1 = Some`) over sockets: the three-term
/// update must also be transport-invariant.
#[test]
fn sim_and_sockets_agree_on_chebyshev() {
    use dlb_mpk::mpk::trad::trad_recurrence;
    let a = gen::stencil_2d_5pt(12, 9);
    let n = a.n_rows();
    let x = test_vector(n);
    let xm1: Vec<f64> = (0..n).map(|i| ((i * 13 % 29) as f64) / 29.0).collect();
    let np = 3;
    let part = partition(&a, np, Method::Block);
    let d = DistMatrix::build(&a, &part);
    let xs = d.scatter(&x);
    let xm1s = d.scatter(&xm1);
    let p_m = 3;
    let sim = trad_recurrence(&d, &x, Some(&xm1), p_m, Recurrence::Chebyshev, &mut NativeBackend);
    let outs = sock_ranks(np, |i, mut c| {
        let mut backend = NativeBackend;
        let mut inner = InnerExec::serial();
        let run = trad::trad_rank(
            &d.ranks[i],
            &xs[i],
            Some(&xm1s[i]),
            p_m,
            Recurrence::Chebyshev,
            &mut c,
            &mut backend,
            &mut inner,
        );
        let st = c.stats().clone();
        (run, st)
    });
    let (powers, comm, _) = merge_outs(&d, p_m, &outs);
    assert_bitwise(&sim.powers, &powers, "sock cheb trad");
    assert_eq!(sim.comm, comm, "sock cheb trad stats");
}
