//! Cross-variant equivalence on structured matrices (the paper's validation
//! contract: all MPK variants compute identical powers; DLB adds no
//! communication and no redundant flops).

use dlb_mpk::distsim::DistMatrix;
use dlb_mpk::matrix::anderson::{anderson, AndersonConfig};
use dlb_mpk::matrix::gen;
use dlb_mpk::mpk::dlb::{self, DlbOptions, Recurrence};
use dlb_mpk::mpk::trad::trad_recurrence;
use dlb_mpk::mpk::{ca, trad_mpk, NativeBackend};
use dlb_mpk::partition::{partition, Method};

fn assert_close(a: &[Vec<f64>], b: &[Vec<f64>], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: power count");
    for (p, (u, v)) in a.iter().zip(b).enumerate() {
        for (r, (x, y)) in u.iter().zip(v).enumerate() {
            assert!(
                (x - y).abs() < 1e-9 * (1.0 + y.abs()),
                "{tag}: power {} row {r}: {x} vs {y}",
                p + 1
            );
        }
    }
}

#[test]
fn all_variants_all_partitioners_stencil() {
    let a = gen::stencil_2d_5pt(20, 17);
    let x: Vec<f64> = (0..a.n_rows()).map(|i| ((i % 13) as f64 - 6.0) / 7.0).collect();
    for method in [Method::Block, Method::GreedyGrow, Method::RecursiveBisect] {
        for np in [1, 2, 5] {
            let part = partition(&a, np, method);
            let d = DistMatrix::build(&a, &part);
            let p_m = 4;
            let want = trad_mpk(&d, &x, p_m, &mut NativeBackend);
            let dlb_out = dlb::dlb_mpk(
                &d, &x, p_m,
                &DlbOptions { cache_bytes: 4 << 10, s_m: 20, async_remainder: false },
                &mut NativeBackend,
            );
            let ca_out = ca::ca_mpk_with(&a, &d, &x, p_m);
            let tag = format!("{method:?}/np={np}");
            assert_close(&dlb_out.result.powers, &want.powers, &tag);
            assert_close(&ca_out.result.powers, &want.powers, &tag);
            assert_eq!(dlb_out.result.comm.bytes, want.comm.bytes, "{tag}: comm");
            assert_eq!(dlb_out.result.flop_nnz, want.flop_nnz, "{tag}: flops");
        }
    }
}

#[test]
fn anderson_aniso_high_power() {
    let cfg = AndersonConfig { lx: 24, ly: 6, lz: 6, w: 2.0, t: 1.0, t_perp: 0.01, seed: 3 };
    let mut h = anderson(&cfg);
    h.scale(1.0 / h.inf_norm()); // keep powers bounded at p_m = 10
    let x: Vec<f64> = (0..h.n_rows()).map(|i| (i as f64 * 0.1).sin()).collect();
    let part = partition(&h, 6, Method::RecursiveBisect);
    let d = DistMatrix::build(&h, &part);
    let p_m = 10;
    let want = trad_mpk(&d, &x, p_m, &mut NativeBackend);
    let got = dlb::dlb_mpk(&d, &x, p_m, &DlbOptions { cache_bytes: 8 << 10, s_m: 50, async_remainder: false }, &mut NativeBackend);
    assert_close(&got.result.powers, &want.powers, "anderson p10");
}

#[test]
fn chebyshev_recurrence_dlb_equals_trad() {
    let a = gen::random_banded_sym(400, 10, 30, 8);
    let x: Vec<f64> = (0..400).map(|i| ((i * 31 % 97) as f64) / 97.0).collect();
    let xm1: Vec<f64> = (0..400).map(|i| ((i * 17 % 89) as f64) / 89.0).collect();
    for np in [1, 3] {
        let part = partition(&a, np, Method::Block);
        let d = DistMatrix::build(&a, &part);
        let p_m = 5;
        let want = trad_recurrence(&d, &x, Some(&xm1), p_m, Recurrence::Chebyshev, &mut NativeBackend);
        let plan = dlb::plan(&d, p_m, &DlbOptions { cache_bytes: 2 << 10, s_m: 50, async_remainder: false });
        let got = dlb::execute_recurrence(&plan, &x, Some(&xm1), Recurrence::Chebyshev, &mut NativeBackend);
        assert_close(&got.powers, &want.powers, &format!("cheb np={np}"));
        assert_eq!(got.comm.bytes, want.comm.bytes);
    }
}

#[test]
fn chebyshev_windup_without_vm1() {
    let a = gen::tridiag(100);
    let x: Vec<f64> = (0..100).map(|i| i as f64 / 100.0).collect();
    let part = partition(&a, 2, Method::Block);
    let d = DistMatrix::build(&a, &part);
    let want = trad_recurrence(&d, &x, None, 3, Recurrence::Chebyshev, &mut NativeBackend);
    let plan = dlb::plan(&d, 3, &DlbOptions { cache_bytes: 1, s_m: 50, async_remainder: false });
    let got = dlb::execute_recurrence(&plan, &x, None, Recurrence::Chebyshev, &mut NativeBackend);
    assert_close(&got.powers, &want.powers, "windup");
    // wind-up step 1 is plain SpMV: y1 = A x
    let mut y1 = vec![0.0; 100];
    a.spmv(&x, &mut y1);
    for (u, v) in got.powers[0].iter().zip(&y1) {
        assert!((u - v).abs() < 1e-12);
    }
}

#[test]
fn disconnected_matrix_all_variants() {
    // two disjoint stencil blocks — exercises BFS restarts and empty halos
    let b1 = gen::stencil_2d_5pt(8, 8);
    let mut coo = dlb_mpk::matrix::CooMatrix::new(128, 128);
    for r in 0..64 {
        for (c, v) in b1.row_cols(r).iter().zip(b1.row_vals(r)) {
            coo.push(r, *c as usize, *v);
            coo.push(r + 64, *c as usize + 64, *v);
        }
    }
    let a = coo.to_csr();
    let x = vec![1.0; 128];
    for np in [1, 2, 3] {
        let part = partition(&a, np, Method::GreedyGrow);
        let d = DistMatrix::build(&a, &part);
        let want = trad_mpk(&d, &x, 3, &mut NativeBackend);
        let got = dlb::dlb_mpk(&d, &x, 3, &DlbOptions { cache_bytes: 1 << 10, s_m: 50, async_remainder: false }, &mut NativeBackend);
        assert_close(&got.result.powers, &want.powers, &format!("disconnected np={np}"));
    }
}

#[test]
fn pm_one_degenerates_to_single_spmv() {
    let a = gen::stencil_2d_5pt(10, 10);
    let x = vec![1.0; 100];
    let part = partition(&a, 4, Method::Block);
    let d = DistMatrix::build(&a, &part);
    let want = trad_mpk(&d, &x, 1, &mut NativeBackend);
    let got = dlb::dlb_mpk(&d, &x, 1, &DlbOptions::default(), &mut NativeBackend);
    assert_close(&got.result.powers, &want.powers, "pm=1");
    let mut y = vec![0.0; 100];
    a.spmv(&x, &mut y);
    for (u, v) in got.result.powers[0].iter().zip(&y) {
        assert!((u - v).abs() < 1e-12);
    }
}
