//! Human-readable run reports (the CLI/bench output format).

use crate::distsim::CommStats;
use crate::perf::Timed;

#[derive(Clone, Debug)]
pub struct Report {
    pub variant: String,
    pub n_rows: usize,
    pub nnz: usize,
    pub crs_mib: usize,
    pub n_ranks: usize,
    pub p_m: usize,
    pub time: Timed,
    pub gflops: f64,
    pub comm: CommStats,
    /// Per-sweep compute time overlapped with in-flight receives
    /// (trace-derived; `None` when tracing was off or the variant has no
    /// overlap accounting). Read next to `wait_ms`: overlap is the part of
    /// the wait the async remainder hid behind compute.
    pub overlap_ms: Option<f64>,
    pub o_mpi: f64,
    pub o_dlb: f64,
    pub validated: Option<bool>,
}

impl Report {
    pub fn print_header() {
        println!(
            "{:<10} {:>9} {:>10} {:>8} {:>5} {:>4} {:>9} {:>8} {:>9} {:>8} {:>9} {:>8} {:>7} \
             {:>7} {:>5}",
            "variant", "rows", "nnz", "MiB", "ranks", "p_m", "median_s", "Gflop/s", "comm_MiB",
            "maxmsg_B", "wait_ms", "ovlp_ms", "O_MPI", "O_DLB", "ok"
        );
    }

    pub fn print_row(&self) {
        println!(
            "{:<10} {:>9} {:>10} {:>8} {:>5} {:>4} {:>9.4} {:>8.2} {:>9.2} {:>8} {:>9.3} {:>8} \
             {:>7.4} {:>7.4} {:>5}",
            self.variant,
            self.n_rows,
            self.nnz,
            self.crs_mib,
            self.n_ranks,
            self.p_m,
            self.time.median_s,
            self.gflops,
            self.comm.bytes as f64 / (1 << 20) as f64,
            self.comm.max_message_bytes,
            self.comm.total_wait_ns() as f64 / 1e6,
            match self.overlap_ms {
                Some(v) => format!("{v:.3}"),
                None => "-".to_string(),
            },
            self.o_mpi,
            self.o_dlb,
            match self.validated {
                Some(true) => "yes",
                Some(false) => "NO",
                None => "-",
            }
        );
    }
}
