//! The experiment pipeline: matrix → partition → distribute → engine →
//! report. All variant/executor dispatch goes through
//! [`crate::engine::MpkEngine`] — one prepared session per variant, timed
//! over repeated sweeps (which is exactly the engine's design point:
//! setup once, sweep many).

use std::sync::Arc;

use anyhow::Result;

use crate::distsim::DistMatrix;
use crate::engine::{BackendSpec, EngineConfig, MpkEngine, Variant};
use crate::mpk::dlb::{DlbOptions, Recurrence};
use crate::mpk::MpkResult;
use crate::partition::partition;
use crate::perf::{median_time, roofline};
use crate::util::mib;

use super::config::RunConfig;
use super::report::Report;

/// Everything a run produces: per-variant reports plus raw results.
pub struct RunOutput {
    pub reports: Vec<Report>,
    pub trad: MpkResult,
    pub dlb: MpkResult,
    pub dlb_overhead: f64,
}

/// Execute TRAD and DLB (and validate) per `cfg`, timing both under the
/// configured executor (`sim` counts exactly; `threads` measures real
/// parallel wall-clock over the engine's persistent rank pool).
pub fn run(cfg: &RunConfig) -> Result<RunOutput> {
    let a = cfg.matrix.build()?;
    // `threads(n)` with nonzero n sets the rank count directly
    let n_ranks = cfg.executor.ranks(cfg.n_ranks);
    let part = partition(&a, n_ranks, cfg.partitioner);
    // One shared matrix: the TRAD engine reuses this Arc outright, the DLB
    // engine derives its own level-permuted clone from it.
    let dist = Arc::new(DistMatrix::build(&a, &part));
    let x: Vec<f64> = (0..a.n_rows())
        .map(|i| 1.0 + ((i * 2654435761) % 1000) as f64 / 1000.0)
        .collect();

    let opts = DlbOptions {
        cache_bytes: cfg.cache_bytes,
        s_m: cfg.s_m,
        async_remainder: cfg.async_remainder,
    };
    let mk_cfg = |variant: Variant| EngineConfig {
        variant,
        executor: cfg.executor,
        backend: BackendSpec::Native,
        trace: false,
        inner_threads: cfg.inner_threads,
        ..EngineConfig::default()
    };
    let mut trad_eng = MpkEngine::from_shared(dist.clone(), cfg.p_m, &mk_cfg(Variant::Trad))?;
    // Overlap accounting replays spans, so the DLB engine traces whenever
    // the pipelined remainder is on (the `ovlp_ms` report column).
    let mut dlb_cfg = mk_cfg(Variant::Dlb(opts));
    dlb_cfg.trace = cfg.async_remainder;
    let mut dlb_eng = MpkEngine::from_shared(dist.clone(), cfg.p_m, &dlb_cfg)?;
    let o_dlb = dlb_eng.dlb_overhead().expect("DLB engine has a primary plan");
    let o_mpi = dist.mpi_overhead();

    // timed runs (sweep-many over the prepared engines)
    let mut trad_out = None;
    let t_trad = median_time(cfg.reps, || {
        trad_out = Some(trad_eng.sweep(&x, None, Recurrence::Power));
    });
    let trad_res = trad_out.unwrap();

    let mut dlb_out = None;
    let t_dlb = median_time(cfg.reps, || {
        dlb_out = Some(dlb_eng.sweep(&x, None, Recurrence::Power));
    });
    let dlb_res = dlb_out.unwrap();

    let validated = if cfg.validate {
        Some(equal(&trad_res, &dlb_res))
    } else {
        None
    };
    // Per-sweep average: the trace accumulates over every sweep run so far.
    let dlb_overlap_ms = dlb_eng.metrics().map(|m| {
        m.total_overlap_ns as f64 / 1e6 / dlb_eng.sweeps_run().max(1) as f64
    });

    let label = exec_label(cfg);
    let mk = |name: &str,
              res: &MpkResult,
              t: crate::perf::Timed,
              o_dlb: f64,
              validated,
              overlap_ms| Report {
        variant: format!("{name}@{label}"),
        n_rows: a.n_rows(),
        nnz: a.nnz(),
        crs_mib: mib(a.crs_bytes()),
        n_ranks,
        p_m: cfg.p_m,
        time: t,
        gflops: roofline::gflops(res.flop_nnz, t.median_s),
        comm: res.comm.clone(),
        overlap_ms,
        o_mpi,
        o_dlb,
        validated,
    };

    let reports = vec![
        mk("trad", &trad_res, t_trad, 0.0, None, None),
        mk("dlb", &dlb_res, t_dlb, o_dlb, validated, dlb_overlap_ms),
    ];
    Ok(RunOutput { reports, trad: trad_res, dlb: dlb_res, dlb_overhead: o_dlb })
}

/// Also run CA-MPK and report its overheads (used by `fig5` and the CLI),
/// honoring the configured executor like [`run`] does.
pub fn run_ca(cfg: &RunConfig) -> Result<(Report, crate::mpk::CaOverheads)> {
    let a = cfg.matrix.build()?;
    let n_ranks = cfg.executor.ranks(cfg.n_ranks);
    let part = partition(&a, n_ranks, cfg.partitioner);
    let dist = Arc::new(DistMatrix::build(&a, &part));
    let x: Vec<f64> = (0..a.n_rows()).map(|i| (i % 7) as f64).collect();

    let eng_cfg = EngineConfig {
        variant: Variant::Ca,
        executor: cfg.executor,
        backend: BackendSpec::Native,
        trace: false,
        inner_threads: cfg.inner_threads,
        ..EngineConfig::default()
    };
    let mut eng = MpkEngine::from_shared(dist.clone(), cfg.p_m, &eng_cfg)?;
    let overheads = eng.ca_overheads().expect("CA engine has a primary plan");
    let mut out = None;
    let t = median_time(cfg.reps, || {
        out = Some(eng.sweep(&x, None, Recurrence::Power));
    });
    let res = out.unwrap();
    let rep = Report {
        variant: format!("ca@{}", exec_label(cfg)),
        n_rows: a.n_rows(),
        nnz: a.nnz(),
        crs_mib: mib(a.crs_bytes()),
        n_ranks,
        p_m: cfg.p_m,
        time: t,
        gflops: roofline::gflops(res.flop_nnz, t.median_s),
        comm: res.comm.clone(),
        overlap_ms: None,
        o_mpi: dist.mpi_overhead(),
        o_dlb: 0.0,
        validated: None,
    };
    Ok((rep, overheads))
}

/// Executor label for report variants, with the within-rank thread count
/// appended when the inner pool is active (`thr` → `thrx2`). The default
/// `inner_threads == 1` keeps the plain label, so existing report shapes
/// (`trad@thr`, `ca@sim`, …) are unchanged.
fn exec_label(cfg: &RunConfig) -> String {
    if cfg.inner_threads > 1 {
        format!("{}x{}", cfg.executor.label(), cfg.inner_threads)
    } else {
        cfg.executor.label().to_string()
    }
}

fn equal(a: &MpkResult, b: &MpkResult) -> bool {
    a.powers.len() == b.powers.len()
        && a.powers.iter().zip(&b.powers).all(|(u, v)| {
            u.iter()
                .zip(v)
                .all(|(x, y)| (x - y).abs() <= 1e-9 * (1.0 + y.abs()))
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::MatrixSpec;
    use crate::exec::ExecutorKind;

    #[test]
    fn pipeline_runs_and_validates() {
        let cfg = RunConfig {
            matrix: MatrixSpec::Stencil2D { nx: 24, ny: 24 },
            n_ranks: 3,
            p_m: 3,
            reps: 1,
            cache_bytes: 64 << 10,
            ..Default::default()
        };
        let out = run(&cfg).unwrap();
        assert_eq!(out.reports.len(), 2);
        assert_eq!(out.reports[1].validated, Some(true));
        assert!(out.dlb_overhead >= 0.0);
    }

    #[test]
    fn pipeline_threaded_executor_matches_sim() {
        let cfg = RunConfig {
            matrix: MatrixSpec::Stencil2D { nx: 20, ny: 20 },
            n_ranks: 4,
            p_m: 3,
            reps: 1,
            cache_bytes: 32 << 10,
            executor: ExecutorKind::Threads { n: 0 },
            ..Default::default()
        };
        let thr = run(&cfg).unwrap();
        assert_eq!(thr.reports[1].validated, Some(true));
        assert_eq!(thr.reports[0].variant, "trad@thr");
        let sim = run(&RunConfig { executor: ExecutorKind::Sim, ..cfg }).unwrap();
        assert_eq!(thr.trad.powers, sim.trad.powers);
        assert_eq!(thr.dlb.powers, sim.dlb.powers);
        assert_eq!(thr.trad.comm, sim.trad.comm);
        assert_eq!(thr.dlb.comm, sim.dlb.comm);
    }

    #[test]
    fn threads_n_overrides_rank_count() {
        let cfg = RunConfig {
            matrix: MatrixSpec::Stencil2D { nx: 16, ny: 16 },
            n_ranks: 1,
            p_m: 2,
            reps: 1,
            executor: ExecutorKind::Threads { n: 3 },
            ..Default::default()
        };
        let out = run(&cfg).unwrap();
        assert_eq!(out.reports[0].n_ranks, 3);
        assert_eq!(out.reports[1].validated, Some(true));
    }

    #[test]
    fn inner_threads_label_and_results_match_serial() {
        let cfg = RunConfig {
            matrix: MatrixSpec::Stencil2D { nx: 20, ny: 20 },
            n_ranks: 2,
            p_m: 3,
            reps: 1,
            cache_bytes: 32 << 10,
            executor: ExecutorKind::Threads { n: 0 },
            inner_threads: 2,
            ..Default::default()
        };
        let par = run(&cfg).unwrap();
        assert_eq!(par.reports[0].variant, "trad@thrx2");
        assert_eq!(par.reports[1].variant, "dlb@thrx2");
        assert_eq!(par.reports[1].validated, Some(true));
        let ser = run(&RunConfig { inner_threads: 1, ..cfg }).unwrap();
        assert_eq!(ser.reports[0].variant, "trad@thr");
        assert_eq!(par.trad.powers, ser.trad.powers);
        assert_eq!(par.dlb.powers, ser.dlb.powers);
        assert_eq!(par.trad.comm, ser.trad.comm);
        assert_eq!(par.dlb.comm, ser.dlb.comm);
    }

    #[test]
    fn pipeline_async_remainder_validates_and_reports_overlap() {
        let cfg = RunConfig {
            matrix: MatrixSpec::Stencil2D { nx: 20, ny: 20 },
            n_ranks: 3,
            p_m: 3,
            reps: 1,
            cache_bytes: 32 << 10,
            async_remainder: true,
            ..Default::default()
        };
        let out = run(&cfg).unwrap();
        assert_eq!(out.reports[1].validated, Some(true));
        assert!(out.reports[0].overlap_ms.is_none(), "TRAD has no overlap accounting");
        assert!(out.reports[1].overlap_ms.is_some(), "async DLB run is traced");
        let sync = run(&RunConfig { async_remainder: false, ..cfg }).unwrap();
        assert_eq!(out.dlb.powers, sync.dlb.powers, "pipelining must be bitwise neutral");
        assert_eq!(out.dlb.comm, sync.dlb.comm);
        assert!(sync.reports[1].overlap_ms.is_none(), "sync run is untraced");
    }

    #[test]
    fn ca_pipeline_reports_overheads() {
        let cfg = RunConfig {
            matrix: MatrixSpec::Stencil2D { nx: 16, ny: 16 },
            n_ranks: 2,
            p_m: 3,
            reps: 1,
            ..Default::default()
        };
        let (rep, ov) = run_ca(&cfg).unwrap();
        assert_eq!(rep.variant, "ca@sim");
        assert!(ov.extra_halo > 0);
    }

    #[test]
    fn ca_pipeline_honors_threaded_executor() {
        let cfg = RunConfig {
            matrix: MatrixSpec::Stencil2D { nx: 16, ny: 16 },
            n_ranks: 1,
            p_m: 3,
            reps: 1,
            executor: ExecutorKind::Threads { n: 2 },
            ..Default::default()
        };
        let (rep, ov) = run_ca(&cfg).unwrap();
        assert_eq!(rep.variant, "ca@thr");
        assert_eq!(rep.n_ranks, 2);
        assert!(ov.extra_halo > 0);
        // same counters as the sequential path on the same partition
        let (sim_rep, _) = run_ca(&RunConfig {
            n_ranks: 2,
            executor: ExecutorKind::Sim,
            ..cfg
        })
        .unwrap();
        assert_eq!(rep.comm, sim_rep.comm);
    }
}
