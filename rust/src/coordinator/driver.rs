//! The experiment pipeline: matrix → partition → distribute → MPK → report.

use anyhow::Result;

use crate::distsim::DistMatrix;
use crate::mpk::dlb::{self, DlbOptions};
use crate::mpk::{ca, trad_mpk, MpkResult, NativeBackend};
use crate::partition::partition;
use crate::perf::{median_time, roofline};
use crate::util::mib;

use super::config::RunConfig;
use super::report::Report;

/// Everything a run produces: per-variant reports plus raw results.
pub struct RunOutput {
    pub reports: Vec<Report>,
    pub trad: MpkResult,
    pub dlb: MpkResult,
    pub dlb_overhead: f64,
}

/// Execute TRAD and DLB (and validate) per `cfg`, timing both.
pub fn run(cfg: &RunConfig) -> Result<RunOutput> {
    let a = cfg.matrix.build()?;
    let part = partition(&a, cfg.n_ranks, cfg.partitioner);
    let dist = DistMatrix::build(&a, &part);
    let x: Vec<f64> = (0..a.n_rows())
        .map(|i| 1.0 + ((i * 2654435761) % 1000) as f64 / 1000.0)
        .collect();

    let opts = DlbOptions { cache_bytes: cfg.cache_bytes, s_m: cfg.s_m };
    let plan = dlb::plan(&dist, cfg.p_m, &opts);
    let o_dlb = crate::mpk::overheads::dlb_overhead_from_plan(&plan);
    let o_mpi = dist.mpi_overhead();

    // timed runs
    let mut trad_out = None;
    let t_trad = median_time(cfg.reps, || {
        trad_out = Some(trad_mpk(&dist, &x, cfg.p_m, &mut NativeBackend));
    });
    let trad_res = trad_out.unwrap();

    let mut dlb_out = None;
    let t_dlb = median_time(cfg.reps, || {
        dlb_out = Some(dlb::execute(&plan, &x, &mut NativeBackend));
    });
    let dlb_res = dlb_out.unwrap();

    let validated = if cfg.validate {
        Some(equal(&trad_res, &dlb_res))
    } else {
        None
    };

    let mk = |name: &str, res: &MpkResult, t: crate::perf::Timed, o_dlb: f64, validated| Report {
        variant: name.to_string(),
        n_rows: a.n_rows(),
        nnz: a.nnz(),
        crs_mib: mib(a.crs_bytes()),
        n_ranks: cfg.n_ranks,
        p_m: cfg.p_m,
        time: t,
        gflops: roofline::gflops(res.flop_nnz, t.median_s),
        comm: res.comm.clone(),
        o_mpi,
        o_dlb,
        validated,
    };

    let reports = vec![
        mk("trad", &trad_res, t_trad, 0.0, None),
        mk("dlb", &dlb_res, t_dlb, o_dlb, validated),
    ];
    Ok(RunOutput { reports, trad: trad_res, dlb: dlb_res, dlb_overhead: o_dlb })
}

/// Also run CA-MPK and report its overheads (used by `fig5` and the CLI).
pub fn run_ca(cfg: &RunConfig) -> Result<(Report, ca::CaOverheads)> {
    let a = cfg.matrix.build()?;
    let part = partition(&a, cfg.n_ranks, cfg.partitioner);
    let dist = DistMatrix::build(&a, &part);
    let x: Vec<f64> = (0..a.n_rows()).map(|i| (i % 7) as f64).collect();
    let mut out = None;
    let t = median_time(cfg.reps, || {
        out = Some(ca::ca_mpk_with(&a, &dist, &x, cfg.p_m));
    });
    let o = out.unwrap();
    let rep = Report {
        variant: "ca".into(),
        n_rows: a.n_rows(),
        nnz: a.nnz(),
        crs_mib: mib(a.crs_bytes()),
        n_ranks: cfg.n_ranks,
        p_m: cfg.p_m,
        time: t,
        gflops: roofline::gflops(o.result.flop_nnz, t.median_s),
        comm: o.result.comm.clone(),
        o_mpi: dist.mpi_overhead(),
        o_dlb: 0.0,
        validated: None,
    };
    Ok((rep, o.overheads))
}

fn equal(a: &MpkResult, b: &MpkResult) -> bool {
    a.powers.len() == b.powers.len()
        && a.powers.iter().zip(&b.powers).all(|(u, v)| {
            u.iter()
                .zip(v)
                .all(|(x, y)| (x - y).abs() <= 1e-9 * (1.0 + y.abs()))
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::MatrixSpec;

    #[test]
    fn pipeline_runs_and_validates() {
        let cfg = RunConfig {
            matrix: MatrixSpec::Stencil2D { nx: 24, ny: 24 },
            n_ranks: 3,
            p_m: 3,
            reps: 1,
            cache_bytes: 64 << 10,
            ..Default::default()
        };
        let out = run(&cfg).unwrap();
        assert_eq!(out.reports.len(), 2);
        assert_eq!(out.reports[1].validated, Some(true));
        assert!(out.dlb_overhead >= 0.0);
    }

    #[test]
    fn ca_pipeline_reports_overheads() {
        let cfg = RunConfig {
            matrix: MatrixSpec::Stencil2D { nx: 16, ny: 16 },
            n_ranks: 2,
            p_m: 3,
            reps: 1,
            ..Default::default()
        };
        let (rep, ov) = run_ca(&cfg).unwrap();
        assert_eq!(rep.variant, "ca");
        assert!(ov.extra_halo > 0);
    }
}
