//! Top-level drivers: configuration, pipeline wiring, reporting.
//!
//! The coordinator owns the full experiment pipeline the paper runs:
//! generate/load matrix → partition → build the distributed matrix →
//! plan + execute an MPK variant → validate → report performance and
//! overheads. The CLI (`rust/src/main.rs`) and all benches are thin
//! wrappers over this module.

pub mod config;
pub mod driver;
pub mod report;

pub use config::{MatrixSpec, RunConfig};
pub use driver::{run, RunOutput};
pub use report::Report;
