//! Run configuration (the paper's tunables in one place).

use crate::exec::ExecutorKind;
use crate::partition::Method;

/// Which matrix to run on.
#[derive(Clone, Debug)]
pub enum MatrixSpec {
    /// 2D 5-point stencil `nx × ny`.
    Stencil2D { nx: usize, ny: usize },
    /// 3D 7-point stencil.
    Stencil3D { nx: usize, ny: usize, nz: usize },
    /// Synthetic banded FEM-like matrix.
    Banded { n: usize, nnzr: usize, band: usize, seed: u64 },
    /// Anderson Hamiltonian (isotropic).
    Anderson { l: usize, w: f64, seed: u64 },
    /// Table-4 suite analogue by name (e.g. "Serena-s") at `scale`.
    Suite { name: String, scale: f64 },
    /// MatrixMarket file.
    File { path: std::path::PathBuf },
}

impl MatrixSpec {
    pub fn build(&self) -> anyhow::Result<crate::matrix::CsrMatrix> {
        use crate::matrix::gen;
        Ok(match self {
            Self::Stencil2D { nx, ny } => gen::stencil_2d_5pt(*nx, *ny),
            Self::Stencil3D { nx, ny, nz } => gen::stencil_3d_7pt(*nx, *ny, *nz),
            Self::Banded { n, nnzr, band, seed } => gen::random_banded_sym(*n, *nnzr, *band, *seed),
            Self::Anderson { l, w, seed } => crate::matrix::anderson::anderson(
                &crate::matrix::anderson::AndersonConfig::isotropic(*l, *w, *seed),
            ),
            Self::Suite { name, scale } => {
                let entry = gen::suite()
                    .into_iter()
                    .find(|e| e.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown suite matrix {name}"))?;
                (entry.build)(*scale)
            }
            Self::File { path } => crate::matrix::mm::read_matrix_market(path)?,
        })
    }
}

/// Full run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub matrix: MatrixSpec,
    pub n_ranks: usize,
    pub partitioner: Method,
    pub p_m: usize,
    /// Cache budget C for DLB (bytes).
    pub cache_bytes: usize,
    /// RACE recursion cap s_m.
    pub s_m: usize,
    /// Timing repetitions (median reported, paper §6.1.2).
    pub reps: usize,
    /// Validate DLB/CA against TRAD.
    pub validate: bool,
    /// How to execute the kernels: `sim` (sequential counting simulator)
    /// or `threads`/`threads(n)` (one OS thread per rank, measured
    /// wall-clock; a nonzero `n` overrides [`RunConfig::n_ranks`]).
    pub executor: ExecutorKind,
    /// Within-rank worker threads (`crate::inner`): 1 = serial rank
    /// kernels, `k >= 2` row-splits each rank's compute across `k`
    /// participants with bitwise-identical results.
    pub inner_threads: usize,
    /// Pipeline DLB's phase-3 remainder rounds: complete halo receives in
    /// arrival order and overlap the per-segment class-`I_1` advances with
    /// the messages still in flight (bitwise identical; see
    /// [`crate::mpk::dlb::DlbOptions::async_remainder`]).
    pub async_remainder: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            matrix: MatrixSpec::Stencil2D { nx: 64, ny: 64 },
            n_ranks: 1,
            partitioner: Method::RecursiveBisect,
            p_m: 4,
            cache_bytes: 16 << 20,
            s_m: 50,
            reps: 5,
            validate: true,
            executor: ExecutorKind::Sim,
            inner_threads: 1,
            async_remainder: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_build() {
        assert_eq!(MatrixSpec::Stencil2D { nx: 4, ny: 3 }.build().unwrap().n_rows(), 12);
        assert_eq!(
            MatrixSpec::Anderson { l: 4, w: 1.0, seed: 1 }.build().unwrap().n_rows(),
            64
        );
        assert!(MatrixSpec::Suite { name: "Serena-s".into(), scale: 0.01 }.build().is_ok());
        assert!(MatrixSpec::Suite { name: "nope".into(), scale: 1.0 }.build().is_err());
    }
}
