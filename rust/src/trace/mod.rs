//! Per-rank span tracing + metrics — the observability layer under
//! [`crate::engine::MpkEngine`].
//!
//! The paper's argument is about *where time goes inside a power sweep*:
//! compute on the cache-blocked inner levels vs. waiting on halo exchanges
//! in the remainder rounds (§5–§6, Fig. 9/10). Aggregate [`CommStats`]
//! counters cannot show that, so this module records rank-level timelines:
//!
//! * [`RankRecorder`] — one per rank, a preallocated event buffer with
//!   span begin/end (monotonic nanosecond timestamps) and named counters.
//!   The **disabled** recorder is the default everywhere and its hot-path
//!   methods are a branch on one bool: no clock read, no allocation.
//! * [`Span`] — the closed vocabulary of instrumented regions:
//!   `dlb.wavefront(level)`, `dlb.remainder(round, class)`, `ca.exchange`/
//!   `ca.promote`, `trad.spmv(power)`, `comm.send/recv/wait`, and the rank
//!   pool's `job.dispatch`/`job.park`.
//! * [`TraceSession`] — engine-owned collection of every rank's events
//!   against one shared epoch, with two exporters: Chrome Trace Event
//!   Format JSON ([`TraceSession::chrome_trace_json`], loadable in
//!   `chrome://tracing` or <https://ui.perfetto.dev>) and an aggregated
//!   [`Metrics`] summary ([`TraceSession::metrics`]). Inner-pool worker
//!   threads ([`crate::inner`]) contribute per-thread *lane* streams that
//!   export as separate tids (`rank * LANE_STRIDE + lane`) and fold into
//!   their rank's metric totals.
//!
//! Recorders travel inside the transports ([`crate::exec::comm::SimComm`],
//! [`crate::exec::comm::ThreadComm`], [`crate::exec::SockComm`]) via
//! [`crate::exec::Communicator::tracer`], so kernels and transports share
//! one per-rank buffer — and any future transport (MPI) inherits the
//! instrumentation seam for free. In a multi-**process** run the peer
//! ranks' buffers are harvested over the socket at sweep end via the
//! [`wire`] codec and absorbed into rank 0's session.
//!
//! [`CommStats`]: crate::distsim::CommStats

pub mod chrome;
pub mod metrics;
pub mod wire;

pub use chrome::{validate_chrome_trace, TraceCheck};
pub use metrics::{Metrics, PeerFlow, RankMetrics};

use std::collections::BTreeMap;
use std::time::Instant;

/// Default per-rank event-buffer capacity (events, not bytes).
pub const DEFAULT_EVENT_CAPACITY: usize = 1 << 14;

/// Chrome-trace tid spacing between ranks: rank `r`'s main thread exports
/// as tid `r * LANE_STRIDE`, and its inner-pool workers ([`crate::inner`])
/// as tids `r * LANE_STRIDE + lane` for lane `1..LANE_STRIDE`. The
/// validator maps tids back to ranks by integer division.
pub const LANE_STRIDE: usize = 64;

/// An instrumented region. Payload fields are small copies (peer ids,
/// byte counts, round numbers) so events stay `Copy` and the recorder's
/// hot path never allocates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Span {
    /// TRAD full local SpMV of power `power` (paper Alg. 1 inner step).
    TradSpmv { power: u32 },
    /// DLB phase-2 wavefront step: level-group `group` promoted to `power`.
    DlbWavefront { group: u32, power: u32 },
    /// DLB phase-3 remainder: round `round` advancing class `I_class`.
    DlbRemainder { round: u32, class: u32 },
    /// DLB async phase-3: the class-`I_class` rows fed exclusively by rank
    /// `peer`'s halo segment, advanced in round `round` the moment that
    /// segment landed (while other receives may still be in flight).
    DlbSegment { round: u32, class: u32, peer: u32 },
    /// CA's single up-front extended-halo exchange.
    CaExchange,
    /// CA promotion round `power` (owned rows + still-live external classes).
    CaPromote { power: u32 },
    /// One point-to-point send (`bytes` of payload to rank `to`).
    CommSend { to: u32, bytes: u32 },
    /// One matched receive (`bytes` of payload from rank `from`).
    CommRecv { from: u32, bytes: u32 },
    /// A nonblocking receive probe that found nothing from rank `from`
    /// (async remainder `try_recv` miss).
    CommProbe { from: u32 },
    /// Round-closing barrier wait (`round` is the per-endpoint cumulative
    /// round counter at close).
    CommWait { round: u32 },
    /// Rank-pool worker executing one sweep job.
    JobDispatch,
    /// Rank-pool worker parked on its job channel.
    JobPark,
    /// One inner-pool task ([`crate::inner`]): level-group `group` promoted
    /// to `power` on some participant of a rank's inner thread pool.
    InnerTask { group: u32, power: u32 },
}

impl Span {
    /// Display name, e.g. `dlb.remainder(r1,k2)` — stable strings the
    /// exporters and tests key on.
    pub fn name(&self) -> String {
        match self {
            Self::TradSpmv { power } => format!("trad.spmv(p{power})"),
            Self::DlbWavefront { group, power } => format!("dlb.wavefront(g{group},p{power})"),
            Self::DlbRemainder { round, class } => format!("dlb.remainder(r{round},k{class})"),
            Self::DlbSegment { round, class, peer } => {
                format!("dlb.segment(r{round},k{class},<-{peer})")
            }
            Self::CaExchange => "ca.exchange".to_string(),
            Self::CaPromote { power } => format!("ca.promote(p{power})"),
            Self::CommSend { to, .. } => format!("comm.send(->{to})"),
            Self::CommRecv { from, .. } => format!("comm.recv(<-{from})"),
            Self::CommProbe { from } => format!("comm.probe(<-{from})"),
            Self::CommWait { round } => format!("comm.wait(r{round})"),
            Self::JobDispatch => "job.dispatch".to_string(),
            Self::JobPark => "job.park".to_string(),
            Self::InnerTask { group, power } => format!("inner.task(g{group},p{power})"),
        }
    }

    /// Chrome-trace category: `compute`, `comm`, or `pool`.
    pub fn cat(&self) -> &'static str {
        match self {
            Self::TradSpmv { .. }
            | Self::DlbWavefront { .. }
            | Self::DlbRemainder { .. }
            | Self::DlbSegment { .. }
            | Self::CaPromote { .. }
            | Self::InnerTask { .. } => "compute",
            Self::CaExchange
            | Self::CommSend { .. }
            | Self::CommRecv { .. }
            | Self::CommProbe { .. }
            | Self::CommWait { .. } => "comm",
            Self::JobDispatch | Self::JobPark => "pool",
        }
    }
}

/// What happened at one timestamp.
#[derive(Clone, Copy, Debug)]
pub enum EventKind {
    /// Open a span (closed by the matching `End` on the same rank).
    Begin(Span),
    /// Close the innermost open span.
    End,
    /// A named sample (chrome-trace 'C' event).
    Counter { name: &'static str, value: f64 },
}

/// One timeline entry: nanoseconds since the session epoch + payload.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub t_ns: u64,
    pub kind: EventKind,
}

/// Per-rank event recorder. Disabled (the default) it is a no-op whose
/// methods cost one predictable branch — no clock reads, no allocation;
/// enabled it appends into a buffer preallocated at attach time.
#[derive(Debug)]
pub struct RankRecorder {
    enabled: bool,
    rank: u32,
    epoch: Instant,
    capacity: usize,
    events: Vec<Event>,
}

impl Default for RankRecorder {
    fn default() -> Self {
        Self::disabled()
    }
}

impl RankRecorder {
    /// The no-op recorder: never timestamps, never allocates.
    pub fn disabled() -> Self {
        Self { enabled: false, rank: 0, epoch: Instant::now(), capacity: 0, events: Vec::new() }
    }

    /// An enabled recorder for `rank`, timestamping against `epoch`, with
    /// `capacity` events preallocated (grows beyond it only on overflow).
    pub fn enabled(rank: usize, epoch: Instant, capacity: usize) -> Self {
        Self {
            enabled: true,
            rank: rank as u32,
            epoch,
            capacity,
            events: Vec::with_capacity(capacity),
        }
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    pub fn rank(&self) -> usize {
        self.rank as usize
    }

    /// Buffered event count (0 while disabled).
    pub fn buffered(&self) -> usize {
        self.events.len()
    }

    /// Current buffer capacity — stays 0 on the disabled path, which is
    /// how tests prove "no allocation per event".
    pub fn buffer_capacity(&self) -> usize {
        self.events.capacity()
    }

    /// Nanoseconds since the session epoch (0 while disabled — callers use
    /// it only to feed back into [`RankRecorder::closed_span`]).
    #[inline]
    pub fn now(&self) -> u64 {
        if self.enabled {
            self.epoch.elapsed().as_nanos() as u64
        } else {
            0
        }
    }

    /// Open `span` at the current time.
    #[inline]
    pub fn begin(&mut self, span: Span) {
        if self.enabled {
            let t_ns = self.epoch.elapsed().as_nanos() as u64;
            self.events.push(Event { t_ns, kind: EventKind::Begin(span) });
        }
    }

    /// Close the innermost open span at the current time.
    #[inline]
    pub fn end(&mut self) {
        if self.enabled {
            let t_ns = self.epoch.elapsed().as_nanos() as u64;
            self.events.push(Event { t_ns, kind: EventKind::End });
        }
    }

    /// Record a span that began at `t0_ns` (a prior [`RankRecorder::now`])
    /// and ends now — one call emitting a balanced Begin/End pair, for
    /// regions whose payload (e.g. byte count) is only known at the end.
    #[inline]
    pub fn closed_span(&mut self, span: Span, t0_ns: u64) {
        if self.enabled {
            let t_ns = self.epoch.elapsed().as_nanos() as u64;
            self.events.push(Event { t_ns: t0_ns, kind: EventKind::Begin(span) });
            self.events.push(Event { t_ns, kind: EventKind::End });
        }
    }

    /// Record a named counter sample.
    #[inline]
    pub fn counter(&mut self, name: &'static str, value: f64) {
        if self.enabled {
            let t_ns = self.epoch.elapsed().as_nanos() as u64;
            self.events.push(Event { t_ns, kind: EventKind::Counter { name, value } });
        }
    }

    /// Drain the buffer (the recorder stays attached and keeps recording
    /// into a fresh preallocated buffer).
    pub fn take_events(&mut self) -> Vec<Event> {
        let fresh = Vec::with_capacity(if self.enabled { self.capacity } else { 0 });
        std::mem::replace(&mut self.events, fresh)
    }
}

/// Engine-owned trace state: one epoch shared by every rank's recorder,
/// plus the absorbed per-rank event streams — the main (lane-0) stream of
/// every rank, and any inner-pool lane streams keyed `(rank, lane)`.
pub struct TraceSession {
    epoch: Instant,
    capacity: usize,
    per_rank: Vec<Vec<Event>>,
    lanes: BTreeMap<(usize, usize), Vec<Event>>,
}

impl TraceSession {
    pub fn new(n_ranks: usize) -> Self {
        Self::with_capacity(n_ranks, DEFAULT_EVENT_CAPACITY)
    }

    pub fn with_capacity(n_ranks: usize, capacity: usize) -> Self {
        Self {
            epoch: Instant::now(),
            capacity,
            per_rank: vec![Vec::new(); n_ranks],
            lanes: BTreeMap::new(),
        }
    }

    pub fn n_ranks(&self) -> usize {
        self.per_rank.len()
    }

    /// A fresh enabled recorder for `rank`, sharing this session's epoch
    /// (so timelines of all ranks align).
    pub fn recorder(&self, rank: usize) -> RankRecorder {
        assert!(rank < self.per_rank.len(), "recorder for out-of-range rank {rank}");
        RankRecorder::enabled(rank, self.epoch, self.capacity)
    }

    /// Append a drained event buffer to `rank`'s main (lane-0) stream.
    pub fn absorb(&mut self, rank: usize, events: Vec<Event>) {
        self.per_rank[rank].extend(events);
    }

    /// Append a drained inner-pool worker buffer to `rank`'s lane stream
    /// `lane` (lanes start at 1; lane 0 is the rank's main thread).
    pub fn absorb_lane(&mut self, rank: usize, lane: usize, events: Vec<Event>) {
        assert!(rank < self.per_rank.len(), "lane events for out-of-range rank {rank}");
        assert!((1..LANE_STRIDE).contains(&lane), "inner lane {lane} out of range");
        self.lanes.entry((rank, lane)).or_default().extend(events);
    }

    pub fn events(&self, rank: usize) -> &[Event] {
        &self.per_rank[rank]
    }

    pub fn total_events(&self) -> usize {
        self.per_rank.iter().map(Vec::len).sum::<usize>()
            + self.lanes.values().map(Vec::len).sum::<usize>()
    }

    /// Chrome Trace Event Format JSON (B/E phase events, ts in µs, tid
    /// `rank * LANE_STRIDE + lane`). Open in `chrome://tracing` or
    /// <https://ui.perfetto.dev>.
    pub fn chrome_trace_json(&self) -> String {
        let mut streams: Vec<(usize, &[Event])> = self
            .per_rank
            .iter()
            .enumerate()
            .map(|(rank, ev)| (rank * LANE_STRIDE, ev.as_slice()))
            .collect();
        for (&(rank, lane), ev) in &self.lanes {
            streams.push((rank * LANE_STRIDE + lane, ev.as_slice()));
        }
        chrome::chrome_trace_streams(&streams)
    }

    /// Aggregate the absorbed streams into per-rank + total [`Metrics`] —
    /// inner-pool lane streams fold into their owning rank's totals.
    pub fn metrics(&self) -> Metrics {
        let mut m = Metrics::from_events(&self.per_rank);
        for (&(rank, _lane), events) in &self.lanes {
            let lm = metrics::aggregate_rank(rank, events);
            m.total_compute_ns += lm.compute_ns;
            let rm = &mut m.per_rank[rank];
            rm.compute_ns += lm.compute_ns;
            rm.spans += lm.spans;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_never_allocates() {
        let mut r = RankRecorder::disabled();
        for _ in 0..10_000 {
            let t0 = r.now();
            r.begin(Span::TradSpmv { power: 1 });
            r.end();
            r.closed_span(Span::CommWait { round: 0 }, t0);
            r.counter("x", 1.0);
        }
        assert_eq!(r.buffered(), 0);
        assert_eq!(r.buffer_capacity(), 0, "disabled path must not allocate");
        assert!(r.take_events().is_empty());
        assert_eq!(r.buffer_capacity(), 0);
    }

    #[test]
    fn enabled_recorder_preallocates_and_balances() {
        let s = TraceSession::with_capacity(2, 64);
        let mut r = s.recorder(1);
        assert_eq!(r.buffer_capacity(), 64);
        let t0 = r.now();
        r.begin(Span::DlbWavefront { group: 0, power: 1 });
        r.end();
        r.closed_span(Span::CommRecv { from: 0, bytes: 8 }, t0);
        assert_eq!(r.buffered(), 4);
        let ev = r.take_events();
        assert_eq!(ev.len(), 4);
        assert_eq!(r.buffer_capacity(), 64, "drain keeps the preallocation");
        let begins = ev.iter().filter(|e| matches!(e.kind, EventKind::Begin(_))).count();
        let ends = ev.iter().filter(|e| matches!(e.kind, EventKind::End)).count();
        assert_eq!(begins, ends);
        // timestamps are monotone per pair
        assert!(ev[0].t_ns <= ev[1].t_ns);
    }

    #[test]
    fn span_names_are_stable() {
        assert_eq!(Span::TradSpmv { power: 2 }.name(), "trad.spmv(p2)");
        assert_eq!(Span::DlbWavefront { group: 3, power: 1 }.name(), "dlb.wavefront(g3,p1)");
        assert_eq!(Span::DlbRemainder { round: 1, class: 2 }.name(), "dlb.remainder(r1,k2)");
        assert_eq!(Span::CommWait { round: 4 }.name(), "comm.wait(r4)");
        assert_eq!(Span::CommSend { to: 1, bytes: 8 }.name(), "comm.send(->1)");
        assert_eq!(Span::CommRecv { from: 0, bytes: 8 }.name(), "comm.recv(<-0)");
        assert_eq!(Span::JobPark.cat(), "pool");
        assert_eq!(Span::CaExchange.cat(), "comm");
        assert_eq!(Span::CaPromote { power: 1 }.cat(), "compute");
        assert_eq!(Span::InnerTask { group: 2, power: 3 }.name(), "inner.task(g2,p3)");
        assert_eq!(Span::InnerTask { group: 2, power: 3 }.cat(), "compute");
        let seg = Span::DlbSegment { round: 1, class: 1, peer: 3 };
        assert_eq!(seg.name(), "dlb.segment(r1,k1,<-3)");
        assert_eq!(seg.cat(), "compute");
        assert_eq!(Span::CommProbe { from: 2 }.name(), "comm.probe(<-2)");
        assert_eq!(Span::CommProbe { from: 2 }.cat(), "comm");
    }

    #[test]
    fn lane_streams_export_and_fold_into_rank_metrics() {
        let mut s = TraceSession::with_capacity(2, 16);
        let mut main = s.recorder(1);
        let t0 = main.now();
        main.closed_span(Span::InnerTask { group: 0, power: 1 }, t0);
        s.absorb(1, main.take_events());
        let mut lane = s.recorder(1);
        let t0 = lane.now();
        lane.closed_span(Span::InnerTask { group: 1, power: 1 }, t0);
        s.absorb_lane(1, 1, lane.take_events());
        assert_eq!(s.total_events(), 4);
        let m = s.metrics();
        assert_eq!(m.per_rank.len(), 2);
        assert_eq!(m.per_rank[1].spans, 2, "lane spans fold into the owning rank");
        let check = chrome::validate_chrome_trace(&s.chrome_trace_json()).unwrap();
        assert_eq!(check.n_ranks(), 1, "main + lane tids map to one rank");
        assert_eq!(check.spans_per_rank[&1], 2);
        assert!(check.has_name_prefix("inner.task"));
    }
}
