//! Wire codec for trace events — how the multi-process transport
//! ([`crate::exec::SockComm`]) harvests per-rank timelines.
//!
//! In a `processes` run each rank's [`super::RankRecorder`] lives in its
//! own OS process, so at sweep end ranks `> 0` ship their drained event
//! buffers (main stream + inner-pool lane streams) to rank 0 over the
//! socket control plane, and rank 0 absorbs them into its
//! [`super::TraceSession`]. The socket payload type is `Vec<f64>`, so
//! events encode as fixed four-slot records whose `u64` bit patterns ride
//! inside `f64`s (`f64::from_bits`/`to_bits` — pure bit transport, never
//! arithmetic, so every pattern survives).
//!
//! Record layout per event: `[t_ns][code][a<<32|b][c-or-value]` where
//! `code` is the [`super::Span`] discriminant (`1..=13`), `0` for an
//! `End`, or `1000 + i` for a counter sample of the `i`-th name in the
//! closed counter vocabulary ([`COUNTER_NAMES`] — counters carry
//! `&'static str` names, so the wire sends a table index, not bytes).
//! Streams are framed as `[n_streams]` then per stream
//! `[lane][n_events][records...]`; the main stream is lane 0.
//!
//! Caveat (documented follow-up in ROADMAP): each process timestamps
//! against its own session epoch, so cross-rank time alignment is not
//! meaningful in a merged multi-process trace — per-rank span durations
//! and balance (what `dlb-mpk trace-check` validates) are.

use super::{Event, EventKind, Span};

/// The closed vocabulary of counter names that may appear on the wire —
/// exactly the `&'static str`s the kernels pass to
/// [`super::RankRecorder::counter`]. Extend this table when adding a
/// counter (the encoder panics on an unknown name, so a miss fails tests
/// immediately rather than corrupting a trace).
pub const COUNTER_NAMES: [&str; 2] = ["flop_nnz", "dlb.outstanding"];

const CODE_END: u64 = 0;
const CODE_COUNTER_BASE: u64 = 1000;

#[inline]
fn lift(x: u64) -> f64 {
    f64::from_bits(x)
}

#[inline]
fn sink(x: f64) -> u64 {
    x.to_bits()
}

fn pack(a: u32, b: u32) -> u64 {
    ((a as u64) << 32) | b as u64
}

fn unpack(ab: u64) -> (u32, u32) {
    ((ab >> 32) as u32, ab as u32)
}

fn encode_event(out: &mut Vec<f64>, ev: &Event) {
    let (code, ab, c): (u64, u64, u64) = match ev.kind {
        EventKind::Begin(span) => match span {
            Span::TradSpmv { power } => (1, pack(power, 0), 0),
            Span::DlbWavefront { group, power } => (2, pack(group, power), 0),
            Span::DlbRemainder { round, class } => (3, pack(round, class), 0),
            Span::DlbSegment { round, class, peer } => (4, pack(round, class), peer as u64),
            Span::CaExchange => (5, 0, 0),
            Span::CaPromote { power } => (6, pack(power, 0), 0),
            Span::CommSend { to, bytes } => (7, pack(to, bytes), 0),
            Span::CommRecv { from, bytes } => (8, pack(from, bytes), 0),
            Span::CommProbe { from } => (9, pack(from, 0), 0),
            Span::CommWait { round } => (10, pack(round, 0), 0),
            Span::JobDispatch => (11, 0, 0),
            Span::JobPark => (12, 0, 0),
            Span::InnerTask { group, power } => (13, pack(group, power), 0),
        },
        EventKind::End => (CODE_END, 0, 0),
        EventKind::Counter { name, value } => {
            let idx = COUNTER_NAMES
                .iter()
                .position(|&n| n == name)
                .unwrap_or_else(|| panic!("counter {name:?} missing from trace::wire::COUNTER_NAMES"));
            (CODE_COUNTER_BASE + idx as u64, 0, value.to_bits())
        }
    };
    out.push(lift(ev.t_ns));
    out.push(lift(code));
    out.push(lift(ab));
    out.push(lift(c));
}

fn decode_event(rec: &[f64]) -> Event {
    let t_ns = sink(rec[0]);
    let code = sink(rec[1]);
    let ab = sink(rec[2]);
    let c = sink(rec[3]);
    let (a, b) = unpack(ab);
    let kind = match code {
        CODE_END => EventKind::End,
        1 => EventKind::Begin(Span::TradSpmv { power: a }),
        2 => EventKind::Begin(Span::DlbWavefront { group: a, power: b }),
        3 => EventKind::Begin(Span::DlbRemainder { round: a, class: b }),
        4 => EventKind::Begin(Span::DlbSegment { round: a, class: b, peer: c as u32 }),
        5 => EventKind::Begin(Span::CaExchange),
        6 => EventKind::Begin(Span::CaPromote { power: a }),
        7 => EventKind::Begin(Span::CommSend { to: a, bytes: b }),
        8 => EventKind::Begin(Span::CommRecv { from: a, bytes: b }),
        9 => EventKind::Begin(Span::CommProbe { from: a }),
        10 => EventKind::Begin(Span::CommWait { round: a }),
        11 => EventKind::Begin(Span::JobDispatch),
        12 => EventKind::Begin(Span::JobPark),
        13 => EventKind::Begin(Span::InnerTask { group: a, power: b }),
        i if i >= CODE_COUNTER_BASE => {
            let idx = (i - CODE_COUNTER_BASE) as usize;
            assert!(idx < COUNTER_NAMES.len(), "unknown counter index {idx} on the wire");
            EventKind::Counter { name: COUNTER_NAMES[idx], value: f64::from_bits(c) }
        }
        other => panic!("unknown trace event code {other} on the wire"),
    };
    Event { t_ns, kind }
}

/// Encode one rank's drained streams — the main (lane-0) buffer plus any
/// inner-pool `(lane, events)` buffers — into one socket payload.
pub fn encode_streams(main: &[Event], lanes: &[(usize, Vec<Event>)]) -> Vec<f64> {
    let n_events: usize = main.len() + lanes.iter().map(|(_, e)| e.len()).sum::<usize>();
    let mut out = Vec::with_capacity(1 + (1 + lanes.len()) * 2 + n_events * 4);
    out.push(lift(1 + lanes.len() as u64));
    out.push(lift(0)); // main stream = lane 0
    out.push(lift(main.len() as u64));
    for ev in main {
        encode_event(&mut out, ev);
    }
    for (lane, events) in lanes {
        out.push(lift(*lane as u64));
        out.push(lift(events.len() as u64));
        for ev in events {
            encode_event(&mut out, ev);
        }
    }
    out
}

/// Decode a payload produced by [`encode_streams`] back into
/// `(main_events, lane_streams)`. Panics on a malformed payload — the
/// frames arrive over [`crate::exec::SockComm`]'s validated wire, so a
/// decode failure is a codec bug, not an I/O condition.
pub fn decode_streams(payload: &[f64]) -> (Vec<Event>, Vec<(usize, Vec<Event>)>) {
    let mut pos = 0;
    let mut take = |n: usize| {
        let s = &payload[pos..pos + n];
        pos += n;
        s
    };
    let n_streams = sink(take(1)[0]) as usize;
    let mut main = Vec::new();
    let mut lanes = Vec::new();
    for s in 0..n_streams {
        let lane = sink(take(1)[0]) as usize;
        let n_events = sink(take(1)[0]) as usize;
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            events.push(decode_event(take(4)));
        }
        if s == 0 {
            assert_eq!(lane, 0, "first stream must be the main (lane-0) stream");
            main = events;
        } else {
            lanes.push((lane, events));
        }
    }
    assert_eq!(pos, payload.len(), "trailing bytes in trace payload");
    (main, lanes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_kinds() -> Vec<Event> {
        let spans = [
            Span::TradSpmv { power: 3 },
            Span::DlbWavefront { group: 7, power: 2 },
            Span::DlbRemainder { round: 1, class: 2 },
            Span::DlbSegment { round: 2, class: 1, peer: 5 },
            Span::CaExchange,
            Span::CaPromote { power: 4 },
            Span::CommSend { to: 3, bytes: 4096 },
            Span::CommRecv { from: 1, bytes: u32::MAX },
            Span::CommProbe { from: 2 },
            Span::CommWait { round: 9 },
            Span::JobDispatch,
            Span::JobPark,
            Span::InnerTask { group: 11, power: 6 },
        ];
        let mut evs = Vec::new();
        for (i, s) in spans.iter().enumerate() {
            evs.push(Event { t_ns: i as u64 * 1_000, kind: EventKind::Begin(*s) });
            evs.push(Event { t_ns: i as u64 * 1_000 + 500, kind: EventKind::End });
        }
        evs.push(Event { t_ns: 42, kind: EventKind::Counter { name: "flop_nnz", value: 123.5 } });
        evs.push(Event {
            t_ns: u64::MAX, // extreme timestamp bit pattern survives the f64 ride
            kind: EventKind::Counter { name: "dlb.outstanding", value: -0.0 },
        });
        evs
    }

    fn assert_events_eq(a: &[Event], b: &[Event]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.t_ns, y.t_ns);
            match (&x.kind, &y.kind) {
                (EventKind::Begin(s1), EventKind::Begin(s2)) => assert_eq!(s1, s2),
                (EventKind::End, EventKind::End) => {}
                (
                    EventKind::Counter { name: n1, value: v1 },
                    EventKind::Counter { name: n2, value: v2 },
                ) => {
                    assert_eq!(n1, n2);
                    assert_eq!(v1.to_bits(), v2.to_bits(), "counter value must be bit-preserved");
                }
                (k1, k2) => panic!("kind mismatch: {k1:?} vs {k2:?}"),
            }
        }
    }

    #[test]
    fn every_event_kind_roundtrips() {
        let evs = all_kinds();
        let wire = encode_streams(&evs, &[]);
        let (main, lanes) = decode_streams(&wire);
        assert_events_eq(&evs, &main);
        assert!(lanes.is_empty());
    }

    #[test]
    fn lane_streams_roundtrip() {
        let main = vec![Event { t_ns: 1, kind: EventKind::Begin(Span::CaExchange) }];
        let l1 = vec![
            Event { t_ns: 2, kind: EventKind::Begin(Span::InnerTask { group: 0, power: 1 }) },
            Event { t_ns: 3, kind: EventKind::End },
        ];
        let l3: Vec<Event> = Vec::new();
        let wire = encode_streams(&main, &[(1, l1.clone()), (3, l3.clone())]);
        let (m, lanes) = decode_streams(&wire);
        assert_events_eq(&main, &m);
        assert_eq!(lanes.len(), 2);
        assert_eq!(lanes[0].0, 1);
        assert_events_eq(&l1, &lanes[0].1);
        assert_eq!(lanes[1].0, 3);
        assert!(lanes[1].1.is_empty());
    }

    #[test]
    fn empty_harvest_roundtrips() {
        let wire = encode_streams(&[], &[]);
        let (m, lanes) = decode_streams(&wire);
        assert!(m.is_empty());
        assert!(lanes.is_empty());
    }

    #[test]
    fn counter_vocabulary_is_closed() {
        // Every production counter name must be in the table — grep for
        // `.counter(` when this fails.
        for name in COUNTER_NAMES {
            let ev = Event { t_ns: 0, kind: EventKind::Counter { name, value: 1.0 } };
            let wire = encode_streams(&[ev], &[]);
            let (m, _) = decode_streams(&wire);
            assert!(matches!(m[0].kind, EventKind::Counter { name: n, .. } if n == name));
        }
    }
}
