//! Chrome Trace Event Format exporter + validator.
//!
//! Emits the JSON-object form (`{"traceEvents": [...]}`) with duration
//! events as explicit `"B"`/`"E"` pairs — one `pid` (the process), one
//! `tid` per rank, timestamps in microseconds. Loadable in
//! `chrome://tracing` and <https://ui.perfetto.dev>.
//!
//! The validator re-parses an exported file with the crate's own JSON
//! parser ([`crate::util::json::Json`]) and checks structural invariants
//! (every `E` closes a prior `B` on its rank; nothing left open) — it backs
//! both the `dlb-mpk trace-check` CLI used by CI and the trace-layer tests.

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::{Event, EventKind, Span};

/// Serialize per-rank event streams to Chrome Trace Event Format JSON
/// (rank `r` exports as tid `r * LANE_STRIDE`, lane 0).
pub fn chrome_trace_json(per_rank: &[Vec<Event>]) -> String {
    let streams: Vec<(usize, &[Event])> = per_rank
        .iter()
        .enumerate()
        .map(|(rank, ev)| (rank * super::LANE_STRIDE, ev.as_slice()))
        .collect();
    chrome_trace_streams(&streams)
}

/// Serialize `(tid, events)` streams to Chrome Trace Event Format JSON —
/// the lane-aware form [`super::TraceSession`] uses to export a rank's main
/// thread and its inner-pool workers as separate timeline rows.
pub(crate) fn chrome_trace_streams(streams: &[(usize, &[Event])]) -> String {
    let mut out =
        String::with_capacity(64 * streams.iter().map(|(_, ev)| ev.len()).sum::<usize>() + 64);
    out.push_str("{\"traceEvents\":[");
    let mut first = true;
    for &(tid, events) in streams {
        // Re-derive each End's span from the begin stack so its "name"
        // matches the opener (viewers tolerate nameless E events; our
        // validator and tests are stricter).
        let mut stack: Vec<Span> = Vec::new();
        for ev in events {
            let ts_us = ev.t_ns as f64 / 1000.0;
            let entry = match ev.kind {
                EventKind::Begin(span) => {
                    stack.push(span);
                    event_json(&span, "B", ts_us, tid)
                }
                EventKind::End => {
                    let span = stack
                        .pop()
                        .unwrap_or_else(|| panic!("tid {tid}: End event without an open span"));
                    event_json(&span, "E", ts_us, tid)
                }
                EventKind::Counter { name, value } => format!(
                    "{{\"name\":{},\"ph\":\"C\",\"ts\":{ts_us:.3},\"pid\":0,\"tid\":{tid},\
                     \"args\":{{{}:{value}}}}}",
                    json_str(name),
                    json_str(name),
                ),
            };
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&entry);
        }
        assert!(stack.is_empty(), "tid {tid}: {} span(s) left open at export", stack.len());
    }
    out.push_str("],\"displayTimeUnit\":\"ms\"}");
    out
}

fn event_json(span: &Span, ph: &str, ts_us: f64, tid: usize) -> String {
    let args = span_args(span);
    format!(
        "{{\"name\":{},\"cat\":\"{}\",\"ph\":\"{ph}\",\"ts\":{ts_us:.3},\"pid\":0,\
         \"tid\":{tid}{args}}}",
        json_str(&span.name()),
        span.cat(),
    )
}

fn span_args(span: &Span) -> String {
    match span {
        Span::CommSend { to, bytes } => format!(",\"args\":{{\"to\":{to},\"bytes\":{bytes}}}"),
        Span::CommRecv { from, bytes } => {
            format!(",\"args\":{{\"from\":{from},\"bytes\":{bytes}}}")
        }
        Span::CommWait { round } => format!(",\"args\":{{\"round\":{round}}}"),
        Span::DlbWavefront { group, power } => {
            format!(",\"args\":{{\"group\":{group},\"power\":{power}}}")
        }
        Span::DlbRemainder { round, class } => {
            format!(",\"args\":{{\"round\":{round},\"class\":{class}}}")
        }
        Span::TradSpmv { power } | Span::CaPromote { power } => {
            format!(",\"args\":{{\"power\":{power}}}")
        }
        Span::InnerTask { group, power } => {
            format!(",\"args\":{{\"group\":{group},\"power\":{power}}}")
        }
        Span::CaExchange | Span::JobDispatch | Span::JobPark => String::new(),
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// What [`validate_chrome_trace`] found in a well-formed trace.
#[derive(Clone, Debug, Default)]
pub struct TraceCheck {
    /// Total events in `traceEvents`.
    pub events: usize,
    /// Balanced begin/end span pairs per rank (tid / `LANE_STRIDE`, so a
    /// rank's inner-pool lanes count toward the rank), ascending rank.
    pub spans_per_rank: BTreeMap<i64, usize>,
    /// Distinct span names seen.
    pub names: Vec<String>,
}

impl TraceCheck {
    pub fn n_ranks(&self) -> usize {
        self.spans_per_rank.len()
    }

    pub fn has_name_prefix(&self, prefix: &str) -> bool {
        self.names.iter().any(|n| n.starts_with(prefix))
    }
}

/// Parse `json` as a Chrome Trace Event file and verify it is structurally
/// sound: `traceEvents` exists, every event carries `ph`/`ts`/`tid`, and on
/// every tid the `B`/`E` events balance like a bracket sequence (no `E`
/// without an open `B`, nothing left open). Returns per-rank span counts
/// (ranks recovered as tid / `LANE_STRIDE`) and the distinct names on
/// success.
pub fn validate_chrome_trace(json: &str) -> Result<TraceCheck, String> {
    let doc = Json::parse(json).map_err(|e| format!("not valid JSON: {e}"))?;
    let events = doc
        .get("traceEvents")
        .ok_or("missing \"traceEvents\" key")?;
    let Json::Arr(events) = events else {
        return Err("\"traceEvents\" is not an array".into());
    };
    let mut check = TraceCheck { events: events.len(), ..TraceCheck::default() };
    let mut depth: BTreeMap<i64, usize> = BTreeMap::new();
    let mut names: BTreeMap<String, ()> = BTreeMap::new();
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("event {i}: missing \"ph\""))?;
        let tid = match ev.get("tid") {
            Some(Json::Num(n)) => *n as i64,
            _ => return Err(format!("event {i}: missing numeric \"tid\"")),
        };
        if !matches!(ev.get("ts"), Some(Json::Num(_))) {
            return Err(format!("event {i}: missing numeric \"ts\""));
        }
        if let Some(name) = ev.get("name").and_then(Json::as_str) {
            names.entry(name.to_string()).or_insert(());
        }
        match ph {
            "B" => {
                *depth.entry(tid).or_insert(0) += 1;
            }
            "E" => {
                let d = depth.entry(tid).or_insert(0);
                if *d == 0 {
                    return Err(format!("event {i}: \"E\" with no open span on tid {tid}"));
                }
                *d -= 1;
                let rank = tid.div_euclid(super::LANE_STRIDE as i64);
                *check.spans_per_rank.entry(rank).or_insert(0) += 1;
            }
            "C" | "X" | "M" | "i" | "I" => {}
            other => return Err(format!("event {i}: unsupported phase {other:?}")),
        }
    }
    for (tid, d) in &depth {
        if *d != 0 {
            return Err(format!("tid {tid}: {d} span(s) left open (unbalanced B/E)"));
        }
    }
    check.names = names.into_keys().collect();
    Ok(check)
}

#[cfg(test)]
mod tests {
    use super::super::TraceSession;
    use super::*;

    #[test]
    fn export_round_trips_through_validator() {
        let s = TraceSession::with_capacity(2, 16);
        let mut session = s;
        for rank in 0..2 {
            let mut r = session.recorder(rank);
            let t0 = r.now();
            r.begin(Span::DlbWavefront { group: 0, power: 1 });
            r.closed_span(Span::CommRecv { from: 1 - rank as u32, bytes: 16 }, t0);
            r.end();
            r.counter("flop_nnz", 123.0);
            let ev = r.take_events();
            session.absorb(rank, ev);
        }
        let json = session.chrome_trace_json();
        let check = validate_chrome_trace(&json).expect("exported trace must validate");
        assert_eq!(check.n_ranks(), 2);
        assert_eq!(check.spans_per_rank[&0], 2);
        assert_eq!(check.spans_per_rank[&1], 2);
        assert!(check.has_name_prefix("dlb.wavefront"));
        assert!(check.has_name_prefix("comm.recv"));
    }

    #[test]
    fn validator_rejects_unbalanced_and_garbage() {
        assert!(validate_chrome_trace("not json").is_err());
        assert!(validate_chrome_trace("{}").is_err());
        assert!(validate_chrome_trace("{\"traceEvents\":3}").is_err());
        // E without B
        let bad = r#"{"traceEvents":[{"name":"x","ph":"E","ts":1,"pid":0,"tid":0}]}"#;
        assert!(validate_chrome_trace(bad).is_err());
        // B left open
        let open = r#"{"traceEvents":[{"name":"x","ph":"B","ts":1,"pid":0,"tid":0}]}"#;
        assert!(validate_chrome_trace(open).is_err());
        // balanced pair passes
        let ok = r#"{"traceEvents":[{"name":"x","ph":"B","ts":1,"pid":0,"tid":0},
                                     {"name":"x","ph":"E","ts":2,"pid":0,"tid":0}]}"#;
        let c = validate_chrome_trace(ok).unwrap();
        assert_eq!(c.events, 2);
        assert_eq!(c.spans_per_rank[&0], 1);
    }
}
