//! Aggregation of recorded events into per-rank and merged metrics — the
//! flat-summary exporter next to the chrome-trace timeline.
//!
//! Replays each rank's event stream with a span stack and buckets leaf
//! durations: compute by kind (wavefront time additionally by level
//! group), comm by direction, barrier wait by round, and received
//! bytes/messages by peer. Receiver-side flows reproduce the
//! [`crate::distsim::CommStats`] totals exactly (same accounting side),
//! which `rust/tests/trace_layer.rs` asserts.

use std::collections::BTreeMap;

use super::{Event, EventKind, Span};

/// Receiver- or sender-side flow to/from one peer rank.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PeerFlow {
    pub peer: usize,
    pub messages: usize,
    pub bytes: usize,
}

/// One rank's aggregated timeline.
#[derive(Clone, Debug, Default)]
pub struct RankMetrics {
    pub rank: usize,
    /// Total time in compute spans (wavefront + remainder + spmv + promote).
    pub compute_ns: u64,
    /// Time inside `comm.send` spans (payload copy + enqueue).
    pub send_ns: u64,
    /// Time inside `comm.recv` spans (blocking for + copying payloads).
    pub recv_ns: u64,
    /// Time inside `comm.wait` spans (the round-closing barrier).
    pub wait_ns: u64,
    /// Time inside `comm.probe` spans (nonblocking receive misses).
    pub probe_ns: u64,
    /// Communication/computation overlap: compute time spent while at
    /// least one receive of the current round was still outstanding
    /// (tracked via the kernel's `dlb.outstanding` counter; only the async
    /// remainder emits it, so this is 0 on the sync path).
    pub overlap_ns: u64,
    /// Overlapped compute per remainder round `(round, ns)`, ascending —
    /// from round-carrying compute spans (`dlb.segment`/`dlb.remainder`)
    /// closed while receives were outstanding.
    pub overlap_by_round: Vec<(u32, u64)>,
    /// Time parked between pool jobs.
    pub park_ns: u64,
    /// Messages received (receiver-side, like [`crate::distsim::CommStats`]).
    pub messages: usize,
    /// Bytes received.
    pub bytes: usize,
    /// Receive flows by sending peer, ascending peer id.
    pub recv_from: Vec<PeerFlow>,
    /// Send flows by destination peer, ascending peer id.
    pub sent_to: Vec<PeerFlow>,
    /// Barrier wait per round `(round, ns)`, ascending round.
    pub wait_by_round: Vec<(u32, u64)>,
    /// DLB wavefront compute per level group `(group, ns)`, ascending —
    /// the level-resolved histogram the paper's §5 analysis is about.
    pub level_compute_ns: Vec<(u32, u64)>,
    /// Closed spans replayed.
    pub spans: usize,
}

/// Per-rank metrics plus merged totals.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub per_rank: Vec<RankMetrics>,
    pub total_compute_ns: u64,
    pub total_wait_ns: u64,
    /// Summed [`RankMetrics::overlap_ns`] — compute hidden behind
    /// still-in-flight receives across all ranks.
    pub total_overlap_ns: u64,
    pub total_messages: usize,
    pub total_bytes: usize,
}

impl Metrics {
    /// Aggregate per-rank event streams (see the module docs).
    pub fn from_events(per_rank: &[Vec<Event>]) -> Self {
        let mut out = Metrics::default();
        for (rank, events) in per_rank.iter().enumerate() {
            let rm = aggregate_rank(rank, events);
            out.total_compute_ns += rm.compute_ns;
            out.total_wait_ns += rm.wait_ns;
            out.total_overlap_ns += rm.overlap_ns;
            out.total_messages += rm.messages;
            out.total_bytes += rm.bytes;
            out.per_rank.push(rm);
        }
        out
    }

    /// Flat JSON summary (the second exporter next to the chrome trace).
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"ranks\": {},\n", self.per_rank.len()));
        s.push_str(&format!(
            "  \"total\": {{\"compute_ns\": {}, \"wait_ns\": {}, \"overlap_ns\": {}, \
             \"messages\": {}, \"bytes\": {}}},\n",
            self.total_compute_ns,
            self.total_wait_ns,
            self.total_overlap_ns,
            self.total_messages,
            self.total_bytes
        ));
        s.push_str("  \"per_rank\": [\n");
        for (i, r) in self.per_rank.iter().enumerate() {
            let flows = |fl: &[PeerFlow]| -> String {
                let items: Vec<String> = fl
                    .iter()
                    .map(|f| {
                        format!(
                            "{{\"peer\": {}, \"messages\": {}, \"bytes\": {}}}",
                            f.peer, f.messages, f.bytes
                        )
                    })
                    .collect();
                format!("[{}]", items.join(", "))
            };
            let pairs = |ps: &[(u32, u64)], k: &str| -> String {
                let items: Vec<String> = ps
                    .iter()
                    .map(|(key, ns)| format!("{{\"{k}\": {key}, \"ns\": {ns}}}"))
                    .collect();
                format!("[{}]", items.join(", "))
            };
            s.push_str(&format!(
                "    {{\"rank\": {}, \"compute_ns\": {}, \"send_ns\": {}, \"recv_ns\": {}, \
                 \"wait_ns\": {}, \"probe_ns\": {}, \"overlap_ns\": {}, \"park_ns\": {}, \
                 \"messages\": {}, \"bytes\": {}, \
                 \"recv_from\": {}, \"sent_to\": {}, \"wait_by_round\": {}, \
                 \"overlap_by_round\": {}, \"level_compute_ns\": {}}}{}\n",
                r.rank,
                r.compute_ns,
                r.send_ns,
                r.recv_ns,
                r.wait_ns,
                r.probe_ns,
                r.overlap_ns,
                r.park_ns,
                r.messages,
                r.bytes,
                flows(&r.recv_from),
                flows(&r.sent_to),
                pairs(&r.wait_by_round, "round"),
                pairs(&r.overlap_by_round, "round"),
                pairs(&r.level_compute_ns, "group"),
                if i + 1 < self.per_rank.len() { "," } else { "" },
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

pub(crate) fn aggregate_rank(rank: usize, events: &[Event]) -> RankMetrics {
    let mut rm = RankMetrics { rank, ..RankMetrics::default() };
    let mut recv_from: BTreeMap<usize, PeerFlow> = BTreeMap::new();
    let mut sent_to: BTreeMap<usize, PeerFlow> = BTreeMap::new();
    let mut wait_by_round: BTreeMap<u32, u64> = BTreeMap::new();
    let mut overlap_by_round: BTreeMap<u32, u64> = BTreeMap::new();
    let mut level_ns: BTreeMap<u32, u64> = BTreeMap::new();
    let mut stack: Vec<(Span, u64)> = Vec::new();
    // Outstanding-receive level from the async remainder's
    // `dlb.outstanding` counter. The kernel updates it before each
    // segment's compute span opens, so at a compute End the level is the
    // number of receives that were still in flight during that span.
    let mut outstanding = 0.0f64;
    for ev in events {
        match ev.kind {
            EventKind::Begin(span) => stack.push((span, ev.t_ns)),
            EventKind::End => {
                let (span, t0) = stack
                    .pop()
                    .unwrap_or_else(|| panic!("rank {rank}: End event without an open span"));
                let dur = ev.t_ns.saturating_sub(t0);
                rm.spans += 1;
                if outstanding >= 1.0 && span.cat() == "compute" {
                    rm.overlap_ns += dur;
                    if let Span::DlbSegment { round, .. } | Span::DlbRemainder { round, .. } = span
                    {
                        *overlap_by_round.entry(round).or_insert(0) += dur;
                    }
                }
                match span {
                    Span::TradSpmv { .. }
                    | Span::DlbRemainder { .. }
                    | Span::DlbSegment { .. }
                    | Span::CaPromote { .. }
                    | Span::InnerTask { .. } => {
                        rm.compute_ns += dur;
                    }
                    Span::DlbWavefront { group, .. } => {
                        rm.compute_ns += dur;
                        *level_ns.entry(group).or_insert(0) += dur;
                    }
                    Span::CommSend { to, bytes } => {
                        rm.send_ns += dur;
                        let f = sent_to.entry(to as usize).or_insert(PeerFlow {
                            peer: to as usize,
                            ..PeerFlow::default()
                        });
                        f.messages += 1;
                        f.bytes += bytes as usize;
                    }
                    Span::CommRecv { from, bytes } => {
                        rm.recv_ns += dur;
                        rm.messages += 1;
                        rm.bytes += bytes as usize;
                        let f = recv_from.entry(from as usize).or_insert(PeerFlow {
                            peer: from as usize,
                            ..PeerFlow::default()
                        });
                        f.messages += 1;
                        f.bytes += bytes as usize;
                    }
                    Span::CommWait { round } => {
                        rm.wait_ns += dur;
                        *wait_by_round.entry(round).or_insert(0) += dur;
                    }
                    Span::CommProbe { .. } => rm.probe_ns += dur,
                    Span::JobPark => rm.park_ns += dur,
                    // dispatch wraps the kernel's own spans; attributing its
                    // duration too would double-count
                    Span::CaExchange | Span::JobDispatch => {}
                }
            }
            EventKind::Counter { name, value } => {
                if name == "dlb.outstanding" {
                    outstanding = value;
                }
            }
        }
    }
    assert!(stack.is_empty(), "rank {rank}: {} span(s) left open", stack.len());
    rm.recv_from = recv_from.into_values().collect();
    rm.sent_to = sent_to.into_values().collect();
    rm.wait_by_round = wait_by_round.into_iter().collect();
    rm.overlap_by_round = overlap_by_round.into_iter().collect();
    rm.level_compute_ns = level_ns.into_iter().collect();
    rm
}

#[cfg(test)]
mod tests {
    use super::super::TraceSession;
    use super::*;

    #[test]
    fn aggregates_flows_and_buckets() {
        let mut s = TraceSession::with_capacity(1, 32);
        let mut r = s.recorder(0);
        let t0 = r.now();
        r.closed_span(Span::CommRecv { from: 2, bytes: 24 }, t0);
        r.closed_span(Span::CommRecv { from: 2, bytes: 8 }, t0);
        r.closed_span(Span::CommRecv { from: 1, bytes: 16 }, t0);
        r.closed_span(Span::CommSend { to: 1, bytes: 40 }, t0);
        r.closed_span(Span::CommWait { round: 0 }, t0);
        r.closed_span(Span::DlbWavefront { group: 0, power: 1 }, t0);
        r.closed_span(Span::DlbWavefront { group: 0, power: 2 }, t0);
        s.absorb(0, r.take_events());
        let m = s.metrics();
        assert_eq!(m.per_rank.len(), 1);
        let rm = &m.per_rank[0];
        assert_eq!(rm.messages, 3);
        assert_eq!(rm.bytes, 48);
        assert_eq!(
            rm.recv_from,
            vec![
                PeerFlow { peer: 1, messages: 1, bytes: 16 },
                PeerFlow { peer: 2, messages: 2, bytes: 32 }
            ]
        );
        assert_eq!(rm.sent_to, vec![PeerFlow { peer: 1, messages: 1, bytes: 40 }]);
        assert_eq!(rm.wait_by_round.len(), 1);
        assert_eq!(rm.level_compute_ns.len(), 1);
        assert_eq!(m.total_bytes, 48);
        assert_eq!(m.total_messages, 3);
        // the summary is valid JSON
        assert!(crate::util::json::Json::parse(&m.to_json()).is_ok());
    }

    #[test]
    fn overlap_counts_compute_while_receives_outstanding() {
        let mut s = TraceSession::with_capacity(1, 32);
        let mut r = s.recorder(0);
        // Round start: two receives outstanding.
        r.counter("dlb.outstanding", 2.0);
        let t0 = r.now();
        r.closed_span(Span::CommRecv { from: 1, bytes: 8 }, t0);
        r.counter("dlb.outstanding", 1.0);
        let t0 = r.now();
        // Segment advanced while peer 2's message is still in flight.
        r.closed_span(Span::DlbSegment { round: 1, class: 1, peer: 1 }, t0);
        let t0 = r.now();
        r.closed_span(Span::CommRecv { from: 2, bytes: 8 }, t0);
        r.counter("dlb.outstanding", 0.0);
        let t0 = r.now();
        // Everything landed: this compute is NOT overlapped.
        r.closed_span(Span::DlbSegment { round: 1, class: 1, peer: 2 }, t0);
        let t0 = r.now();
        r.closed_span(Span::CommProbe { from: 2 }, t0);
        s.absorb(0, r.take_events());
        let m = s.metrics();
        let rm = &m.per_rank[0];
        assert_eq!(rm.messages, 2);
        // Only the first segment's compute overlapped a receive in flight,
        // and it is attributed to round 1.
        assert_eq!(rm.overlap_by_round.len(), 1);
        assert_eq!(rm.overlap_by_round[0].0, 1);
        assert_eq!(rm.overlap_by_round[0].1, rm.overlap_ns);
        assert_eq!(m.total_overlap_ns, rm.overlap_ns);
        assert!(rm.compute_ns >= rm.overlap_ns);
        assert!(crate::util::json::Json::parse(&m.to_json()).is_ok());
    }
}
