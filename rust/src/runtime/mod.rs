//! PJRT/XLA runtime: load and execute the AOT artifacts produced by
//! `python/compile/aot.py` (Layer 2 + Layer 1 lowered to HLO text).
//!
//! Python never runs on this path — the artifacts are compiled once at
//! startup (`HloModuleProto::from_text_file` → `client.compile`) and then
//! executed with rust-owned buffers. HLO *text* is the interchange format
//! (jax ≥ 0.5 emits 64-bit-id protos that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids — see /opt/xla-example/README.md).

pub mod artifacts;
pub mod backend;
pub mod client;

pub use artifacts::{ArtifactKind, ArtifactMeta, Manifest};
pub use backend::XlaSpmv;
pub use client::Runtime;
