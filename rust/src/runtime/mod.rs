//! PJRT/XLA runtime: load and execute the AOT artifacts produced by
//! `python/compile/aot.py` (Layer 2 + Layer 1 lowered to HLO text).
//!
//! Python never runs on this path — the artifacts are compiled once at
//! startup (`HloModuleProto::from_text_file` → `client.compile`) and then
//! executed with rust-owned buffers. HLO *text* is the interchange format
//! (jax ≥ 0.5 emits 64-bit-id protos that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids — see /opt/xla-example/README.md).

//! ## Feature gating
//!
//! The PJRT path needs the external `xla` crate, which is not available in
//! offline builds. With the default feature set, [`stub`] provides the same
//! public types (`Runtime`, `XlaSpmv`, `XlaChebStep`) whose constructors
//! fail with a clear message, so everything downstream still compiles and
//! artifact-probing callers skip gracefully. Build with `--features xla`
//! (and an `xla` crate on the path) for the real runtime.

pub mod artifacts;

#[cfg(feature = "xla")]
pub mod backend;
#[cfg(feature = "xla")]
pub mod client;

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use self::stub as backend;
#[cfg(not(feature = "xla"))]
pub use self::stub as client;

pub use artifacts::{ArtifactKind, ArtifactMeta, Manifest};
pub use self::backend::XlaSpmv;
pub use self::client::Runtime;
