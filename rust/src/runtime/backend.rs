//! XLA-backed SpMV: route matrix chunks through the AOT Pallas kernel.
//!
//! This is the three-layer composition proof: the ELL chunk built in rust is
//! executed by the Pallas `spmv_ell` kernel (Layer 1) inside the jax-lowered
//! HLO (Layer 2) on the PJRT CPU client, driven from the rust coordinator
//! (Layer 3). Used by `examples/quickstart.rs` and the end-to-end Chebyshev
//! driver; the criterion-style benches use the native backend because
//! interpret-mode Pallas timings are not meaningful (DESIGN.md §Backends).

use anyhow::{Context, Result};

use crate::matrix::EllChunk;

use super::artifacts::ArtifactKind;
use super::client::{lit_f64, lit_i32, vec_f64, Runtime};

/// Executes whole-chunk SpMVs through a fixed-shape AOT artifact.
pub struct XlaSpmv<'rt> {
    rt: &'rt Runtime,
    artifact: String,
    rows: usize,
    width: usize,
    xlen: usize,
}

impl<'rt> XlaSpmv<'rt> {
    /// Pick the artifact matching the chunk shape.
    pub fn new(rt: &'rt Runtime, rows: usize, width: usize, xlen: usize) -> Result<Self> {
        let meta = rt
            .manifest()
            .find(ArtifactKind::Spmv, rows, width, xlen)
            .with_context(|| {
                format!("no spmv artifact for rows={rows} width={width} xlen={xlen}; re-run `make artifacts` with --spmv rows={rows},width={width},xlen={xlen}")
            })?;
        Ok(Self {
            rt,
            artifact: meta.name.clone(),
            rows,
            width,
            xlen,
        })
    }

    /// `y = A x` with `A` as a padded ELL chunk (shape must match).
    pub fn spmv(&self, ell: &EllChunk, x: &[f64]) -> Result<Vec<f64>> {
        anyhow::ensure!(ell.rows == self.rows && ell.width == self.width, "chunk shape mismatch");
        anyhow::ensure!(x.len() == self.xlen, "x length mismatch");
        let vals = lit_f64(&ell.vals, &[self.rows as i64, self.width as i64])?;
        let cols = lit_i32(&ell.cols, &[self.rows as i64, self.width as i64])?;
        let xl = lit_f64(x, &[self.xlen as i64])?;
        let out = self.rt.execute(&self.artifact, &[vals, cols, xl])?;
        let mut y = vec_f64(&out[0])?;
        y.truncate(ell.rows_valid);
        Ok(y)
    }
}

/// Executes the fused Chebyshev recurrence step artifact.
pub struct XlaChebStep<'rt> {
    rt: &'rt Runtime,
    artifact: String,
    pub rows: usize,
    pub width: usize,
    pub xlen: usize,
}

impl<'rt> XlaChebStep<'rt> {
    pub fn new(rt: &'rt Runtime, rows: usize, width: usize, xlen: usize) -> Result<Self> {
        let meta = rt
            .manifest()
            .find(ArtifactKind::ChebStep, rows, width, xlen)
            .with_context(|| format!("no cheb_step artifact for {rows}x{width}, xlen {xlen}"))?;
        Ok(Self { rt, artifact: meta.name.clone(), rows, width, xlen })
    }

    /// `(v_re', v_im') = 2·H(v) − v_prev` on both planes, one PJRT call.
    pub fn step(
        &self,
        ell: &EllChunk,
        v_re: &[f64],
        v_im: &[f64],
        vp_re: &[f64],
        vp_im: &[f64],
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let dims = [self.rows as i64, self.width as i64];
        let vals = lit_f64(&ell.vals, &dims)?;
        let cols = lit_i32(&ell.cols, &dims)?;
        let n = self.xlen as i64;
        let out = self.rt.execute(
            &self.artifact,
            &[
                vals,
                cols,
                lit_f64(v_re, &[n])?,
                lit_f64(v_im, &[n])?,
                lit_f64(vp_re, &[n])?,
                lit_f64(vp_im, &[n])?,
            ],
        )?;
        Ok((vec_f64(&out[0])?, vec_f64(&out[1])?))
    }
}
