//! API-compatible stand-ins for the PJRT/XLA runtime when the crate is
//! built without the `xla` feature (the default in offline environments —
//! the `xla` crate is not vendored).
//!
//! Every constructor fails with a clear message. Callers that can skip
//! (the integration tests and examples) check `cfg!(feature = "xla")`
//! before probing for artifacts; anything else surfaces the load error.

use std::path::Path;

use anyhow::{bail, Result};

use crate::matrix::EllChunk;

use super::artifacts::{ArtifactMeta, Manifest};

/// Stub for `runtime::client::Runtime`: always fails to load.
pub struct Runtime {
    manifest: Manifest,
}

impl Runtime {
    pub fn load(_dir: &Path) -> Result<Self> {
        bail!("built without the `xla` feature: PJRT runtime unavailable (rebuild with `--features xla`)")
    }

    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.manifest.entries.get(name)
    }
}

/// Stub for `runtime::backend::XlaSpmv`.
pub struct XlaSpmv<'rt> {
    #[allow(dead_code)]
    rt: &'rt Runtime,
}

impl<'rt> XlaSpmv<'rt> {
    pub fn new(_rt: &'rt Runtime, _rows: usize, _width: usize, _xlen: usize) -> Result<Self> {
        bail!("built without the `xla` feature: XlaSpmv unavailable")
    }

    pub fn spmv(&self, _ell: &EllChunk, _x: &[f64]) -> Result<Vec<f64>> {
        bail!("built without the `xla` feature: XlaSpmv unavailable")
    }
}

/// Stub for `runtime::backend::XlaChebStep`.
pub struct XlaChebStep<'rt> {
    #[allow(dead_code)]
    rt: &'rt Runtime,
    pub rows: usize,
    pub width: usize,
    pub xlen: usize,
}

impl<'rt> XlaChebStep<'rt> {
    pub fn new(_rt: &'rt Runtime, _rows: usize, _width: usize, _xlen: usize) -> Result<Self> {
        bail!("built without the `xla` feature: XlaChebStep unavailable")
    }

    pub fn step(
        &self,
        _ell: &EllChunk,
        _v_re: &[f64],
        _v_im: &[f64],
        _vp_re: &[f64],
        _vp_im: &[f64],
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        bail!("built without the `xla` feature: XlaChebStep unavailable")
    }
}
