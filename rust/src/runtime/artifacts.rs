//! `artifacts/manifest.json` parsing: the contract between the AOT exporter
//! and the rust runtime (operand shapes, dtypes, entry kinds).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// `(vals f64[R,W], cols i32[R,W], x f64[N]) -> (y f64[R],)`
    Spmv,
    /// `(vals, cols, x) -> (ys f64[p_m, R],)`
    Mpk,
    /// `(vals, cols, v_re, v_im, vp_re, vp_im) -> (vn_re, vn_im)`
    ChebStep,
    /// `(a f64[], b f64[], x f64[N], y f64[N]) -> (z f64[N],)`
    Axpby,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "spmv" => Self::Spmv,
            "mpk" => Self::Mpk,
            "cheb_step" => Self::ChebStep,
            "axpby" => Self::Axpby,
            other => bail!("unknown artifact kind {other:?}"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: ArtifactKind,
    pub rows: usize,
    pub width: usize,
    pub xlen: usize,
    pub p_m: usize,
    pub path: PathBuf,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("read {}/manifest.json (run `make artifacts`)", dir.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let j = Json::parse(text).context("parse manifest.json")?;
        let obj = j.as_obj().context("manifest must be an object")?;
        let mut entries = BTreeMap::new();
        for (name, meta) in obj {
            let kind = ArtifactKind::parse(
                meta.get("kind").and_then(|k| k.as_str()).context("missing kind")?,
            )?;
            let get = |k: &str| meta.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
            let file = meta
                .get("file")
                .and_then(|f| f.as_str())
                .with_context(|| format!("artifact {name} missing file"))?;
            entries.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    kind,
                    rows: get("rows"),
                    width: get("width"),
                    xlen: get("xlen"),
                    p_m: get("p_m"),
                    path: dir.join(file),
                },
            );
        }
        Ok(Self { dir: dir.to_path_buf(), entries })
    }

    /// Find an artifact by kind + exact shape.
    pub fn find(&self, kind: ArtifactKind, rows: usize, width: usize, xlen: usize) -> Option<&ArtifactMeta> {
        self.entries
            .values()
            .find(|m| m.kind == kind && m.rows == rows && m.width == width && m.xlen == xlen)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "and32_spmv_32768x7": {"kind": "spmv", "rows": 32768, "width": 7,
                              "xlen": 32768, "file": "a.hlo.txt", "chars": 10},
      "axpby_32768": {"kind": "axpby", "xlen": 32768, "file": "b.hlo.txt"}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let a = &m.entries["and32_spmv_32768x7"];
        assert_eq!(a.kind, ArtifactKind::Spmv);
        assert_eq!((a.rows, a.width, a.xlen), (32768, 7, 32768));
        assert!(a.path.ends_with("a.hlo.txt"));
    }

    #[test]
    fn find_by_shape() {
        let m = Manifest::parse(Path::new("/x"), SAMPLE).unwrap();
        assert!(m.find(ArtifactKind::Spmv, 32768, 7, 32768).is_some());
        assert!(m.find(ArtifactKind::Spmv, 1, 7, 32768).is_none());
    }

    #[test]
    fn rejects_unknown_kind() {
        let bad = r#"{"x": {"kind": "frobnicate", "file": "f"}}"#;
        assert!(Manifest::parse(Path::new("/x"), bad).is_err());
    }
}
