//! PJRT client wrapper: compile HLO-text artifacts once, execute many times.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::artifacts::{ArtifactMeta, Manifest};

/// A compiled artifact registry on the PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create the CPU client and eagerly compile every artifact in `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut exes = HashMap::new();
        for (name, meta) in &manifest.entries {
            let proto = xla::HloModuleProto::from_text_file(
                meta.path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parse HLO text {}", meta.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compile artifact {name}"))?;
            exes.insert(name.clone(), exe);
        }
        Ok(Self { client, manifest, exes })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.manifest.entries.get(name)
    }

    /// Execute artifact `name` with literal operands; returns the elements
    /// of the result tuple.
    pub fn execute(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.exes.get(name).with_context(|| format!("unknown artifact {name}"))?;
        let out = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        // artifacts are lowered with return_tuple=True
        decompose_tuple(out)
    }
}

/// Unpack a tuple literal into its elements (1-tuples included).
fn decompose_tuple(mut lit: xla::Literal) -> Result<Vec<xla::Literal>> {
    Ok(lit.decompose_tuple()?)
}

/// Build an f64 literal of shape `dims` from a flat slice.
pub fn lit_f64(data: &[f64], dims: &[i64]) -> Result<xla::Literal> {
    let l = xla::Literal::vec1(data);
    Ok(l.reshape(dims)?)
}

/// Build an i32 literal of shape `dims`.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let l = xla::Literal::vec1(data);
    Ok(l.reshape(dims)?)
}

/// Extract a f64 vector from a literal.
pub fn vec_f64(lit: &xla::Literal) -> Result<Vec<f64>> {
    Ok(lit.to_vec::<f64>()?)
}
