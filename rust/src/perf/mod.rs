//! Performance modeling and measurement: the roofline bound (paper Eq. 4),
//! streaming-bandwidth measurement (paper Fig. 7's likwid load-only kernel),
//! and timing helpers.

pub mod bandwidth;
pub mod roofline;
pub mod timer;

pub use bandwidth::{load_bandwidth, BandwidthPoint};
pub use roofline::{spmv_roofline_flops, spmv_roofline_gflops};
pub use timer::{median_time, median_time_warm, Timed};
