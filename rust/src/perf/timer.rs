//! Minimal timing helpers (criterion is unavailable offline; benches use
//! median-of-N wall-clock like the paper: "benchmarks are repeated several
//! times, and the median performance is taken").

use std::time::Instant;

/// A measured run: median seconds plus spread.
#[derive(Clone, Copy, Debug)]
pub struct Timed {
    pub median_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub reps: usize,
}

/// Run `f` `reps` times and report the median (paper §6.1.2 methodology).
pub fn median_time<F: FnMut()>(reps: usize, f: F) -> Timed {
    median_time_warm(0, reps, f)
}

/// [`median_time`] preceded by `warmup` untimed runs of `f`, so the timed
/// repetitions see hot caches, faulted-in pages, and (for engine sweeps) an
/// already-parked rank pool instead of first-touch costs.
pub fn median_time_warm<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Timed {
    assert!(reps >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Timed {
        median_s: times[times.len() / 2],
        min_s: times[0],
        max_s: *times.last().unwrap(),
        reps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_of_sleeps() {
        let t = median_time(3, || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(t.median_s >= 0.002);
        assert!(t.min_s <= t.median_s && t.median_s <= t.max_s);
        assert_eq!(t.reps, 3);
    }

    #[test]
    fn warmup_runs_are_untimed() {
        let mut calls = 0usize;
        let t = median_time_warm(2, 3, || calls += 1);
        assert_eq!(calls, 5, "2 warmup + 3 timed runs");
        assert_eq!(t.reps, 3, "reps counts only timed runs");
    }
}
