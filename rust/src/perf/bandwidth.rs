//! Streaming load-only bandwidth measurement (the likwid-bench `load`
//! analogue used for paper Fig. 7).

use std::hint::black_box;
use std::time::Instant;

#[derive(Clone, Copy, Debug)]
pub struct BandwidthPoint {
    pub bytes: usize,
    pub gb_per_s: f64,
}

/// Measure load-only bandwidth for a working set of `bytes`, repeating the
/// sweep until `min_time` elapsed (so small sets aren't noise-dominated).
pub fn load_bandwidth(bytes: usize, min_time_s: f64) -> BandwidthPoint {
    let n = (bytes / 8).max(1024);
    let data: Vec<f64> = (0..n).map(|i| (i % 17) as f64).collect();
    // warm-up sweep
    let mut acc = 0.0f64;
    for &v in &data {
        acc += v;
    }
    black_box(acc);

    let mut reps = 0u32;
    let t0 = Instant::now();
    let mut sum = 0.0f64;
    loop {
        // 8-way unrolled sum: keeps the core load-bound, not add-latency-bound
        let mut s = [0.0f64; 8];
        let chunks = data.chunks_exact(8);
        let rem = chunks.remainder();
        for c in chunks {
            s[0] += c[0];
            s[1] += c[1];
            s[2] += c[2];
            s[3] += c[3];
            s[4] += c[4];
            s[5] += c[5];
            s[6] += c[6];
            s[7] += c[7];
        }
        sum += s.iter().sum::<f64>() + rem.iter().sum::<f64>();
        reps += 1;
        if t0.elapsed().as_secs_f64() >= min_time_s {
            break;
        }
    }
    black_box(sum);
    let secs = t0.elapsed().as_secs_f64();
    let moved = (n * 8) as f64 * reps as f64;
    BandwidthPoint { bytes: n * 8, gb_per_s: moved / secs / 1e9 }
}

/// Sweep working-set sizes (logarithmic ladder), Fig. 7 style.
pub fn bandwidth_sweep(min_bytes: usize, max_bytes: usize, points_per_decade: usize) -> Vec<BandwidthPoint> {
    let mut out = Vec::new();
    let ratio = 10f64.powf(1.0 / points_per_decade as f64);
    let mut b = min_bytes as f64;
    while b <= max_bytes as f64 {
        out.push(load_bandwidth(b as usize, 0.05));
        b *= ratio;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_is_positive_and_sane() {
        let p = load_bandwidth(1 << 20, 0.02);
        assert!(p.gb_per_s > 0.5 && p.gb_per_s < 5000.0, "bw {}", p.gb_per_s);
    }

    #[test]
    fn cache_faster_than_memory() {
        // 32 KiB (L1-resident) must beat 256 MiB (memory-resident)
        let l1 = load_bandwidth(32 << 10, 0.05);
        let mem = load_bandwidth(256 << 20, 0.2);
        assert!(
            l1.gb_per_s > mem.gb_per_s,
            "L1 {} <= mem {}",
            l1.gb_per_s,
            mem.gb_per_s
        );
    }
}
