//! The paper's roofline performance bound for CRS SpMV (Eq. 4):
//!
//!   P = b_s / (6 B + 14 B / N_nzr)
//!
//! with `b_s` the saturated main-memory load bandwidth. Derivation (per
//! non-zero): 8 B value + 4 B column index, halved to 6 B + … no — per flop:
//! each non-zero contributes 2 flops and streams 12 B of matrix data, plus
//! per-row 4 B rowptr and 8 B y-write + 8 B x-read amortized over N_nzr
//! non-zeros; the paper's constants fold this to 6 B/flop + 14 B/(flop·N_nzr).

/// Roofline flop/s bound for SpMV with mean row length `nnzr`, given
/// bandwidth `bs_bytes_per_s`.
pub fn spmv_roofline_flops(bs_bytes_per_s: f64, nnzr: f64) -> f64 {
    bs_bytes_per_s / (6.0 + 14.0 / nnzr)
}

/// Same in Gflop/s with `bs` in GB/s (decimal, as the paper reports).
pub fn spmv_roofline_gflops(bs_gb_per_s: f64, nnzr: f64) -> f64 {
    spmv_roofline_flops(bs_gb_per_s * 1e9, nnzr) / 1e9
}

/// Flops of one SpMV: 2·nnz (multiply + add per non-zero).
pub fn spmv_flops(nnz: usize) -> f64 {
    2.0 * nnz as f64
}

/// Achieved Gflop/s for `nnz` non-zeros processed in `seconds`.
pub fn gflops(nnz_processed: usize, seconds: f64) -> f64 {
    spmv_flops(nnz_processed) / seconds / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roofline_monotone_in_nnzr() {
        // wider rows amortize the per-row traffic -> higher bound
        assert!(spmv_roofline_gflops(200.0, 80.0) > spmv_roofline_gflops(200.0, 10.0));
    }

    #[test]
    fn paper_scale_sanity() {
        // SPR: 241 GB/s, N_nzr ~ 46 (Serena) -> ~38 Gflop/s (paper Fig. 9
        // shows TRAD around the upper-30s Gflop/s for such matrices)
        let p = spmv_roofline_gflops(241.0, 46.3);
        assert!((30.0..50.0).contains(&p), "P = {p}");
    }

    #[test]
    fn limit_is_bandwidth_over_six() {
        let inf = spmv_roofline_gflops(100.0, 1e12);
        assert!((inf - 100.0 / 6.0).abs() < 1e-6);
    }
}
