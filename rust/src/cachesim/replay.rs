//! Replay an MPK schedule's matrix-data reference stream.

use crate::cachesim::LruCache;
use crate::matrix::CsrMatrix;

/// A trace of row-range SpMV executions: `(lo, hi)` row ranges of a local
/// matrix, in execution order.
pub struct MpkTrace<'a> {
    pub a: &'a CsrMatrix,
    pub steps: Vec<(usize, usize)>,
}

impl<'a> MpkTrace<'a> {
    /// TRAD: `p_m` full sweeps.
    pub fn trad(a: &'a CsrMatrix, p_m: usize) -> Self {
        Self { a, steps: (0..p_m).map(|_| (0, a.n_rows())).collect() }
    }

    /// Wavefront trace from a schedule + group ranges.
    pub fn wavefront(
        a: &'a CsrMatrix,
        ranges: &[(usize, usize)],
        schedule: &[crate::race::schedule::Step],
    ) -> Self {
        Self { a, steps: schedule.iter().map(|s| ranges[s.group]).collect() }
    }
}

#[derive(Clone, Debug, Default)]
pub struct AccessStats {
    /// Bytes of matrix data requested (CRS values + colidx + rowptr).
    pub requested: u64,
    /// Bytes loaded from main memory (cache misses).
    pub mem_traffic: u64,
}

impl AccessStats {
    /// Fraction of matrix traffic served by the cache.
    pub fn hit_fraction(&self) -> f64 {
        if self.requested == 0 {
            0.0
        } else {
            1.0 - self.mem_traffic as f64 / self.requested as f64
        }
    }
}

/// Replay the matrix-data reference stream of `trace` through `cache`.
///
/// Address layout (byte offsets, disjoint regions):
/// * values:  `[0, 8·nnz)`
/// * colidx:  `[8·nnz, 12·nnz)`
/// * rowptr:  `[12·nnz, 12·nnz + 4·(n+1))`
///
/// The x/y vectors are deliberately *not* replayed: the paper's blocking
/// argument concerns matrix data (the dominant stream, `12 B/nnz` vs
/// `8 B/row`), and the BFS reordering makes vector accesses near-sequential.
pub fn replay(trace: &MpkTrace, cache: &mut LruCache) -> AccessStats {
    let a = trace.a;
    let nnz = a.nnz() as u64;
    let val_base = 0u64;
    let col_base = 8 * nnz;
    let ptr_base = 12 * nnz;
    let mut stats = AccessStats::default();
    for &(lo, hi) in &trace.steps {
        let k0 = a.rowptr[lo] as u64;
        let k1 = a.rowptr[hi] as u64;
        let nnz_bytes = 8 * (k1 - k0);
        let col_bytes = 4 * (k1 - k0);
        let ptr_bytes = 4 * (hi as u64 - lo as u64 + 1);
        stats.requested += nnz_bytes + col_bytes + ptr_bytes;
        stats.mem_traffic += cache.touch(val_base + 8 * k0, nnz_bytes as usize);
        stats.mem_traffic += cache.touch(col_base + 4 * k0, col_bytes as usize);
        stats.mem_traffic += cache.touch(ptr_base + 4 * lo as u64, ptr_bytes as usize);
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::levels::bfs_reorder;
    use crate::matrix::gen;
    use crate::race::{group_levels, wavefront};

    #[test]
    fn trad_traffic_is_pm_times_matrix() {
        let a = gen::stencil_2d_5pt(40, 40);
        let p_m = 4;
        let trace = MpkTrace::trad(&a, p_m);
        // cache far smaller than the matrix -> every sweep misses
        let mut cache = LruCache::new(8 << 10, 64, 8);
        let st = replay(&trace, &mut cache);
        let per_sweep = st.requested / p_m as u64;
        assert!(st.mem_traffic as f64 > 0.95 * (p_m as f64) * per_sweep as f64);
    }

    #[test]
    fn wavefront_traffic_close_to_single_sweep() {
        let a = gen::stencil_2d_5pt(40, 40);
        let (b, lv) = bfs_reorder(&a, 0);
        let p_m = 4;
        // budget C below the physical cache ("safety factor", paper §6.2)
        let cache_bytes = 64 << 10;
        let g = group_levels(&b, &lv, p_m, cache_bytes / 2, 50);
        let s = wavefront(&g, lv.n_levels(), p_m);
        let trace = MpkTrace::wavefront(&b, &g.ranges, &s);
        let mut cache = LruCache::new(cache_bytes, 64, 8);
        let st = replay(&trace, &mut cache);
        let one_sweep = st.requested / p_m as u64;
        // cache blocking: total memory traffic ≈ one sweep (compulsory
        // misses), far below p_m sweeps
        assert!(
            (st.mem_traffic as f64) < 1.8 * one_sweep as f64,
            "traffic {} vs sweep {}",
            st.mem_traffic,
            one_sweep
        );
    }

    #[test]
    fn dlb_beats_trad_traffic_on_level_matrix() {
        let a = gen::random_banded_sym(4_000, 16, 60, 3);
        let (b, lv) = bfs_reorder(&a, 0);
        let p_m = 4;
        let cache_bytes = 96 << 10;
        // budget C below the physical cache ("safety factor", paper §6.2)
        let g = group_levels(&b, &lv, p_m, cache_bytes / 2, 50);
        let s = wavefront(&g, lv.n_levels(), p_m);

        let mut c1 = LruCache::new(cache_bytes, 64, 8);
        let trad = replay(&MpkTrace::trad(&b, p_m), &mut c1);
        let mut c2 = LruCache::new(cache_bytes, 64, 8);
        let dlb = replay(&MpkTrace::wavefront(&b, &g.ranges, &s), &mut c2);
        assert!(
            dlb.mem_traffic * 2 < trad.mem_traffic,
            "dlb {} vs trad {}",
            dlb.mem_traffic,
            trad.mem_traffic
        );
    }
}
