//! Cache simulator: replay the memory reference stream of an MPK execution
//! and count main-memory traffic (DESIGN.md §Substitutions — stands in for
//! likwid hardware counters).
//!
//! The paper's roofline argument is entirely about how many bytes of matrix
//! data must come from main memory per SpMV. [`replay`] replays the exact
//! row-range schedule an MPK variant executes, at cache-line granularity,
//! against a set-associative LRU cache, and reports the memory-traffic
//! ratio TRAD/DLB — the cache-blocking factor that wall-clock speedups
//! follow.

pub mod lru;
pub mod replay;

pub use lru::LruCache;
pub use replay::{replay, AccessStats, MpkTrace};
