//! Set-associative LRU cache at cache-line granularity.

/// Set-associative cache with true-LRU replacement.
///
/// Addresses are abstract byte offsets; the simulator only needs relative
/// layout, not real pointers.
pub struct LruCache {
    line_bytes: usize,
    n_sets: usize,
    assoc: usize,
    /// tags[set * assoc + way]; u64::MAX = invalid.
    tags: Vec<u64>,
    /// LRU stamps, larger = more recent.
    stamp: Vec<u64>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
}

impl LruCache {
    /// `capacity` rounds down to a power-of-two set count.
    pub fn new(capacity: usize, line_bytes: usize, assoc: usize) -> Self {
        assert!(line_bytes.is_power_of_two());
        let lines = (capacity / line_bytes).max(assoc);
        // largest power-of-two set count that fits the capacity
        let raw = lines / assoc;
        let n_sets = if raw.is_power_of_two() { raw } else { raw.next_power_of_two() / 2 };
        let n_sets = n_sets.max(1);
        Self {
            line_bytes,
            n_sets,
            assoc,
            tags: vec![u64::MAX; n_sets * assoc],
            stamp: vec![0; n_sets * assoc],
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.n_sets * self.assoc * self.line_bytes
    }

    /// Touch one byte range; returns bytes missed (loaded from memory).
    pub fn touch(&mut self, addr: u64, bytes: usize) -> u64 {
        if bytes == 0 {
            return 0;
        }
        let lb = self.line_bytes as u64;
        let first = addr / lb;
        let last = (addr + bytes as u64 - 1) / lb;
        let mut missed = 0u64;
        for line in first..=last {
            if !self.access_line(line) {
                missed += lb;
            }
        }
        missed
    }

    /// Returns true on hit.
    fn access_line(&mut self, line: u64) -> bool {
        self.clock += 1;
        let set = (line as usize) & (self.n_sets - 1);
        let base = set * self.assoc;
        let ways = &mut self.tags[base..base + self.assoc];
        if let Some(w) = ways.iter().position(|&t| t == line) {
            self.stamp[base + w] = self.clock;
            self.hits += 1;
            return true;
        }
        // miss: evict LRU way
        let (mut victim, mut best) = (0usize, u64::MAX);
        for w in 0..self.assoc {
            if self.tags[base + w] == u64::MAX {
                victim = w;
                break;
            }
            if self.stamp[base + w] < best {
                best = self.stamp[base + w];
                victim = w;
            }
        }
        self.tags[base + victim] = line;
        self.stamp[base + victim] = self.clock;
        self.misses += 1;
        false
    }

    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_working_set_hits_after_warmup() {
        let mut c = LruCache::new(64 << 10, 64, 8);
        let m1 = c.touch(0, 32 << 10);
        assert_eq!(m1, 32 << 10); // cold
        let m2 = c.touch(0, 32 << 10);
        assert_eq!(m2, 0); // warm
    }

    #[test]
    fn working_set_larger_than_cache_thrashes() {
        let mut c = LruCache::new(16 << 10, 64, 8);
        c.touch(0, 1 << 20);
        c.reset_counters();
        let missed = c.touch(0, 1 << 20);
        // sequential sweep of 1 MiB through 16 KiB cache: ~all misses
        assert!(missed as usize >= (1 << 20) - c.capacity());
    }

    #[test]
    fn partial_line_counts_full_line() {
        let mut c = LruCache::new(4 << 10, 64, 4);
        assert_eq!(c.touch(10, 4), 64);
        assert_eq!(c.touch(12, 4), 0); // same line
        assert_eq!(c.touch(60, 8), 64); // crosses into next line
    }

    #[test]
    fn capacity_rounds_to_pow2_sets() {
        let c = LruCache::new(100 << 10, 64, 8);
        assert!(c.capacity() <= 100 << 10);
        assert!(c.capacity() >= 32 << 10);
    }
}
