//! Sparse matrix substrate: formats, IO, and generators.
//!
//! The crate-wide canonical format is CRS ([`CsrMatrix`]) with `u32` column
//! indices and `f64` values, matching the paper's storage accounting
//! (Section 6: 8 B values + 4 B column indices + 4 B row pointer, i.e. a
//! total CRS footprint of `4·N_r + 12·N_nz` bytes).
//!
//! [`ell`] provides the padded ELLPACK chunks consumed by the AOT
//! Pallas/XLA SpMV artifacts (see `python/compile/kernels/spmv_ell.py`).

pub mod anderson;
pub mod coo;
pub mod csr;
pub mod ell;
pub mod gen;
pub mod mm;
pub mod rcm;

pub use coo::CooMatrix;
pub use csr::CsrMatrix;
pub use ell::EllChunk;

/// CRS storage footprint in bytes: `4·N_r + 12·N_nz` (paper §6.1.2).
pub fn crs_bytes(n_rows: usize, n_nz: usize) -> usize {
    4 * n_rows + 12 * n_nz
}

#[cfg(test)]
mod tests {
    #[test]
    fn crs_bytes_matches_paper_formula() {
        // Serena: N_r = 1,391,349, N_nz = 64,531,701 -> 744 MiB (Table 4).
        let b = super::crs_bytes(1_391_349, 64_531_701);
        assert_eq!(crate::util::mib(b), 744);
        // audikw_1: 943,695 rows, 77,651,847 nnz -> 892 MiB.
        assert_eq!(crate::util::mib(super::crs_bytes(943_695, 77_651_847)), 892);
    }
}
