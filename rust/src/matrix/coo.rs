//! Coordinate-format builder: accumulate (row, col, val) triplets, then
//! compact into CRS (duplicates summed — the standard assembly contract).

use crate::matrix::CsrMatrix;

#[derive(Clone, Debug, Default)]
pub struct CooMatrix {
    pub n_rows: usize,
    pub n_cols: usize,
    entries: Vec<(u32, u32, f64)>,
}

impl CooMatrix {
    pub fn new(n_rows: usize, n_cols: usize) -> Self {
        Self { n_rows, n_cols, entries: Vec::new() }
    }

    pub fn push(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.n_rows && c < self.n_cols, "({r},{c}) out of bounds");
        self.entries.push((r as u32, c as u32, v));
    }

    pub fn nnz_raw(&self) -> usize {
        self.entries.len()
    }

    /// Sort, sum duplicates, drop explicit zeros produced by cancellation,
    /// and emit CRS.
    pub fn to_csr(mut self) -> CsrMatrix {
        self.entries.sort_unstable_by_key(|&(r, c, _)| ((r as u64) << 32) | c as u64);
        let mut rowptr = vec![0usize; self.n_rows + 1];
        let mut colidx: Vec<u32> = Vec::with_capacity(self.entries.len());
        let mut values: Vec<f64> = Vec::with_capacity(self.entries.len());
        let mut it = self.entries.iter().peekable();
        while let Some(&(r, c, v)) = it.next() {
            let mut sum = v;
            while let Some(&&(r2, c2, v2)) = it.peek() {
                if r2 == r && c2 == c {
                    sum += v2;
                    it.next();
                } else {
                    break;
                }
            }
            if sum != 0.0 {
                colidx.push(c);
                values.push(sum);
                rowptr[r as usize + 1] += 1;
            }
        }
        for r in 0..self.n_rows {
            rowptr[r + 1] += rowptr[r];
        }
        CsrMatrix::new(self.n_rows, self.n_cols, rowptr, colidx, values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_sums_duplicates() {
        let mut coo = CooMatrix::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 0, 2.0);
        coo.push(1, 1, 5.0);
        coo.push(0, 1, -1.0);
        let a = coo.to_csr();
        assert_eq!(a.nnz(), 3);
        let d = a.to_dense();
        assert_eq!(d[0], vec![3.0, -1.0]);
        assert_eq!(d[1], vec![0.0, 5.0]);
    }

    #[test]
    fn cancellation_drops_entry() {
        let mut coo = CooMatrix::new(1, 1);
        coo.push(0, 0, 1.0);
        coo.push(0, 0, -1.0);
        assert_eq!(coo.to_csr().nnz(), 0);
    }

    #[test]
    fn empty_matrix() {
        let a = CooMatrix::new(3, 3).to_csr();
        assert_eq!(a.nnz(), 0);
        assert!(a.validate().is_ok());
    }
}
