//! Padded ELLPACK chunks — the wire format of the AOT Pallas/XLA SpMV.
//!
//! Mirrors `python/compile/kernels/ref.py::csr_to_ell`: a block of rows is
//! stored as dense `(rows, width)` panels of values (f64) and column indices
//! (i32), rows shorter than `width` padded with `(0.0, col 0)` — harmless
//! because `0.0 * x[0] == 0`. Row count is padded up to a multiple of the
//! kernel's panel height.

use crate::matrix::CsrMatrix;

#[derive(Clone, Debug)]
pub struct EllChunk {
    /// Rows including padding (multiple of `panel_rows` used at AOT time).
    pub rows: usize,
    /// Rows of actual payload (<= rows).
    pub rows_valid: usize,
    pub width: usize,
    /// Row-major (rows × width).
    pub vals: Vec<f64>,
    /// Row-major (rows × width), i32 to match the artifact operand dtype.
    pub cols: Vec<i32>,
}

impl EllChunk {
    /// Convert CRS rows `[lo, hi)` of `a`, padding rows up to a multiple of
    /// `row_align` and width up to at least `min_width`.
    pub fn from_csr_rows(
        a: &CsrMatrix,
        lo: usize,
        hi: usize,
        row_align: usize,
        min_width: usize,
    ) -> Self {
        assert!(lo <= hi && hi <= a.n_rows);
        let rows_valid = hi - lo;
        let width = (lo..hi)
            .map(|r| a.rowptr[r + 1] - a.rowptr[r])
            .max()
            .unwrap_or(0)
            .max(min_width)
            .max(1);
        let rows = rows_valid.div_ceil(row_align.max(1)) * row_align.max(1);
        let mut vals = vec![0.0; rows * width];
        let mut cols = vec![0i32; rows * width];
        for (i, r) in (lo..hi).enumerate() {
            let (s, e) = (a.rowptr[r], a.rowptr[r + 1]);
            for (w, k) in (s..e).enumerate() {
                vals[i * width + w] = a.values[k];
                cols[i * width + w] = a.colidx[k] as i32;
            }
        }
        Self { rows, rows_valid, width, vals, cols }
    }

    /// Whole-matrix conversion.
    pub fn from_csr(a: &CsrMatrix, row_align: usize) -> Self {
        Self::from_csr_rows(a, 0, a.n_rows, row_align, 1)
    }

    /// Reference ELL SpMV (used to validate the XLA path from rust).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert!(y.len() >= self.rows_valid);
        for r in 0..self.rows_valid {
            let mut sum = 0.0;
            for w in 0..self.width {
                let k = r * self.width + w;
                sum += self.vals[k] * x[self.cols[k] as usize];
            }
            y[r] = sum;
        }
    }

    /// Padding fraction (wasted slots / total slots) — ELL efficiency metric.
    pub fn pad_fraction(&self, nnz: usize) -> f64 {
        let slots = self.rows * self.width;
        if slots == 0 {
            0.0
        } else {
            1.0 - nnz as f64 / slots as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::util::rng::Rng;

    #[test]
    fn ell_matches_csr_spmv() {
        let a = gen::stencil_2d_5pt(13, 9);
        let ell = EllChunk::from_csr(&a, 8);
        assert_eq!(ell.rows_valid, a.n_rows());
        assert_eq!(ell.rows % 8, 0);
        assert_eq!(ell.width, 5);
        let mut rng = Rng::new(5);
        let x: Vec<f64> = (0..a.n_rows()).map(|_| rng.normal()).collect();
        let mut y_csr = vec![0.0; a.n_rows()];
        let mut y_ell = vec![0.0; a.n_rows()];
        a.spmv(&x, &mut y_csr);
        ell.spmv(&x, &mut y_ell);
        for (u, v) in y_csr.iter().zip(&y_ell) {
            assert!((u - v).abs() < 1e-13);
        }
    }

    #[test]
    fn row_range_chunk() {
        let a = gen::stencil_2d_5pt(10, 10);
        let ell = EllChunk::from_csr_rows(&a, 20, 50, 16, 1);
        assert_eq!(ell.rows_valid, 30);
        assert_eq!(ell.rows, 32);
        let x = vec![1.0; 100];
        let mut y_ell = vec![0.0; 30];
        ell.spmv(&x, &mut y_ell);
        let mut y_full = vec![0.0; 100];
        a.spmv(&x, &mut y_full);
        for i in 0..30 {
            assert!((y_ell[i] - y_full[20 + i]).abs() < 1e-14);
        }
    }

    #[test]
    fn pad_fraction_counts_waste() {
        let a = gen::stencil_2d_5pt(4, 4); // corner rows have 3 nnz, width 5
        let ell = EllChunk::from_csr(&a, 1);
        let f = ell.pad_fraction(a.nnz());
        assert!(f > 0.0 && f < 0.5, "pad fraction {f}");
    }
}
