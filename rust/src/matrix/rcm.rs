//! Reverse Cuthill–McKee (RCM) bandwidth-reducing reordering.
//!
//! The paper's related-work section ties SpMV performance to access locality
//! via matrix bandwidth reduction (Kreutzer et al. 2014). RCM is the
//! standard tool; it also serves as an alternative pre-permutation ahead of
//! the BFS level reordering for matrices with poor initial orderings
//! (ablation: `benches/ablation` / `coordinator` config).

use crate::graph::Adjacency;
use crate::matrix::CsrMatrix;

/// RCM permutation (`perm[new] = old`). Starts each component from a
/// pseudo-peripheral vertex (two-sweep BFS heuristic), visits neighbors in
/// ascending degree order, and reverses the final order.
pub fn rcm_permutation(a: &CsrMatrix) -> Vec<usize> {
    let g = Adjacency::from_symmetric_or_general(a);
    let n = g.n;
    let mut visited = vec![false; n];
    let mut order: Vec<u32> = Vec::with_capacity(n);
    let mut scan = 0usize;
    while order.len() < n {
        while scan < n && visited[scan] {
            scan += 1;
        }
        let root = pseudo_peripheral(&g, scan as u32, &visited);
        // Cuthill–McKee BFS with ascending-degree tie-break
        let start = order.len();
        visited[root as usize] = true;
        order.push(root);
        let mut head = start;
        while head < order.len() {
            let u = order[head] as usize;
            head += 1;
            let mut nbrs: Vec<u32> = g
                .neighbors(u)
                .iter()
                .copied()
                .filter(|&v| !visited[v as usize])
                .collect();
            nbrs.sort_unstable_by_key(|&v| g.degree(v as usize));
            for v in nbrs {
                if !visited[v as usize] {
                    visited[v as usize] = true;
                    order.push(v);
                }
            }
        }
    }
    order.reverse();
    order.into_iter().map(|v| v as usize).collect()
}

/// Two-sweep BFS pseudo-peripheral vertex heuristic (George & Liu).
fn pseudo_peripheral(g: &Adjacency, start: u32, visited: &[bool]) -> u32 {
    let mut cur = start;
    let mut last_ecc = 0u32;
    for _ in 0..4 {
        let (far, ecc) = bfs_farthest(g, cur, visited);
        if ecc <= last_ecc {
            break;
        }
        last_ecc = ecc;
        cur = far;
    }
    cur
}

fn bfs_farthest(g: &Adjacency, root: u32, visited: &[bool]) -> (u32, u32) {
    let mut dist = vec![u32::MAX; g.n];
    dist[root as usize] = 0;
    let mut frontier = vec![root];
    let mut far = root;
    let mut ecc = 0;
    while !frontier.is_empty() {
        let mut next = Vec::new();
        for &u in &frontier {
            for &v in g.neighbors(u as usize) {
                if !visited[v as usize] && dist[v as usize] == u32::MAX {
                    dist[v as usize] = dist[u as usize] + 1;
                    if dist[v as usize] > ecc {
                        ecc = dist[v as usize];
                        far = v;
                    }
                    next.push(v);
                }
            }
        }
        frontier = next;
    }
    (far, ecc)
}

/// Apply RCM: returns the symmetrically permuted matrix and the permutation.
pub fn rcm_reorder(a: &CsrMatrix) -> (CsrMatrix, Vec<usize>) {
    let perm = rcm_permutation(a);
    (a.permute_symmetric(&perm), perm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::util::rng::Rng;

    #[test]
    fn rcm_reduces_bandwidth_of_shuffled_matrix() {
        let a = gen::stencil_2d_5pt(20, 20);
        let mut perm: Vec<usize> = (0..400).collect();
        Rng::new(3).shuffle(&mut perm);
        let shuffled = a.permute_symmetric(&perm);
        let (r, _) = rcm_reorder(&shuffled);
        assert!(r.bandwidth() < shuffled.bandwidth() / 2,
            "rcm {} vs shuffled {}", r.bandwidth(), shuffled.bandwidth());
    }

    #[test]
    fn rcm_is_a_permutation_and_preserves_spmv() {
        let a = gen::random_banded_sym(300, 8, 40, 6);
        let (r, perm) = rcm_reorder(&a);
        let mut seen = vec![false; 300];
        for &p in &perm {
            assert!(!seen[p]);
            seen[p] = true;
        }
        // y_perm[i] == y[perm[i]]
        let x: Vec<f64> = (0..300).map(|i| (i as f64).cos()).collect();
        let xp: Vec<f64> = perm.iter().map(|&o| x[o]).collect();
        let mut y = vec![0.0; 300];
        let mut yp = vec![0.0; 300];
        a.spmv(&x, &mut y);
        r.spmv(&xp, &mut yp);
        for (i, &o) in perm.iter().enumerate() {
            assert!((yp[i] - y[o]).abs() < 1e-12);
        }
    }

    #[test]
    fn rcm_handles_disconnected() {
        let mut coo = crate::matrix::CooMatrix::new(6, 6);
        for (u, v) in [(0, 1), (2, 3), (4, 5)] {
            coo.push(u, v, 1.0);
            coo.push(v, u, 1.0);
        }
        for i in 0..6 {
            coo.push(i, i, 2.0);
        }
        let a = coo.to_csr();
        let perm = rcm_permutation(&a);
        let mut s = perm.clone();
        s.sort_unstable();
        assert_eq!(s, (0..6).collect::<Vec<_>>());
    }
}
