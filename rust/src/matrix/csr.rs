//! Compressed Row Storage (CRS/CSR) matrix.

use crate::matrix::crs_bytes;

/// Square or rectangular sparse matrix in CRS format.
///
/// Invariants (checked by [`CsrMatrix::validate`]):
/// * `rowptr.len() == n_rows + 1`, `rowptr[0] == 0`, non-decreasing
/// * `colidx.len() == values.len() == rowptr[n_rows]`
/// * every column index is `< n_cols`
/// * column indices are strictly increasing within a row
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    pub n_rows: usize,
    pub n_cols: usize,
    pub rowptr: Vec<usize>,
    pub colidx: Vec<u32>,
    pub values: Vec<f64>,
}

impl CsrMatrix {
    pub fn new(
        n_rows: usize,
        n_cols: usize,
        rowptr: Vec<usize>,
        colidx: Vec<u32>,
        values: Vec<f64>,
    ) -> Self {
        let m = Self { n_rows, n_cols, rowptr, colidx, values };
        debug_assert!(m.validate().is_ok(), "{:?}", m.validate());
        m
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    pub fn nnz(&self) -> usize {
        self.colidx.len()
    }

    /// Average non-zeros per row (the paper's `N_nzr`).
    pub fn nnzr(&self) -> f64 {
        if self.n_rows == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.n_rows as f64
        }
    }

    /// CRS footprint in bytes (paper convention, §6.1.2).
    pub fn crs_bytes(&self) -> usize {
        crs_bytes(self.n_rows, self.nnz())
    }

    /// Column indices of row `r`.
    #[inline]
    pub fn row_cols(&self, r: usize) -> &[u32] {
        &self.colidx[self.rowptr[r]..self.rowptr[r + 1]]
    }

    /// Values of row `r`.
    #[inline]
    pub fn row_vals(&self, r: usize) -> &[f64] {
        &self.values[self.rowptr[r]..self.rowptr[r + 1]]
    }

    /// Full structural validation; returns a description of the first
    /// violation, if any.
    pub fn validate(&self) -> Result<(), String> {
        if self.rowptr.len() != self.n_rows + 1 {
            return Err(format!(
                "rowptr length {} != n_rows + 1 = {}",
                self.rowptr.len(),
                self.n_rows + 1
            ));
        }
        if self.rowptr[0] != 0 {
            return Err("rowptr[0] != 0".into());
        }
        if self.colidx.len() != self.values.len() {
            return Err("colidx/values length mismatch".into());
        }
        if *self.rowptr.last().unwrap() != self.colidx.len() {
            return Err("rowptr[n] != nnz".into());
        }
        for r in 0..self.n_rows {
            if self.rowptr[r] > self.rowptr[r + 1] {
                return Err(format!("rowptr decreasing at row {r}"));
            }
            let cols = self.row_cols(r);
            for (k, &c) in cols.iter().enumerate() {
                if c as usize >= self.n_cols {
                    return Err(format!("col {c} out of bounds in row {r}"));
                }
                if k > 0 && cols[k - 1] >= c {
                    return Err(format!("row {r} columns not strictly increasing"));
                }
            }
        }
        Ok(())
    }

    /// Serial reference SpMV: `y = A x`. The correctness oracle everything
    /// else is checked against (mirrors python `ref.spmv_ell_ref`).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert!(x.len() >= self.n_cols, "x too short: {} < {}", x.len(), self.n_cols);
        assert!(y.len() >= self.n_rows);
        for r in 0..self.n_rows {
            let mut sum = 0.0;
            for k in self.rowptr[r]..self.rowptr[r + 1] {
                sum += self.values[k] * x[self.colidx[k] as usize];
            }
            y[r] = sum;
        }
    }

    /// SpMV restricted to the row range `[lo, hi)` — the work unit of the
    /// level-blocked wavefront (levels are contiguous after BFS reordering).
    ///
    /// Hot path of every MPK variant: 4-way unrolled with unchecked loads
    /// (EXPERIMENTS.md §Perf L3-2). SAFETY: `validate()` guarantees every
    /// column index < n_cols and rowptr is monotone within bounds; callers
    /// guarantee `hi <= n_rows`, `x.len() >= n_cols`, `y.len() >= hi`.
    #[inline]
    pub fn spmv_range(&self, lo: usize, hi: usize, x: &[f64], y: &mut [f64]) {
        assert!(hi <= self.n_rows && lo <= hi);
        assert!(x.len() >= self.n_cols && y.len() >= hi);
        let rowptr = &self.rowptr;
        let colidx = &self.colidx[..];
        let values = &self.values[..];
        for r in lo..hi {
            // SAFETY: r+1 <= n_rows < rowptr.len()
            let (start, end) = unsafe {
                (*rowptr.get_unchecked(r), *rowptr.get_unchecked(r + 1))
            };
            let mut s0 = 0.0f64;
            let mut s1 = 0.0f64;
            let mut s2 = 0.0f64;
            let mut s3 = 0.0f64;
            let mut k = start;
            // SAFETY: k..end are valid nnz indices; column indices are
            // validated < n_cols <= x.len().
            unsafe {
                while k + 4 <= end {
                    s0 += values.get_unchecked(k) * x.get_unchecked(*colidx.get_unchecked(k) as usize);
                    s1 += values.get_unchecked(k + 1)
                        * x.get_unchecked(*colidx.get_unchecked(k + 1) as usize);
                    s2 += values.get_unchecked(k + 2)
                        * x.get_unchecked(*colidx.get_unchecked(k + 2) as usize);
                    s3 += values.get_unchecked(k + 3)
                        * x.get_unchecked(*colidx.get_unchecked(k + 3) as usize);
                    k += 4;
                }
                while k < end {
                    s0 += values.get_unchecked(k) * x.get_unchecked(*colidx.get_unchecked(k) as usize);
                    k += 1;
                }
                *y.get_unchecked_mut(r) = (s0 + s1) + (s2 + s3);
            }
        }
    }

    /// Structural symmetry check (pattern only).
    pub fn pattern_symmetric(&self) -> bool {
        if self.n_rows != self.n_cols {
            return false;
        }
        for r in 0..self.n_rows {
            for &c in self.row_cols(r) {
                if self.row_cols(c as usize).binary_search(&(r as u32)).is_err() {
                    return false;
                }
            }
        }
        true
    }

    /// Matrix bandwidth: `max |r - c|` over non-zeros.
    pub fn bandwidth(&self) -> usize {
        let mut bw = 0usize;
        for r in 0..self.n_rows {
            for &c in self.row_cols(r) {
                bw = bw.max(r.abs_diff(c as usize));
            }
        }
        bw
    }

    /// Symmetric permutation `B = P A P^T` with `B[i, j] = A[perm[i], perm[j]]`
    /// — i.e. `perm[i]` is the old index of new row `i` (RACE BFS reordering).
    pub fn permute_symmetric(&self, perm: &[usize]) -> CsrMatrix {
        assert_eq!(self.n_rows, self.n_cols, "symmetric permutation needs square matrix");
        assert_eq!(perm.len(), self.n_rows);
        let mut inv = vec![0usize; self.n_rows];
        for (new, &old) in perm.iter().enumerate() {
            inv[old] = new;
        }
        let mut rowptr = Vec::with_capacity(self.n_rows + 1);
        rowptr.push(0usize);
        let mut colidx = Vec::with_capacity(self.nnz());
        let mut values = Vec::with_capacity(self.nnz());
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        for new_r in 0..self.n_rows {
            let old_r = perm[new_r];
            scratch.clear();
            for k in self.rowptr[old_r]..self.rowptr[old_r + 1] {
                scratch.push((inv[self.colidx[k] as usize] as u32, self.values[k]));
            }
            scratch.sort_unstable_by_key(|&(c, _)| c);
            for &(c, v) in &scratch {
                colidx.push(c);
                values.push(v);
            }
            rowptr.push(colidx.len());
        }
        CsrMatrix::new(self.n_rows, self.n_cols, rowptr, colidx, values)
    }

    /// Extract the rows in `rows` (in order) keeping *global* column indices.
    pub fn extract_rows(&self, rows: &[usize]) -> CsrMatrix {
        let mut rowptr = Vec::with_capacity(rows.len() + 1);
        rowptr.push(0usize);
        let mut colidx = Vec::new();
        let mut values = Vec::new();
        for &r in rows {
            colidx.extend_from_slice(self.row_cols(r));
            values.extend_from_slice(self.row_vals(r));
            rowptr.push(colidx.len());
        }
        CsrMatrix { n_rows: rows.len(), n_cols: self.n_cols, rowptr, colidx, values }
    }

    /// Dense materialization (tests only; panics over ~4k rows).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        assert!(self.n_rows <= 4096, "to_dense is for small test matrices");
        let mut d = vec![vec![0.0; self.n_cols]; self.n_rows];
        for r in 0..self.n_rows {
            for k in self.rowptr[r]..self.rowptr[r + 1] {
                d[r][self.colidx[k] as usize] = self.values[k];
            }
        }
        d
    }

    /// Scale all values by `s` (used to bound spectra for power iterations).
    pub fn scale(&mut self, s: f64) {
        for v in &mut self.values {
            *v *= s;
        }
    }

    /// Infinity norm (max absolute row sum) — cheap spectral bound.
    pub fn inf_norm(&self) -> f64 {
        (0..self.n_rows)
            .map(|r| self.row_vals(r).iter().map(|v| v.abs()).sum::<f64>())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CsrMatrix {
        // [[2, 1, 0], [1, 2, 1], [0, 1, 2]]
        CsrMatrix::new(
            3,
            3,
            vec![0, 2, 5, 7],
            vec![0, 1, 0, 1, 2, 1, 2],
            vec![2.0, 1.0, 1.0, 2.0, 1.0, 1.0, 2.0],
        )
    }

    #[test]
    fn spmv_tridiag() {
        let a = small();
        let x = [1.0, 2.0, 3.0];
        let mut y = [0.0; 3];
        a.spmv(&x, &mut y);
        assert_eq!(y, [4.0, 8.0, 8.0]);
    }

    #[test]
    fn spmv_range_matches_full() {
        let a = small();
        let x = [1.0, -1.0, 0.5];
        let mut y_full = [0.0; 3];
        let mut y_rng = [9.0; 3];
        a.spmv(&x, &mut y_full);
        a.spmv_range(0, 1, &x, &mut y_rng);
        a.spmv_range(1, 3, &x, &mut y_rng);
        assert_eq!(y_full, y_rng);
    }

    #[test]
    fn validate_catches_bad_cols() {
        let m = CsrMatrix {
            n_rows: 1,
            n_cols: 1,
            rowptr: vec![0, 1],
            colidx: vec![5],
            values: vec![1.0],
        };
        assert!(m.validate().is_err());
    }

    #[test]
    fn validate_catches_unsorted_row() {
        let m = CsrMatrix {
            n_rows: 1,
            n_cols: 3,
            rowptr: vec![0, 2],
            colidx: vec![2, 1],
            values: vec![1.0, 1.0],
        };
        assert!(m.validate().is_err());
    }

    #[test]
    fn permute_symmetric_roundtrip() {
        let a = small();
        let perm = vec![2, 0, 1];
        let b = a.permute_symmetric(&perm);
        // B[i][j] == A[perm[i]][perm[j]]
        let da = a.to_dense();
        let db = b.to_dense();
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(db[i][j], da[perm[i]][perm[j]]);
            }
        }
        // identity permutation is a no-op
        let id = a.permute_symmetric(&[0, 1, 2]);
        assert_eq!(id, a);
    }

    #[test]
    fn pattern_symmetric_detects() {
        assert!(small().pattern_symmetric());
        let asym = CsrMatrix::new(2, 2, vec![0, 1, 1], vec![1], vec![1.0]);
        assert!(!asym.pattern_symmetric());
    }

    #[test]
    fn bandwidth_tridiag_is_one() {
        assert_eq!(small().bandwidth(), 1);
    }

    #[test]
    fn extract_rows_keeps_global_cols() {
        let a = small();
        let sub = a.extract_rows(&[2, 0]);
        assert_eq!(sub.n_rows, 2);
        assert_eq!(sub.row_cols(0), &[1, 2]);
        assert_eq!(sub.row_cols(1), &[0, 1]);
    }

    #[test]
    fn inf_norm() {
        assert_eq!(small().inf_norm(), 4.0);
    }
}
