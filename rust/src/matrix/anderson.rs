//! Anderson-model Hamiltonian generator (paper §7, Eq. 8).
//!
//! Stands in for the ScaMaC generator: a single-particle tight-binding
//! Hamiltonian on an `lx × ly × lz` cubic lattice with uncorrelated uniform
//! disorder `w_r ∈ [-1, 1]` scaled by `W/2` on the diagonal, hopping `-t`
//! along x and `-t_perp` along y/z (the weakly-coupled-chains variant used
//! for the quantum-boomerang study; `t_perp == t` recovers the isotropic
//! model). Open boundary conditions; site index `r = x + lx·(y + ly·z)`.

use crate::matrix::CsrMatrix;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct AndersonConfig {
    pub lx: usize,
    pub ly: usize,
    pub lz: usize,
    /// Disorder strength W (diagonal is `W/2 · w_r`).
    pub w: f64,
    /// Hopping along x.
    pub t: f64,
    /// Hopping along y and z (`t_perp < t` = weakly coupled chains).
    pub t_perp: f64,
    pub seed: u64,
}

impl AndersonConfig {
    pub fn isotropic(l: usize, w: f64, seed: u64) -> Self {
        Self { lx: l, ly: l, lz: l, w, t: 1.0, t_perp: 1.0, seed }
    }

    pub fn n_sites(&self) -> usize {
        self.lx * self.ly * self.lz
    }

    #[inline]
    pub fn site(&self, x: usize, y: usize, z: usize) -> usize {
        x + self.lx * (y + self.ly * z)
    }
}

/// Build the Anderson Hamiltonian as a CRS matrix.
///
/// Builds CSR directly (no COO assembly): the stencil structure is known, so
/// each row's sorted neighbor list is emitted in one pass — this keeps
/// multi-GiB weak-scaling lattices (Table 5 ladder) fast to generate.
pub fn anderson(cfg: &AndersonConfig) -> CsrMatrix {
    let n = cfg.n_sites();
    let (lx, ly, lz) = (cfg.lx, cfg.ly, cfg.lz);
    // disorder drawn in site order so the matrix is independent of the
    // assembly strategy (must match the historical COO ordering)
    let mut rng = Rng::new(cfg.seed);
    let mut diag = Vec::with_capacity(n);
    for _ in 0..n {
        diag.push(0.5 * cfg.w * rng.range_f64(-1.0, 1.0));
    }

    let mut rowptr = Vec::with_capacity(n + 1);
    rowptr.push(0usize);
    // 7-point upper bound on nnz
    let mut colidx: Vec<u32> = Vec::with_capacity(7 * n);
    let mut values: Vec<f64> = Vec::with_capacity(7 * n);
    for z in 0..lz {
        for y in 0..ly {
            for x in 0..lx {
                let r = cfg.site(x, y, z);
                // neighbors in ascending column order:
                // -z, -y, -x, diag, +x, +y, +z
                if z > 0 && cfg.t_perp != 0.0 {
                    colidx.push((r - lx * ly) as u32);
                    values.push(-cfg.t_perp);
                }
                if y > 0 && cfg.t_perp != 0.0 {
                    colidx.push((r - lx) as u32);
                    values.push(-cfg.t_perp);
                }
                if x > 0 && cfg.t != 0.0 {
                    colidx.push((r - 1) as u32);
                    values.push(-cfg.t);
                }
                if diag[r] != 0.0 {
                    colidx.push(r as u32);
                    values.push(diag[r]);
                }
                if x + 1 < lx && cfg.t != 0.0 {
                    colidx.push((r + 1) as u32);
                    values.push(-cfg.t);
                }
                if y + 1 < ly && cfg.t_perp != 0.0 {
                    colidx.push((r + lx) as u32);
                    values.push(-cfg.t_perp);
                }
                if z + 1 < lz && cfg.t_perp != 0.0 {
                    colidx.push((r + lx * ly) as u32);
                    values.push(-cfg.t_perp);
                }
                rowptr.push(colidx.len());
            }
        }
    }
    CsrMatrix::new(n, n, rowptr, colidx, values)
}

/// Paper Table 5 weak-scaling ladder: per-domain matrix held at ~constant
/// CRS size by doubling one dimension per step, innermost (x) doubled last
/// "to respect layer conditions for cache blocking".
///
/// `base_l` is the cube edge at 1 domain (paper: 160; scaled down here).
pub fn weak_scaling_configs(base_l: usize, domains: &[usize], w: f64, seed: u64) -> Vec<AndersonConfig> {
    domains
        .iter()
        .map(|&d| {
            assert!(d.is_power_of_two(), "domain counts must be powers of two");
            let k = d.trailing_zeros() as usize;
            // double z, then y, then x, cyclically (innermost x last)
            let mut dims = [base_l, base_l, base_l]; // x, y, z
            for i in 0..k {
                let axis = 2 - (i % 3); // z, y, x, z, y, x, ...
                dims[axis] *= 2;
            }
            AndersonConfig { lx: dims[0], ly: dims[1], lz: dims[2], w, t: 1.0, t_perp: 1.0, seed }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anderson_is_symmetric_7pt() {
        let cfg = AndersonConfig::isotropic(8, 1.0, 3);
        let a = anderson(&cfg);
        assert_eq!(a.n_rows(), 512);
        assert!(a.pattern_symmetric());
        // interior site: diag + 6 neighbors
        let r = cfg.site(4, 4, 4);
        assert_eq!(a.row_cols(r).len(), 7);
        // exact count: n diag + 2*3*l^2*(l-1) hopping = 512 + 2688 for l = 8;
        // nnzr -> 7.0 as l grows (paper Table 5 uses l >= 160).
        assert_eq!(a.nnz(), 512 + 2 * 3 * 8 * 8 * 7);
    }

    #[test]
    fn disorder_bounded_by_w_half() {
        let cfg = AndersonConfig::isotropic(6, 4.0, 9);
        let a = anderson(&cfg);
        for r in 0..a.n_rows() {
            let idx = a.row_cols(r).binary_search(&(r as u32)).unwrap();
            let d = a.row_vals(r)[idx];
            assert!(d.abs() <= 2.0, "diag {d} exceeds W/2");
        }
    }

    #[test]
    fn anisotropic_hopping() {
        let cfg = AndersonConfig { lx: 4, ly: 4, lz: 4, w: 0.0, t: 1.0, t_perp: 0.001, seed: 1 };
        let a = anderson(&cfg);
        let r = cfg.site(1, 1, 1);
        let cols = a.row_cols(r);
        let vals = a.row_vals(r);
        for (c, v) in cols.iter().zip(vals) {
            let c = *c as usize;
            if c == cfg.site(0, 1, 1) || c == cfg.site(2, 1, 1) {
                assert_eq!(*v, -1.0);
            } else if c != r {
                assert_eq!(*v, -0.001);
            }
        }
    }

    #[test]
    fn weak_scaling_doubles_sites() {
        let cfgs = weak_scaling_configs(16, &[1, 2, 4, 8], 1.0, 0);
        let sizes: Vec<usize> = cfgs.iter().map(|c| c.n_sites()).collect();
        assert_eq!(sizes, vec![4096, 8192, 16384, 32768]);
        // x doubled last: after 3 doublings dims are (32, 32, 32)
        assert_eq!((cfgs[3].lx, cfgs[3].ly, cfgs[3].lz), (32, 32, 32));
    }

    #[test]
    fn deterministic_for_seed() {
        let cfg = AndersonConfig::isotropic(5, 2.0, 77);
        assert_eq!(anderson(&cfg), anderson(&cfg));
    }
}
