//! Deterministic matrix generators.
//!
//! These stand in for the paper's SuiteSparse selection (Table 4) and the
//! Lynx matrices, which are not redistributable / not downloadable in this
//! environment (DESIGN.md §Substitutions). Cache blocking behavior is
//! governed by N_r, N_nzr, and the level structure (bandwidth), all of which
//! the generators control directly, so the *shape* of every experiment is
//! preserved: who wins, roughly by how much, and where the cache boundary
//! crossover falls.

use crate::matrix::{CooMatrix, CsrMatrix};
use crate::util::rng::Rng;

/// 1D tridiagonal stencil (paper Fig. 4's example): 2 on the diagonal,
/// -1 off-diagonal.
pub fn tridiag(n: usize) -> CsrMatrix {
    let mut coo = CooMatrix::new(n, n);
    for i in 0..n {
        coo.push(i, i, 2.0);
        if i > 0 {
            coo.push(i, i - 1, -1.0);
        }
        if i + 1 < n {
            coo.push(i, i + 1, -1.0);
        }
    }
    coo.to_csr()
}

/// 2D 5-point Laplacian stencil on an `nx × ny` grid (paper Fig. 1).
pub fn stencil_2d_5pt(nx: usize, ny: usize) -> CsrMatrix {
    let n = nx * ny;
    let mut coo = CooMatrix::new(n, n);
    for y in 0..ny {
        for x in 0..nx {
            let r = y * nx + x;
            coo.push(r, r, 4.0);
            if x > 0 {
                coo.push(r, r - 1, -1.0);
            }
            if x + 1 < nx {
                coo.push(r, r + 1, -1.0);
            }
            if y > 0 {
                coo.push(r, r - nx, -1.0);
            }
            if y + 1 < ny {
                coo.push(r, r + nx, -1.0);
            }
        }
    }
    coo.to_csr()
}

/// 3D 7-point Laplacian stencil on an `nx × ny × nz` grid.
pub fn stencil_3d_7pt(nx: usize, ny: usize, nz: usize) -> CsrMatrix {
    let n = nx * ny * nz;
    let mut coo = CooMatrix::new(n, n);
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let r = (z * ny + y) * nx + x;
                coo.push(r, r, 6.0);
                if x > 0 {
                    coo.push(r, r - 1, -1.0);
                }
                if x + 1 < nx {
                    coo.push(r, r + 1, -1.0);
                }
                if y > 0 {
                    coo.push(r, r - nx, -1.0);
                }
                if y + 1 < ny {
                    coo.push(r, r + nx, -1.0);
                }
                if z > 0 {
                    coo.push(r, r - nx * ny, -1.0);
                }
                if z + 1 < nz {
                    coo.push(r, r + nx * ny, -1.0);
                }
            }
        }
    }
    coo.to_csr()
}

/// 3D 27-point stencil (dense corner coupling) — the nlpkkt-like "bad
/// structure" end of the spectrum when combined with a large grid.
pub fn stencil_3d_27pt(nx: usize, ny: usize, nz: usize) -> CsrMatrix {
    let n = nx * ny * nz;
    let mut coo = CooMatrix::new(n, n);
    for z in 0..nz as isize {
        for y in 0..ny as isize {
            for x in 0..nx as isize {
                let r = ((z as usize * ny) + y as usize) * nx + x as usize;
                for dz in -1..=1isize {
                    for dy in -1..=1isize {
                        for dx in -1..=1isize {
                            let (xx, yy, zz) = (x + dx, y + dy, z + dz);
                            if xx < 0 || yy < 0 || zz < 0 {
                                continue;
                            }
                            let (xx, yy, zz) = (xx as usize, yy as usize, zz as usize);
                            if xx >= nx || yy >= ny || zz >= nz {
                                continue;
                            }
                            let c = (zz * ny + yy) * nx + xx;
                            let v = if c == r { 26.0 } else { -1.0 };
                            coo.push(r, c, v);
                        }
                    }
                }
            }
        }
    }
    coo.to_csr()
}

/// Symmetric random banded matrix: every row gets ~`nnzr` non-zeros whose
/// column offsets are clumped within `±band` of the diagonal (squared-uniform
/// sampling concentrates them near the diagonal, mimicking FEM clustering).
/// Diagonal is always present and dominant, so power iterations stay bounded
/// after [`CsrMatrix::scale`].
pub fn random_banded_sym(n: usize, nnzr: usize, band: usize, seed: u64) -> CsrMatrix {
    assert!(band >= 1 && nnzr >= 1);
    let mut rng = Rng::new(seed);
    let mut coo = CooMatrix::new(n, n);
    // Each mirrored off-diagonal pair contributes 2 nnz; target per-row count.
    let upper_per_row = (nnzr.saturating_sub(1)) / 2;
    for r in 0..n {
        coo.push(r, r, nnzr as f64); // diagonally dominant
        for _ in 0..upper_per_row {
            // squared-uniform: offsets cluster near the diagonal
            let u = rng.f64();
            let off = 1 + ((u * u) * band as f64) as usize;
            if r + off < n {
                let v = -rng.f64();
                coo.push(r, r + off, v);
                coo.push(r + off, r, v);
            }
        }
    }
    coo.to_csr()
}

/// An entry of the synthetic benchmark suite (Table 4 analogue).
#[derive(Clone)]
pub struct SuiteEntry {
    /// `<paper-name>-s` ("-s" = scaled synthetic analogue).
    pub name: &'static str,
    /// Paper value, for the printed comparison.
    pub paper_nnzr: f64,
    /// Rows at `scale = 1.0` (for size targeting in benches).
    pub base_rows: usize,
    pub build: fn(f64) -> CsrMatrix,
}

impl SuiteEntry {
    /// CRS bytes estimate at `scale = 1.0`.
    pub fn base_bytes(&self) -> usize {
        crate::matrix::crs_bytes(self.base_rows, (self.base_rows as f64 * self.paper_nnzr) as usize)
    }

    /// Scale needed so the matrix is roughly `target_bytes` in CRS.
    pub fn scale_for_bytes(&self, target_bytes: usize) -> f64 {
        target_bytes as f64 / self.base_bytes() as f64
    }
}

/// Benchmark suite mirroring Table 4: one synthetic analogue per paper
/// matrix family, ordered by CRS size at `scale = 1.0` (like the paper's
/// size ordering). `scale` multiplies the row count, so benches can place
/// the suite around *this* host's cache boundary the way the paper's suite
/// straddles the SPR/MIL cache sizes.
pub fn suite() -> Vec<SuiteEntry> {
    fn rows(scale: f64, base: usize) -> usize {
        ((base as f64 * scale) as usize).max(512)
    }
    vec![
        SuiteEntry {
            name: "inline_1-s",
            base_rows: 60000,
            paper_nnzr: 73.0,
            build: |s| random_banded_sym(rows(s, 60_000), 73, 1_200, 101),
        },
        SuiteEntry {
            name: "Emilia_923-s",
            base_rows: 110000,
            paper_nnzr: 44.4,
            build: |s| random_banded_sym(rows(s, 110_000), 44, 1_500, 102),
        },
        SuiteEntry {
            name: "ldoor-s",
            base_rows: 115000,
            paper_nnzr: 48.8,
            build: |s| random_banded_sym(rows(s, 115_000), 49, 1_000, 103),
        },
        SuiteEntry {
            name: "af_shell10-s",
            base_rows: 180000,
            paper_nnzr: 34.9,
            build: |s| random_banded_sym(rows(s, 180_000), 35, 800, 104),
        },
        SuiteEntry {
            name: "Serena-s",
            base_rows: 165000,
            paper_nnzr: 46.3,
            build: |s| random_banded_sym(rows(s, 165_000), 46, 2_000, 105),
        },
        SuiteEntry {
            name: "bone010-s",
            base_rows: 120000,
            paper_nnzr: 72.6,
            build: |s| random_banded_sym(rows(s, 120_000), 73, 1_500, 106),
        },
        SuiteEntry {
            name: "audikw_1-s",
            base_rows: 115000,
            paper_nnzr: 82.2,
            build: |s| random_banded_sym(rows(s, 115_000), 82, 2_500, 107),
        },
        SuiteEntry {
            name: "channel-500-s",
            base_rows: 580000,
            paper_nnzr: 17.7,
            build: |s| random_banded_sym(rows(s, 580_000), 18, 300, 113),
        },
        SuiteEntry {
            name: "dielFilter-s",
            base_rows: 135000,
            paper_nnzr: 80.9,
            build: |s| random_banded_sym(rows(s, 135_000), 81, 3_000, 108),
        },
        SuiteEntry {
            name: "nlpkkt120-s",
            base_rows: 175616,
            paper_nnzr: 27.3,
            // x-dimension scales linearly with `s` (rows ∝ s, like the
            // banded entries), keeping ny = nz fixed
            build: |s| stencil_3d_27pt(((56.0 * s) as usize).max(8), 56, 56),
        },
        SuiteEntry {
            name: "ML_Geer-s",
            base_rows: 185000,
            paper_nnzr: 73.7,
            build: |s| random_banded_sym(rows(s, 185_000), 74, 1_800, 109),
        },
        SuiteEntry {
            name: "Lynx68-s",
            base_rows: 820000,
            paper_nnzr: 16.3,
            build: |s| random_banded_sym(rows(s, 820_000), 16, 500, 114),
        },
        SuiteEntry {
            name: "Flan_1565-s",
            base_rows: 190000,
            paper_nnzr: 75.0,
            build: |s| random_banded_sym(rows(s, 190_000), 75, 2_200, 110),
        },
        SuiteEntry {
            name: "Bump_2911-s",
            base_rows: 350000,
            paper_nnzr: 43.9,
            build: |s| random_banded_sym(rows(s, 350_000), 44, 2_800, 111),
        },
        SuiteEntry {
            name: "Queen_4147-s",
            base_rows: 500000,
            paper_nnzr: 79.5,
            build: |s| random_banded_sym(rows(s, 500_000), 80, 4_000, 112),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tridiag_structure() {
        let a = tridiag(5);
        assert_eq!(a.nnz(), 13);
        assert!(a.pattern_symmetric());
        assert_eq!(a.bandwidth(), 1);
    }

    #[test]
    fn stencil_2d_counts() {
        let a = stencil_2d_5pt(4, 4);
        assert_eq!(a.n_rows(), 16);
        // 16 diag + 2*(3*4 + 3*4) off-diag = 16 + 48 = 64
        assert_eq!(a.nnz(), 64);
        assert!(a.pattern_symmetric());
    }

    #[test]
    fn stencil_3d_7pt_interior_row() {
        let a = stencil_3d_7pt(5, 5, 5);
        // interior vertex has 7 nnz
        let r = (2 * 5 + 2) * 5 + 2;
        assert_eq!(a.row_cols(r).len(), 7);
        assert!(a.pattern_symmetric());
    }

    #[test]
    fn stencil_27pt_interior_row() {
        let a = stencil_3d_27pt(4, 4, 4);
        let r = (1 * 4 + 1) * 4 + 1;
        assert_eq!(a.row_cols(r).len(), 27);
        assert!(a.pattern_symmetric());
    }

    #[test]
    fn random_banded_is_symmetric_and_banded() {
        let a = random_banded_sym(2_000, 20, 100, 42);
        assert!(a.pattern_symmetric());
        assert!(a.bandwidth() <= 101);
        let nnzr = a.nnzr();
        assert!((12.0..=22.0).contains(&nnzr), "nnzr = {nnzr}");
        assert!(a.validate().is_ok());
    }

    #[test]
    fn random_banded_deterministic() {
        let a = random_banded_sym(500, 10, 50, 7);
        let b = random_banded_sym(500, 10, 50, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn suite_builds_small_scale() {
        for e in suite() {
            let a = (e.build)(0.01);
            assert!(a.n_rows() >= 512, "{} too small", e.name);
            assert!(a.validate().is_ok(), "{} invalid", e.name);
            assert!(a.pattern_symmetric(), "{} asymmetric", e.name);
        }
    }
}
