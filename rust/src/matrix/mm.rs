//! MatrixMarket coordinate-format IO (the SuiteSparse interchange format).
//!
//! Supports `matrix coordinate real|integer|pattern general|symmetric`.
//! Symmetric files are expanded to full storage on read (the convention the
//! rest of the crate expects).

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::matrix::{CooMatrix, CsrMatrix};

/// Read a MatrixMarket file into CRS.
pub fn read_matrix_market(path: &Path) -> Result<CsrMatrix> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    read_from(BufReader::new(f))
}

pub fn read_from<R: BufRead>(mut r: R) -> Result<CsrMatrix> {
    let mut header = String::new();
    r.read_line(&mut header)?;
    let h: Vec<&str> = header.trim().split_whitespace().collect();
    if h.len() < 5 || h[0] != "%%MatrixMarket" || h[1] != "matrix" || h[2] != "coordinate" {
        bail!("unsupported MatrixMarket header: {header:?}");
    }
    let field = h[3]; // real | integer | pattern
    let sym = h[4]; // general | symmetric
    if !matches!(field, "real" | "integer" | "pattern") {
        bail!("unsupported field type {field}");
    }
    if !matches!(sym, "general" | "symmetric") {
        bail!("unsupported symmetry {sym}");
    }

    let mut line = String::new();
    // skip comments
    loop {
        line.clear();
        if r.read_line(&mut line)? == 0 {
            bail!("missing size line");
        }
        if !line.trim_start().starts_with('%') && !line.trim().is_empty() {
            break;
        }
    }
    let dims: Vec<usize> = line
        .split_whitespace()
        .map(|t| t.parse::<usize>().context("bad size line"))
        .collect::<Result<_>>()?;
    if dims.len() != 3 {
        bail!("size line must have 3 entries, got {line:?}");
    }
    let (n_rows, n_cols, nnz) = (dims[0], dims[1], dims[2]);
    let mut coo = CooMatrix::new(n_rows, n_cols);
    let mut seen = 0usize;
    loop {
        line.clear();
        if r.read_line(&mut line)? == 0 {
            break;
        }
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let i: usize = it.next().context("row missing")?.parse()?;
        let j: usize = it.next().context("col missing")?.parse()?;
        let v: f64 = if field == "pattern" {
            1.0
        } else {
            it.next().context("value missing")?.parse()?
        };
        if i < 1 || i > n_rows || j < 1 || j > n_cols {
            bail!("entry ({i},{j}) out of bounds");
        }
        coo.push(i - 1, j - 1, v);
        if sym == "symmetric" && i != j {
            coo.push(j - 1, i - 1, v);
        }
        seen += 1;
    }
    if seen != nnz {
        bail!("expected {nnz} entries, found {seen}");
    }
    Ok(coo.to_csr())
}

/// Write CRS as `matrix coordinate real general`.
pub fn write_matrix_market(a: &CsrMatrix, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(f, "% written by dlb-mpk")?;
    writeln!(f, "{} {} {}", a.n_rows, a.n_cols, a.nnz())?;
    for r in 0..a.n_rows {
        for k in a.rowptr[r]..a.rowptr[r + 1] {
            writeln!(f, "{} {} {:.17e}", r + 1, a.colidx[k] + 1, a.values[k])?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    #[test]
    fn roundtrip_general() {
        let a = gen::stencil_2d_5pt(6, 5);
        let dir = std::env::temp_dir().join("dlbmpk_mm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("a.mtx");
        write_matrix_market(&a, &p).unwrap();
        let b = read_matrix_market(&p).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn reads_symmetric_expanded() {
        let text = "%%MatrixMarket matrix coordinate real symmetric\n3 3 4\n1 1 2.0\n2 1 -1.0\n2 2 2.0\n3 3 1.5\n";
        let a = read_from(text.as_bytes()).unwrap();
        assert_eq!(a.nnz(), 5); // off-diag mirrored
        let d = a.to_dense();
        assert_eq!(d[0][1], -1.0);
        assert_eq!(d[1][0], -1.0);
    }

    #[test]
    fn reads_pattern() {
        let text = "%%MatrixMarket matrix coordinate pattern general\n2 2 2\n1 1\n2 2\n";
        let a = read_from(text.as_bytes()).unwrap();
        assert_eq!(a.nnz(), 2);
        assert_eq!(a.values, vec![1.0, 1.0]);
    }

    #[test]
    fn rejects_bad_header() {
        assert!(read_from("%%MatrixMarket matrix array real general\n1 1 1\n".as_bytes()).is_err());
    }

    #[test]
    fn rejects_count_mismatch() {
        let text = "%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 1.0\n";
        assert!(read_from(text.as_bytes()).is_err());
    }
}
