//! `dlb-mpk` — CLI launcher for the DLB-MPK library.
//!
//! Subcommands:
//!   run        run TRAD vs DLB on a matrix and report performance
//!   ca         run CA-MPK and report its overheads
//!   verify     statically check plans/schedules, print JSON diagnostics
//!   suite      list the Table-4 synthetic benchmark suite
//!   bandwidth  measure the load-only bandwidth ladder (Fig. 7)
//!   anderson   Chebyshev propagation demo on the Anderson model
//!   launch     spawn N rank processes running one command SPMD (the
//!              multi-process socket transport's launcher)
//!   sweep      one engine sweep, dumped as executor-independent JSON
//!              (bit-exact hex doubles — the cross-executor test oracle)
//!
//! Examples:
//!   dlb-mpk run --matrix banded:400000,12,2000 --ranks 4 --pm 6 --cache-mib 8
//!   dlb-mpk run --matrix suite:Serena-s,0.5 --ranks 2 --pm 4
//!   dlb-mpk anderson --l 32 --w 1.0 --steps 5
//!   dlb-mpk launch --np 2 -- anderson --l 16 --executor processes
//!   dlb-mpk bandwidth --max-mib 512

use anyhow::{bail, Context, Result};

use dlb_mpk::coordinator::{self, MatrixSpec, Report, RunConfig};
use dlb_mpk::exec::ExecutorKind;
use dlb_mpk::matrix::gen;
use dlb_mpk::partition::Method;
use dlb_mpk::util::mib;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    // trace-check takes a positional path, which Flags::parse would reject
    if cmd == "trace-check" {
        return cmd_trace_check(&args[1..]);
    }
    // launch takes the child command line after `--`, which Flags::parse
    // would also reject
    if cmd == "launch" {
        return cmd_launch(&args[1..]);
    }
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "run" => cmd_run(&flags),
        "ca" => cmd_ca(&flags),
        "verify" => cmd_verify(&flags),
        "suite" => cmd_suite(&flags),
        "bandwidth" => cmd_bandwidth(&flags),
        "anderson" => cmd_anderson(&flags),
        "sweep" => cmd_sweep(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `dlb-mpk help`)"),
    }
}

fn print_usage() {
    println!("{}", include_str_usage());
}

fn include_str_usage() -> &'static str {
    "dlb-mpk — Distributed Level-Blocked Matrix Power Kernels\n\
     \n\
     USAGE: dlb-mpk <command> [flags]\n\
     \n\
     COMMANDS:\n\
       run        TRAD vs DLB performance on one matrix\n\
       ca         CA-MPK baseline overheads\n\
       verify     static race & communication-plan check of the TRAD, CA,\n\
                  and DLB plans for one configuration; prints a JSON report;\n\
                  --rule ID filters to one rule (see docs/VERIFY.md); exits\n\
                  0 clean, 1 on usage errors, 2 on diagnostics\n\
       suite      print the Table-4 synthetic suite\n\
       bandwidth  load-only bandwidth ladder (Fig. 7)\n\
       anderson   Chebyshev/Anderson propagation demo (Fig. 11)\n\
       trace-check PATH [--min-ranks N]   validate a chrome trace JSON\n\
       launch --np N [--sock-dir D] [--timeout-ms T] -- <cmd> [flags]\n\
                  spawn N copies of this binary running `<cmd>` SPMD, one\n\
                  OS process per rank, wired up over Unix-domain sockets\n\
                  (sets DLB_MPK_RANK/WORLD/SOCK_DIR; rank 0 keeps stdout);\n\
                  the command should pass --executor processes\n\
       sweep      run one engine sweep and dump powers + counters as JSON\n\
                  with hex-encoded doubles; the dump is byte-identical\n\
                  across executors (--variant trad|ca|dlb, --out PATH,\n\
                  --die-rank R to simulate a rank failure)\n\
     \n\
     COMMON FLAGS:\n\
       --matrix SPEC    stencil2d:NX,NY | stencil3d:NX,NY,NZ |\n\
                        banded:N,NNZR,BAND[,SEED] | anderson:L[,W[,SEED]] |\n\
                        suite:NAME[,SCALE] | file:PATH\n\
       --ranks N        simulated MPI ranks (default 1)\n\
       --pm P           power p_m (default 4)\n\
       --cache-mib C    DLB cache budget (default 16)\n\
       --partitioner M  block | greedy | bisect (default bisect)\n\
       --executor E     sim | threads[(N)] | processes[(N)]  (default sim;\n\
                        threads = one OS thread per rank, measured\n\
                        wall-clock; processes = one OS process per rank\n\
                        over Unix sockets, run under `dlb-mpk launch`;\n\
                        the (N) forms override --ranks)\n\
       --inner-threads K  within-rank worker threads (default 1 = serial;\n\
                        K >= 2 row-splits each rank's compute across K\n\
                        participants, bitwise identical to serial)\n\
       --reps R         timing repetitions (default 5)\n\
       --no-validate    skip TRAD/DLB equivalence check\n\
       --async-remainder  pipeline DLB's remainder rounds: complete halo\n\
                        receives in arrival order and advance each peer\n\
                        segment's rows while the rest is still in flight\n\
                        (bitwise identical to the lockstep path)\n\
       --trace-out PATH (anderson) record per-rank spans, write a Chrome\n\
                        Trace Event JSON (chrome://tracing / Perfetto) and\n\
                        print a metrics summary\n"
}

struct Flags(std::collections::BTreeMap<String, String>);

impl Flags {
    fn parse(args: &[String]) -> Result<Self> {
        let mut m = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if !a.starts_with("--") {
                bail!("unexpected argument {a:?}");
            }
            let key = a.trim_start_matches("--").to_string();
            let boolean = matches!(key.as_str(), "no-validate" | "fast" | "async-remainder");
            if boolean {
                m.insert(key, "true".into());
                i += 1;
            } else {
                let v = args.get(i + 1).with_context(|| format!("flag --{key} needs a value"))?;
                m.insert(key, v.clone());
                i += 2;
            }
        }
        Ok(Self(m))
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.0.get(k).map(|s| s.as_str())
    }

    fn usize(&self, k: &str, default: usize) -> Result<usize> {
        self.get(k).map_or(Ok(default), |v| v.parse().with_context(|| format!("--{k}")))
    }

    fn f64(&self, k: &str, default: f64) -> Result<f64> {
        self.get(k).map_or(Ok(default), |v| v.parse().with_context(|| format!("--{k}")))
    }

    fn has(&self, k: &str) -> bool {
        self.get(k) == Some("true")
    }
}

fn parse_matrix(spec: &str) -> Result<MatrixSpec> {
    let (kind, rest) = spec.split_once(':').unwrap_or((spec, ""));
    fn nums(s: &str) -> Vec<&str> {
        s.split(',').filter(|t| !t.is_empty()).collect()
    }
    Ok(match kind {
        "stencil2d" => {
            let p = nums(rest);
            anyhow::ensure!(p.len() == 2, "stencil2d:NX,NY");
            MatrixSpec::Stencil2D { nx: p[0].parse()?, ny: p[1].parse()? }
        }
        "stencil3d" => {
            let p = nums(rest);
            anyhow::ensure!(p.len() == 3, "stencil3d:NX,NY,NZ");
            MatrixSpec::Stencil3D { nx: p[0].parse()?, ny: p[1].parse()?, nz: p[2].parse()? }
        }
        "banded" => {
            let p = nums(rest);
            anyhow::ensure!(p.len() >= 3, "banded:N,NNZR,BAND[,SEED]");
            MatrixSpec::Banded {
                n: p[0].parse()?,
                nnzr: p[1].parse()?,
                band: p[2].parse()?,
                seed: p.get(3).map_or(Ok(1), |s| s.parse())?,
            }
        }
        "anderson" => {
            let p = nums(rest);
            anyhow::ensure!(!p.is_empty(), "anderson:L[,W[,SEED]]");
            MatrixSpec::Anderson {
                l: p[0].parse()?,
                w: p.get(1).map_or(Ok(1.0), |s| s.parse())?,
                seed: p.get(2).map_or(Ok(1), |s| s.parse())?,
            }
        }
        "suite" => {
            let p = nums(rest);
            anyhow::ensure!(!p.is_empty(), "suite:NAME[,SCALE]");
            MatrixSpec::Suite {
                name: p[0].to_string(),
                scale: p.get(1).map_or(Ok(1.0), |s| s.parse())?,
            }
        }
        "file" => MatrixSpec::File { path: rest.into() },
        other => bail!("unknown matrix kind {other:?}"),
    })
}

fn config(flags: &Flags) -> Result<RunConfig> {
    let matrix = parse_matrix(flags.get("matrix").unwrap_or("stencil2d:256,256"))?;
    let partitioner = Method::parse(flags.get("partitioner").unwrap_or("bisect"))
        .context("--partitioner must be block|greedy|bisect")?;
    let executor = ExecutorKind::parse(flags.get("executor").unwrap_or("sim"))
        .context("--executor must be sim|threads[(N)]|processes[(N)]")?;
    Ok(RunConfig {
        matrix,
        n_ranks: flags.usize("ranks", 1)?,
        partitioner,
        p_m: flags.usize("pm", 4)?,
        cache_bytes: flags.usize("cache-mib", 16)? << 20,
        s_m: flags.usize("sm", 50)?,
        reps: flags.usize("reps", 5)?,
        validate: !flags.has("no-validate"),
        executor,
        inner_threads: flags.usize("inner-threads", 1)?.max(1),
        async_remainder: flags.has("async-remainder"),
    })
}

fn cmd_run(flags: &Flags) -> Result<()> {
    let cfg = config(flags)?;
    let out = coordinator::run(&cfg)?;
    Report::print_header();
    for r in &out.reports {
        r.print_row();
    }
    let speedup = out.reports[0].time.median_s / out.reports[1].time.median_s;
    let inner = if cfg.inner_threads > 1 {
        format!(" x {} inner threads/rank", cfg.inner_threads)
    } else {
        String::new()
    };
    println!("\nexecutor: {}{inner} | DLB speedup over TRAD: {speedup:.2}x", cfg.executor);
    Ok(())
}

fn cmd_ca(flags: &Flags) -> Result<()> {
    let cfg = config(flags)?;
    let (rep, ov) = coordinator::driver::run_ca(&cfg)?;
    Report::print_header();
    rep.print_row();
    println!(
        "\nCA overheads: base halo {} | extra halo {} ({:.2}% of rows) | redundant nnz {} ({:.2}% of nnz)",
        ov.base_halo,
        ov.extra_halo,
        100.0 * ov.rel_extra_halo(rep.n_rows),
        ov.redundant_nnz,
        100.0 * ov.rel_redundant(rep.nnz),
    );
    Ok(())
}

fn cmd_verify(flags: &Flags) -> Result<()> {
    use dlb_mpk::distsim::DistMatrix;
    use dlb_mpk::mpk::{ca, dlb};
    use dlb_mpk::partition::partition;
    use dlb_mpk::verify::{Rule, Verifier};

    // Exit codes are machine-readable (docs/VERIFY.md): 0 = clean,
    // 1 = usage/build errors (via real_main), 2 = diagnostics found.
    let rule = flags
        .get("rule")
        .map(|id| {
            Rule::parse(id).with_context(|| {
                format!("unknown rule ID {id:?} (see docs/VERIFY.md for the {} IDs)", Rule::ALL.len())
            })
        })
        .transpose()?;
    let cfg = config(flags)?;
    let a = cfg.matrix.build()?;
    let part = partition(&a, cfg.n_ranks, cfg.partitioner);
    let dist = DistMatrix::build(&a, &part);
    let v = Verifier::with_inner_threads(cfg.inner_threads);

    let mut trad = v.check_trad(&dist, cfg.p_m);
    let ca_plan = ca::ca_exec_plan(&a, &dist, cfg.p_m);
    let mut ca_rep = v.check_ca(&dist, &ca_plan);
    let opts = dlb::DlbOptions {
        cache_bytes: cfg.cache_bytes,
        s_m: cfg.s_m,
        async_remainder: cfg.async_remainder,
    };
    let plan = dlb::plan(&dist, cfg.p_m, &opts);
    let mut dlb_rep = v.check_all(&plan.dist, &plan.ranks, cfg.p_m);

    let rule_field = match rule {
        Some(r) => {
            trad.retain_rule(r);
            ca_rep.retain_rule(r);
            dlb_rep.retain_rule(r);
            format!("\"{}\"", r.id())
        }
        None => "null".to_string(),
    };
    let ok = trad.is_ok() && ca_rep.is_ok() && dlb_rep.is_ok();
    println!(
        "{{\"ok\": {ok}, \"ranks\": {}, \"pm\": {}, \"rule\": {rule_field}, \"variants\": \
         {{\"trad\": {}, \"ca\": {}, \"dlb\": {}}}}}",
        dist.n_ranks(),
        cfg.p_m,
        trad.to_json(),
        ca_rep.to_json(),
        dlb_rep.to_json(),
    );
    if !ok {
        // Not a bail: diagnostics are the *output*, reported above, and the
        // distinct exit code lets scripts tell "plan is unsafe" (2) apart
        // from "I was invoked wrong" (1).
        std::process::exit(2);
    }
    Ok(())
}

fn cmd_suite(flags: &Flags) -> Result<()> {
    let scale = flags.f64("scale", 0.25)?;
    println!(
        "{:<16} {:>10} {:>12} {:>7} {:>9}  (scale {scale})",
        "name", "N_r", "N_nz", "N_nzr", "CRS MiB"
    );
    for e in gen::suite() {
        let a = (e.build)(scale);
        println!(
            "{:<16} {:>10} {:>12} {:>7.1} {:>9}",
            e.name,
            a.n_rows(),
            a.nnz(),
            a.nnzr(),
            mib(a.crs_bytes())
        );
    }
    Ok(())
}

fn cmd_bandwidth(flags: &Flags) -> Result<()> {
    let max_mib = flags.usize("max-mib", 256)?;
    println!("{:>12} {:>10}", "bytes", "GB/s");
    for p in dlb_mpk::perf::bandwidth::bandwidth_sweep(64 << 10, max_mib << 20, 3) {
        println!("{:>12} {:>10.2}", p.bytes, p.gb_per_s);
    }
    Ok(())
}

fn cmd_anderson(flags: &Flags) -> Result<()> {
    use dlb_mpk::apps::chebyshev::{wave_packet, ChebyshevConfig, ChebyshevPropagator};
    use dlb_mpk::apps::observables::center_of_mass;
    use dlb_mpk::distsim::DistMatrix;
    use dlb_mpk::engine::{BackendSpec, EngineConfig, Variant};
    use dlb_mpk::matrix::anderson::{anderson, AndersonConfig};
    use dlb_mpk::mpk::dlb::DlbOptions;
    use dlb_mpk::partition::partition;

    let l = flags.usize("l", 24)?;
    let w = flags.f64("w", 1.0)?;
    let steps = flags.usize("steps", 5)?;
    let trace_out = flags.get("trace-out").map(str::to_string);
    let executor = ExecutorKind::parse(flags.get("executor").unwrap_or("sim"))
        .context("--executor must be sim|threads[(N)]|processes[(N)]")?;
    let ranks = executor.ranks(flags.usize("ranks", 1)?);
    let inner_threads = flags.usize("inner-threads", 1)?.max(1);
    // Under the processes executor every launched rank runs this whole
    // function SPMD; only rank 0 talks to the terminal / filesystem.
    let rank0 = dlb_mpk::exec::RankEnv::from_env().map_or(true, |e| e.rank == 0);
    let acfg = AndersonConfig { lx: l, ly: l, lz: l, w, t: 1.0, t_perp: 1.0, seed: 42 };
    let h = anderson(&acfg);
    if rank0 {
        println!("anderson {}^3: {} sites, {} nnz", l, h.n_rows(), h.nnz());
    }
    let part = partition(&h, ranks, Method::RecursiveBisect);
    let dist = DistMatrix::build(&h, &part);
    let p_m = flags.usize("pm", 8)?;
    let ccfg = ChebyshevConfig {
        dt: flags.f64("dt", 1.0)?,
        p_m,
        engine: EngineConfig {
            variant: Variant::Dlb(DlbOptions {
                cache_bytes: flags.usize("cache-mib", 16)? << 20,
                s_m: 50,
                async_remainder: flags.has("async-remainder"),
            }),
            executor,
            backend: BackendSpec::Native,
            trace: trace_out.is_some(),
            inner_threads,
            ..EngineConfig::default()
        },
    };
    let mut prop = ChebyshevPropagator::new(&h, &dist, ccfg)?;
    if rank0 {
        println!(
            "chebyshev: {} terms per step, block p_m = {p_m}, executor {executor} ({ranks} \
             ranks, {inner_threads} inner thread(s)/rank)",
            prop.n_terms
        );
    }
    let mut psi = wave_packet(&acfg, l as f64 / 8.0, [std::f64::consts::FRAC_PI_2, 0.0, 0.0]);
    for s in 0..steps {
        psi = prop.step(&psi);
        let com = center_of_mass(&acfg, &psi.density());
        if rank0 {
            println!(
                "step {:>3}: norm² = {:.12}  ⟨x⟩ = {:+.3}  ⟨y⟩ = {:+.3}  ⟨z⟩ = {:+.3}",
                s + 1,
                psi.norm2(),
                com[0],
                com[1],
                com[2]
            );
        }
    }
    if let Some(pool) = prop.engine().pool_stats() {
        if rank0 {
            println!(
                "(rank pool: {} threads spawned once, {} sweeps dispatched)",
                pool.threads, pool.sweeps
            );
        }
    }
    if let Some(path) = trace_out.filter(|_| rank0) {
        let json = prop
            .engine_mut()
            .chrome_trace_json()
            .expect("tracing was enabled for --trace-out");
        std::fs::write(&path, &json).with_context(|| format!("writing {path}"))?;
        let m = prop.engine_mut().metrics().expect("tracing was enabled for --trace-out");
        println!("trace: {path} ({} ranks)", m.per_rank.len());
        println!(
            "trace totals: compute {:.3} ms | wait {:.3} ms | overlap {:.3} ms | {} msgs | \
             {} bytes",
            m.total_compute_ns as f64 / 1e6,
            m.total_wait_ns as f64 / 1e6,
            m.total_overlap_ns as f64 / 1e6,
            m.total_messages,
            m.total_bytes,
        );
        for r in &m.per_rank {
            println!(
                "  rank {}: compute {:.3} ms | wait {:.3} ms | overlap {:.3} ms | recv {} msgs \
                 / {} bytes",
                r.rank,
                r.compute_ns as f64 / 1e6,
                r.wait_ns as f64 / 1e6,
                r.overlap_ns as f64 / 1e6,
                r.messages,
                r.bytes,
            );
        }
    }
    Ok(())
}

fn cmd_trace_check(args: &[String]) -> Result<()> {
    use dlb_mpk::trace::validate_chrome_trace;
    let Some(path) = args.first().filter(|a| !a.starts_with("--")) else {
        bail!("usage: dlb-mpk trace-check PATH [--min-ranks N]");
    };
    let flags = Flags::parse(&args[1..])?;
    let min_ranks = flags.usize("min-ranks", 1)?;
    let json = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let check = validate_chrome_trace(&json).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    anyhow::ensure!(
        check.n_ranks() >= min_ranks,
        "{path}: trace covers {} rank(s), expected >= {min_ranks}",
        check.n_ranks()
    );
    println!(
        "{path}: OK — {} events, {} ranks, spans per rank: {:?}",
        check.events,
        check.n_ranks(),
        check.spans_per_rank.values().collect::<Vec<_>>()
    );
    Ok(())
}

/// `dlb-mpk launch --np N [--sock-dir D] [--timeout-ms T] -- <cmd> ...`:
/// spawn N copies of this binary running `<cmd>` SPMD, one per rank, with
/// the `DLB_MPK_*` rendezvous environment set. Rank 0 keeps stdout (all
/// ranks keep stderr, so panics surface); the launcher waits for every
/// rank and fails reporting the first non-zero exit.
fn cmd_launch(args: &[String]) -> Result<()> {
    const USAGE: &str =
        "usage: dlb-mpk launch --np N [--sock-dir DIR] [--timeout-ms T] -- <command> [flags]";
    let mut np: Option<usize> = None;
    let mut sock_dir: Option<String> = None;
    let mut timeout_ms: Option<u64> = None;
    let mut i = 0;
    let child_args = loop {
        let Some(a) = args.get(i) else { bail!("{USAGE}") };
        match a.as_str() {
            "--np" => {
                let v = args.get(i + 1).context("--np needs a value")?;
                np = Some(v.parse().context("--np")?);
                i += 2;
            }
            "--sock-dir" => {
                sock_dir = Some(args.get(i + 1).context("--sock-dir needs a value")?.clone());
                i += 2;
            }
            "--timeout-ms" => {
                let v = args.get(i + 1).context("--timeout-ms needs a value")?;
                timeout_ms = Some(v.parse().context("--timeout-ms")?);
                i += 2;
            }
            "--" => break &args[i + 1..],
            other => bail!("launch: unexpected argument {other:?} before `--`\n{USAGE}"),
        }
    };
    let np = np.with_context(|| format!("launch needs --np N\n{USAGE}"))?;
    anyhow::ensure!(np >= 1, "--np must be >= 1");
    anyhow::ensure!(!child_args.is_empty(), "launch: nothing to run after `--`\n{USAGE}");

    let exe = std::env::current_exe().context("resolving the dlb-mpk executable")?;
    let (dir, created) = match sock_dir {
        Some(d) => (std::path::PathBuf::from(d), false),
        None => {
            let nonce = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map_or(0, |d| d.as_nanos() as u64);
            let d = std::env::temp_dir()
                .join(format!("dlb-mpk-launch-{}-{nonce:x}", std::process::id()));
            (d, true)
        }
    };
    std::fs::create_dir_all(&dir).with_context(|| format!("creating {}", dir.display()))?;

    let mut children = Vec::with_capacity(np);
    for r in 0..np {
        let mut cmd = std::process::Command::new(&exe);
        cmd.args(child_args)
            .env("DLB_MPK_RANK", r.to_string())
            .env("DLB_MPK_WORLD", np.to_string())
            .env("DLB_MPK_SOCK_DIR", &dir);
        if let Some(t) = timeout_ms {
            cmd.env("DLB_MPK_TIMEOUT_MS", t.to_string());
        }
        if r != 0 {
            cmd.stdout(std::process::Stdio::null());
        }
        children.push(cmd.spawn().with_context(|| format!("spawning rank {r}"))?);
    }
    let mut first_failure: Option<(usize, String)> = None;
    for (r, mut child) in children.into_iter().enumerate() {
        let status = child.wait().with_context(|| format!("waiting for rank {r}"))?;
        if !status.success() && first_failure.is_none() {
            first_failure = Some((r, status.to_string()));
        }
    }
    if created {
        let _ = std::fs::remove_dir_all(&dir); // ranks already unlinked their sockets
    }
    if let Some((r, status)) = first_failure {
        bail!("rank {r} failed: {status}");
    }
    Ok(())
}

/// `dlb-mpk sweep`: one engine sweep over a deterministic input, dumped as
/// JSON with every double hex-encoded (`f64::to_bits`). The dump excludes
/// everything executor-dependent (wall-clock, `wait_ns`, the executor
/// label), so sim / threads / processes runs of the same configuration
/// produce **byte-identical** files — the oracle `rust/tests/sock_proc.rs`
/// diffs. Under the processes executor only rank 0 writes/prints;
/// `--die-rank R` makes rank R exit(3) right after engine construction,
/// for the rank-failure (no-hang) tests.
fn cmd_sweep(flags: &Flags) -> Result<()> {
    use dlb_mpk::distsim::DistMatrix;
    use dlb_mpk::engine::{BackendSpec, EngineConfig, MpkEngine, Variant};
    use dlb_mpk::exec::RankEnv;
    use dlb_mpk::mpk::dlb::{DlbOptions, Recurrence};
    use dlb_mpk::partition::partition;

    let cfg = config(flags)?;
    let variant = match flags.get("variant").unwrap_or("dlb") {
        "trad" => Variant::Trad,
        "ca" => Variant::Ca,
        "dlb" => Variant::Dlb(DlbOptions {
            cache_bytes: cfg.cache_bytes,
            s_m: cfg.s_m,
            async_remainder: cfg.async_remainder,
        }),
        other => bail!("--variant must be trad|ca|dlb, got {other:?}"),
    };
    let a = cfg.matrix.build()?;
    let ranks = cfg.executor.ranks(cfg.n_ranks);
    let part = partition(&a, ranks, cfg.partitioner);
    let dist = DistMatrix::build(&a, &part);
    let eng_cfg = EngineConfig {
        variant,
        executor: cfg.executor,
        backend: BackendSpec::Native,
        trace: false,
        inner_threads: cfg.inner_threads,
        ..EngineConfig::default()
    };
    let mut eng = MpkEngine::from_config(&dist, cfg.p_m, &eng_cfg)?;
    if let Some(die) = flags.get("die-rank") {
        let die: usize = die.parse().context("--die-rank")?;
        if RankEnv::from_env().is_some_and(|e| e.rank == die) {
            // Simulated rank failure after the rendezvous: peers must
            // detect the EOF and fail cleanly instead of hanging.
            std::process::exit(3);
        }
    }
    let x: Vec<f64> = (0..dist.n_global).map(|i| ((i % 17) as f64 - 8.0) / 9.0).collect();
    let out = eng.sweep(&x, None, Recurrence::Power);

    let mut json = String::new();
    json.push_str(&format!(
        "{{\"matrix\": \"{}\", \"ranks\": {ranks}, \"pm\": {}, \"variant\": \"{}\", \
         \"flop_nnz\": {}, \"comm\": {{\"messages\": {}, \"bytes\": {}, \"rounds\": {}, \
         \"max_message_bytes\": {}}}, \"powers\": [",
        flags.get("matrix").unwrap_or("stencil2d:256,256"),
        cfg.p_m,
        variant.label(),
        out.flop_nnz,
        out.comm.messages,
        out.comm.bytes,
        out.comm.rounds,
        out.comm.max_message_bytes,
    ));
    for (p, pw) in out.powers.iter().enumerate() {
        if p > 0 {
            json.push_str(", ");
        }
        json.push('[');
        for (j, v) in pw.iter().enumerate() {
            if j > 0 {
                json.push(',');
            }
            json.push_str(&format!("\"{:016x}\"", v.to_bits()));
        }
        json.push(']');
    }
    json.push_str("]}\n");

    let rank0 = RankEnv::from_env().map_or(true, |e| e.rank == 0);
    if rank0 {
        match flags.get("out") {
            Some(path) => std::fs::write(path, &json).with_context(|| format!("writing {path}"))?,
            None => print!("{json}"),
        }
    }
    Ok(())
}
