//! RACE-style level blocking: group BFS levels under a cache budget and
//! schedule the Lp-diagram wavefront (paper §3).
//!
//! The cache-blocking argument: executing the Lp diagram in diagonal order
//! (`group + power = const`), a level group's matrix data is re-touched after
//! `p_m + 1` execution steps; if the bytes of `p_m + 1` consecutive groups
//! fit in the cache budget `C`, every SpMV except the first streams its
//! matrix data from cache.

pub mod grouping;
pub mod schedule;

pub use grouping::{group_levels, LevelGroups};
pub use schedule::{parallel_batches, wavefront, Step};
