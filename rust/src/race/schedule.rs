//! The Lp-diagram wavefront schedule (paper §3, Fig. 2).
//!
//! Execution steps `(group, power)` are emitted in diagonal order
//! (`group + power = const`, bottom-right to top-left within a diagonal, for
//! increasing const) — the order that guarantees each step's dependencies
//! (`A^{p-1}x` on the level and its two neighbor levels) are already done,
//! while re-touching a group's matrix data after only `p_m + 1` steps.
//!
//! Dependencies are tracked at *level* granularity: when a bulky level was
//! split into sub-block groups (race::grouping, `s_m`), the sub-blocks of
//! one level may reference each other arbitrarily, so `(g, p)` is executable
//! only when every group covering levels `span(g) ± 1` has completed power
//! `p - 1`. For whole-level groups this reduces exactly to the paper's
//! `{L(i-1), L(i), L(i+1)}` rule.

use crate::race::LevelGroups;

/// One execution step: promote all rows of `group` from power `power - 1`
/// to `power`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Step {
    pub group: usize,
    pub power: usize,
}

/// Generate the wavefront schedule for `p_m` powers over `groups`.
///
/// Panics on deadlock, which cannot happen for groupings produced by
/// [`crate::race::group_levels`] (dependencies are monotone in level order).
pub fn wavefront(groups: &LevelGroups, n_levels: usize, p_m: usize) -> Vec<Step> {
    let caps = vec![p_m; groups.n_groups()];
    wavefront_capped(groups, n_levels, p_m, &caps)
}

/// Wavefront with a per-group power cap — the DLB-MPK phase-2 schedule
/// (paper §5): the bulk `M` is promoted all the way to `p_m`, while each
/// boundary class `I_k` stops at power `k` (its dependencies on the halo
/// make higher powers impossible before the phase-3 exchanges).
///
/// A capped schedule is feasible iff `cap[g] <= cap[h] + 1` for every
/// dependency group `h` — which holds by construction for boundary-distance
/// caps (`cap = distance`).
pub fn wavefront_capped(
    groups: &LevelGroups,
    n_levels: usize,
    p_m: usize,
    caps: &[usize],
) -> Vec<Step> {
    let n_groups = groups.n_groups();
    assert_eq!(caps.len(), n_groups);
    if n_groups == 0 || p_m == 0 {
        return Vec::new();
    }
    let _ = n_levels;

    // Super-nodes: consecutive groups sharing one level_span (the sub-blocks
    // of a split level), or a single merged/solo group. The super-node chain
    // has exact ±1 dependencies — sub-blocks of level l depend on levels
    // {l−1, l, l+1}, i.e. super-nodes {i−1, i, i+1}; merged groups likewise —
    // so the classic diagonal traversal is correct by construction and
    // re-touches a node after p_m + 1 steps.
    let mut nodes: Vec<(usize, usize)> = Vec::new(); // group index range
    let mut node_cap: Vec<usize> = Vec::new();
    let mut g = 0usize;
    while g < n_groups {
        let span = groups.level_span[g];
        let mut h = g + 1;
        while h < n_groups && groups.level_span[h] == span {
            debug_assert_eq!(caps[h], caps[g], "sub-blocks must share a cap");
            h += 1;
        }
        nodes.push((g, h));
        node_cap.push(caps[g]);
        g = h;
    }

    let n_nodes = nodes.len();
    let total: usize = caps.iter().sum();
    let mut steps = Vec::with_capacity(total);
    // diagonal d = node + p, bottom-right to top-left (descending node)
    for d in 1..=(n_nodes - 1 + p_m) {
        let hi = (d - 1).min(n_nodes - 1);
        for ni in (0..=hi).rev() {
            let p = d - ni;
            if p < 1 || p > node_cap[ni] {
                continue;
            }
            for g in nodes[ni].0..nodes[ni].1 {
                steps.push(Step { group: g, power: p });
            }
        }
    }
    debug_assert_eq!(steps.len(), total);
    steps
}

/// Group a wavefront schedule into dependency-safe parallel batches.
///
/// Steps are re-ordered into *skewed fronts* `f = node + 2·power`, where
/// `node` is the super-node index (consecutive groups sharing one
/// `level_span` — exactly the chain [`wavefront_capped`] traverses). Every
/// dependency of `(n, p)` lives on nodes `{n−1, n, n+1}` at power `p − 1`,
/// i.e. on fronts `f − 3`, `f − 2`, `f − 1` — all strictly earlier — so the
/// steps of one front are mutually independent:
///
/// * equal powers ⇒ equal nodes ⇒ sub-blocks of one split level, which
///   write disjoint row ranges and read only finished `p − 1` data;
/// * powers differing by 1 ⇒ nodes differing by 2 ⇒ level spans ≥ 2 apart
///   (node spans tile the level axis), so neither step's span intersects
///   the other's ±1 dependency window;
/// * powers differing by ≥ 2 ⇒ different write buffers, and the three-term
///   recurrence reads only a step's own rows two powers down.
///
/// Concatenating the batches in order is therefore itself a valid schedule
/// (checked against [`validate_schedule`] in the tests below), and each
/// batch may run its steps concurrently — the within-rank parallelism used
/// by [`crate::inner`].
pub fn parallel_batches(steps: &[Step], groups: &LevelGroups) -> Vec<Vec<Step>> {
    parallel_batches_spans(steps, &groups.level_span)
}

/// [`parallel_batches`] over a raw `level_span` table (one entry per group).
pub fn parallel_batches_spans(steps: &[Step], level_span: &[(usize, usize)]) -> Vec<Vec<Step>> {
    // Super-node index per group, by the same consecutive-equality scan as
    // `wavefront_capped`.
    let mut node_of = vec![0usize; level_span.len()];
    let mut node = 0usize;
    let mut g = 0usize;
    while g < level_span.len() {
        let span = level_span[g];
        while g < level_span.len() && level_span[g] == span {
            node_of[g] = node;
            g += 1;
        }
        node += 1;
    }
    let mut fronts: std::collections::BTreeMap<usize, Vec<Step>> =
        std::collections::BTreeMap::new();
    for &s in steps {
        fronts.entry(node_of[s.group] + 2 * s.power).or_default().push(s);
    }
    fronts.into_values().collect()
}

/// Validate that a step order never violates dependencies (test harness for
/// the scheduler and for alternative orders).
pub fn validate_schedule(
    groups: &LevelGroups,
    n_levels: usize,
    p_m: usize,
    steps: &[Step],
) -> Result<(), String> {
    let n_groups = groups.n_groups();
    let mut gl_lo = vec![usize::MAX; n_levels];
    let mut gl_hi = vec![0usize; n_levels];
    for (g, &(lo, hi)) in groups.level_span.iter().enumerate() {
        for l in lo..hi {
            gl_lo[l] = gl_lo[l].min(g);
            gl_hi[l] = gl_hi[l].max(g);
        }
    }
    let mut pow = vec![0usize; n_groups];
    for (i, s) in steps.iter().enumerate() {
        if s.power != pow[s.group] + 1 {
            return Err(format!(
                "step {i}: group {} jumps from power {} to {}",
                s.group, pow[s.group], s.power
            ));
        }
        let (lo, hi) = groups.level_span[s.group];
        let dep_lo = lo.saturating_sub(1);
        let dep_hi = (hi + 1).min(n_levels);
        for l in dep_lo..dep_hi {
            for h in gl_lo[l]..=gl_hi[l] {
                if h != s.group && pow[h] < s.power - 1 {
                    return Err(format!(
                        "step {i}: group {} at power {} needs group {h} >= {}",
                        s.group,
                        s.power,
                        s.power - 1
                    ));
                }
            }
        }
        pow[s.group] = s.power;
    }
    if pow.iter().any(|&p| p != p_m) {
        return Err("schedule incomplete".into());
    }
    Ok(())
}

/// Maximum reuse distance (in steps) between consecutive touches of the same
/// group — the cache-blocking quality metric (paper: `p_m + 1` for the ideal
/// diagonal traversal away from wind-up/wind-down).
pub fn max_reuse_distance(steps: &[Step], n_groups: usize) -> usize {
    let mut last = vec![usize::MAX; n_groups];
    let mut worst = 0usize;
    for (i, s) in steps.iter().enumerate() {
        if last[s.group] != usize::MAX {
            worst = worst.max(i - last[s.group]);
        }
        last[s.group] = i;
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::levels::bfs_reorder;
    use crate::matrix::gen;
    use crate::race::group_levels;

    fn setup(nx: usize, p_m: usize, cache: usize) -> (LevelGroups, usize, Vec<Step>) {
        let a = gen::stencil_2d_5pt(nx, nx);
        let (b, lv) = bfs_reorder(&a, 0);
        let g = group_levels(&b, &lv, p_m, cache, 50);
        let s = wavefront(&g, lv.n_levels(), p_m);
        (g, lv.n_levels(), s)
    }

    #[test]
    fn schedule_is_complete_and_valid() {
        let (g, nl, s) = setup(24, 4, 64 << 10);
        assert_eq!(s.len(), g.n_groups() * 4);
        validate_schedule(&g, nl, 4, &s).unwrap();
    }

    #[test]
    fn one_level_per_group_reuse_is_pm_plus_one() {
        // Whole-level groups with generous level count: interior groups are
        // re-touched exactly p_m + 1 steps later (paper §3).
        let a = gen::tridiag(64); // 64 single-row levels
        let (b, lv) = bfs_reorder(&a, 0);
        // tiny budget => one level per group
        let g = group_levels(&b, &lv, 3, 1, 50);
        assert_eq!(g.n_groups(), 64);
        let s = wavefront(&g, lv.n_levels(), 3);
        validate_schedule(&g, lv.n_levels(), 3, &s).unwrap();
        assert_eq!(max_reuse_distance(&s, 64), 3 + 1);
    }

    #[test]
    fn figure2_execution_order() {
        // Paper Fig. 2: 10 levels, p_m = 5; first steps along diagonals:
        // (L0,p1) then (L1,p1),(L0,p2), then (L2,p1),(L1,p2),(L0,p3) ...
        let a = gen::tridiag(10);
        let (b, lv) = bfs_reorder(&a, 0);
        let g = group_levels(&b, &lv, 5, 1, 50);
        let s = wavefront(&g, 10, 5);
        validate_schedule(&g, 10, 5, &s).unwrap();
        assert_eq!(&s[..6], &[
            Step { group: 0, power: 1 },
            Step { group: 1, power: 1 },
            Step { group: 0, power: 2 },
            Step { group: 2, power: 1 },
            Step { group: 1, power: 2 },
            Step { group: 0, power: 3 },
        ]);
    }

    #[test]
    fn split_levels_still_schedule_correctly() {
        let (g, nl, s) = setup(48, 3, 2 << 10); // forces sub-block splits
        validate_schedule(&g, nl, 3, &s).unwrap();
    }

    #[test]
    fn validate_catches_bad_order() {
        let (g, nl, mut s) = setup(16, 2, 32 << 10);
        let last = s.len() - 1;
        s.swap(0, last);
        assert!(validate_schedule(&g, nl, 2, &s).is_err());
    }

    /// The pairwise independence rule the batching must satisfy: two
    /// same-batch steps may never touch each other's dependency window.
    fn independent(a: Step, b: Step, spans: &[(usize, usize)]) -> bool {
        if a.group == b.group {
            return false;
        }
        match a.power.abs_diff(b.power) {
            0 => true, // same write buffer, disjoint row ranges
            1 => {
                let (rd, wr) = if a.power > b.power { (a, b) } else { (b, a) };
                let (rlo, rhi) = spans[rd.group];
                let (wlo, whi) = spans[wr.group];
                // the reader's ±1 level window vs the writer's span
                whi < rlo || wlo > rhi
            }
            _ => true, // different buffers; prev-2 reads only own rows
        }
    }

    fn assert_batches_independent(batches: &[Vec<Step>], spans: &[(usize, usize)]) {
        for batch in batches {
            for (i, &x) in batch.iter().enumerate() {
                for &y in &batch[i + 1..] {
                    assert!(independent(x, y, spans), "dependent steps {x:?} / {y:?} share a batch");
                }
            }
        }
    }

    #[test]
    fn batches_flatten_to_a_valid_schedule() {
        let (g, nl, s) = setup(24, 4, 64 << 10);
        let b = parallel_batches(&s, &g);
        let flat: Vec<Step> = b.iter().flatten().copied().collect();
        assert_eq!(flat.len(), s.len());
        let key = |st: &Step| (st.group, st.power);
        let mut ss = s.clone();
        let mut ff = flat.clone();
        ss.sort_by_key(key);
        ff.sort_by_key(key);
        assert_eq!(ss, ff, "batching preserves the step multiset");
        validate_schedule(&g, nl, 4, &flat).unwrap();
    }

    #[test]
    fn same_batch_steps_never_touch_adjacent_levels() {
        for (nx, p_m, cache) in [(24, 4, 64 << 10), (48, 3, 2 << 10), (16, 2, 32 << 10)] {
            let (g, _nl, s) = setup(nx, p_m, cache);
            assert_batches_independent(&parallel_batches(&s, &g), &g.level_span);
        }
    }

    #[test]
    fn figure2_fronts() {
        // Fig. 2 skewed fronts f = level + 2p: the first five batches.
        let a = gen::tridiag(10);
        let (b, lv) = bfs_reorder(&a, 0);
        let g = group_levels(&b, &lv, 5, 1, 50);
        let s = wavefront(&g, 10, 5);
        let batches = parallel_batches(&s, &g);
        let pairs = |b: &[Step]| {
            let mut v: Vec<(usize, usize)> = b.iter().map(|s| (s.group, s.power)).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(pairs(&batches[0]), vec![(0, 1)]);
        assert_eq!(pairs(&batches[1]), vec![(1, 1)]);
        assert_eq!(pairs(&batches[2]), vec![(0, 2), (2, 1)]);
        assert_eq!(pairs(&batches[3]), vec![(1, 2), (3, 1)]);
        assert_eq!(pairs(&batches[4]), vec![(0, 3), (2, 2), (4, 1)]);
    }

    #[test]
    fn capped_schedule_batches_preserve_steps() {
        // DLB phase-2 style caps (boundary distance) on a split grouping.
        let (g, nl, _s) = setup(48, 3, 2 << 10);
        let caps: Vec<usize> = g.level_span.iter().map(|&(lo, _)| (lo + 1).min(3)).collect();
        let s = wavefront_capped(&g, nl, 3, &caps);
        let b = parallel_batches(&s, &g);
        assert_eq!(b.iter().map(Vec::len).sum::<usize>(), s.len());
        assert_batches_independent(&b, &g.level_span);
    }

    #[test]
    fn single_group_runs_powers_in_order() {
        let a = gen::stencil_2d_5pt(8, 8);
        let (b, lv) = bfs_reorder(&a, 0);
        let g = group_levels(&b, &lv, 4, usize::MAX / 8, 50);
        let s = wavefront(&g, lv.n_levels(), 4);
        assert_eq!(s.len(), 4);
        for (i, st) in s.iter().enumerate() {
            assert_eq!(st.power, i + 1);
        }
    }
}
