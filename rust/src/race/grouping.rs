//! Grouping consecutive BFS levels under the cache budget `C`.
//!
//! RACE's tuning parameters mirrored here (paper §6.2):
//! * `cache_bytes` — the budget `C`; the grouping ensures any `p_m + 1`
//!   consecutive groups hold at most `C` bytes of matrix data (so the
//!   wavefront's working set stays cache-resident).
//! * `s_m` — maximum recursion stage: a "bulky" level whose own data exceeds
//!   the per-group share is split into at most `s_m` sub-blocks (a practical
//!   stand-in for RACE's recursive sub-level coloring: sub-blocks of one
//!   level are mutually independent w.r.t. the level invariant, because the
//!   invariant constrains only *level* adjacency).

use crate::graph::Levels;
use crate::matrix::CsrMatrix;

/// Groups of consecutive levels (and sub-blocks of bulky levels), stored as
/// row ranges of the BFS-permuted matrix.
#[derive(Clone, Debug)]
pub struct LevelGroups {
    /// Row range (permuted matrix) of each group, in level order.
    pub ranges: Vec<(usize, usize)>,
    /// For each group, the range of original level indices it covers
    /// (sub-blocks of a split level share that level's index).
    pub level_span: Vec<(usize, usize)>,
    /// Matrix bytes (CRS accounting) per group.
    pub bytes: Vec<usize>,
}

impl LevelGroups {
    pub fn n_groups(&self) -> usize {
        self.ranges.len()
    }

    /// The largest working set of `window` consecutive groups, in bytes.
    pub fn max_window_bytes(&self, window: usize) -> usize {
        if self.bytes.is_empty() {
            return 0;
        }
        let w = window.min(self.bytes.len());
        let mut sum: usize = self.bytes[..w].iter().sum();
        let mut best = sum;
        for i in w..self.bytes.len() {
            sum += self.bytes[i];
            sum -= self.bytes[i - w];
            best = best.max(sum);
        }
        best
    }

    /// Validate group ranges tile `[0, n_rows)` contiguously.
    pub fn validate(&self, n_rows: usize) -> Result<(), String> {
        let mut next = 0usize;
        for (i, &(lo, hi)) in self.ranges.iter().enumerate() {
            if lo != next {
                return Err(format!("group {i} starts at {lo}, expected {next}"));
            }
            if hi < lo {
                return Err(format!("group {i} is reversed"));
            }
            next = hi;
        }
        if next != n_rows {
            return Err(format!("groups end at {next}, expected {n_rows}"));
        }
        Ok(())
    }
}

/// Group levels so that any `p_m + 1` consecutive groups hold ≤
/// `cache_bytes` of matrix data (best effort: a single level bigger than the
/// per-group share is split into ≤ `s_m` sub-blocks; if even a sub-block
/// overflows, it is kept — cache blocking then degrades gracefully, exactly
/// like RACE with an undersized `C`).
///
/// `b` must be the BFS-permuted matrix matching `levels`.
pub fn group_levels(
    b: &CsrMatrix,
    levels: &Levels,
    p_m: usize,
    cache_bytes: usize,
    s_m: usize,
) -> LevelGroups {
    group_levels_solo_prefix(b, levels, p_m, cache_bytes, s_m, 0)
}

/// Like [`group_levels`], but the first `solo_prefix` levels each form their
/// own (unsplit, unmerged) group. DLB-MPK requires this for the boundary
/// distance classes `I_k` (k < p_m): phase 3 promotes each class exactly one
/// power per round, so a class must not share a group with rows of a
/// different class (paper §5: classes are gathered contiguously in
/// preprocessing).
pub fn group_levels_solo_prefix(
    b: &CsrMatrix,
    levels: &Levels,
    p_m: usize,
    cache_bytes: usize,
    s_m: usize,
    solo_prefix: usize,
) -> LevelGroups {
    assert!(p_m >= 1);
    let window = p_m + 1;
    // Target bytes per group so that `window` consecutive groups fit in C.
    let per_group = (cache_bytes / window).max(1);

    let mut ranges = Vec::new();
    let mut level_span = Vec::new();
    let mut bytes = Vec::new();

    let mut cur_lo = 0usize; // row where the open group starts
    let mut cur_bytes = 0usize;
    let mut cur_level_lo = 0usize;

    let row_bytes = |lo: usize, hi: usize| -> usize {
        crate::matrix::crs_bytes(hi - lo, b.rowptr[hi] - b.rowptr[lo])
    };

    let flush =
        |ranges: &mut Vec<(usize, usize)>,
         level_span: &mut Vec<(usize, usize)>,
         bytes: &mut Vec<usize>,
         cur_lo: &mut usize,
         cur_bytes: &mut usize,
         cur_level_lo: &mut usize,
         row_hi: usize,
         level_hi: usize| {
            if row_hi > *cur_lo {
                ranges.push((*cur_lo, row_hi));
                level_span.push((*cur_level_lo, level_hi));
                bytes.push(*cur_bytes);
            }
            *cur_lo = row_hi;
            *cur_bytes = 0;
            *cur_level_lo = level_hi;
        };

    for l in 0..levels.n_levels() {
        let r = levels.rows(l);
        let lb = row_bytes(r.start, r.end);
        if l < solo_prefix {
            // close any open group, then emit this level as its own group
            flush(
                &mut ranges, &mut level_span, &mut bytes, &mut cur_lo, &mut cur_bytes,
                &mut cur_level_lo, r.start, l,
            );
            if r.end > r.start {
                ranges.push((r.start, r.end));
                level_span.push((l, l + 1));
                bytes.push(lb);
            }
            cur_lo = r.end;
            cur_bytes = 0;
            cur_level_lo = l + 1;
        } else if lb > per_group {
            // bulky level: close the open group, then split this level
            flush(
                &mut ranges, &mut level_span, &mut bytes, &mut cur_lo, &mut cur_bytes,
                &mut cur_level_lo, r.start, l,
            );
            let n_sub = lb.div_ceil(per_group).min(s_m.max(1));
            let rows_per = (r.end - r.start).div_ceil(n_sub);
            let mut lo = r.start;
            while lo < r.end {
                let hi = (lo + rows_per).min(r.end);
                ranges.push((lo, hi));
                level_span.push((l, l + 1));
                bytes.push(row_bytes(lo, hi));
                lo = hi;
            }
            cur_lo = r.end;
            cur_bytes = 0;
            cur_level_lo = l + 1;
        } else if cur_bytes + lb > per_group && cur_bytes > 0 {
            // close the open group before this level
            flush(
                &mut ranges, &mut level_span, &mut bytes, &mut cur_lo, &mut cur_bytes,
                &mut cur_level_lo, r.start, l,
            );
            cur_bytes = lb;
        } else {
            cur_bytes += lb;
        }
    }
    flush(
        &mut ranges, &mut level_span, &mut bytes, &mut cur_lo, &mut cur_bytes,
        &mut cur_level_lo, levels.n_rows(), levels.n_levels(),
    );

    let g = LevelGroups { ranges, level_span, bytes };
    debug_assert!(g.validate(levels.n_rows()).is_ok());
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::levels::bfs_reorder;
    use crate::matrix::gen;

    #[test]
    fn groups_tile_all_rows() {
        let a = gen::stencil_2d_5pt(24, 24);
        let (b, lv) = bfs_reorder(&a, 0);
        let g = group_levels(&b, &lv, 4, 64 << 10, 50);
        g.validate(b.n_rows()).unwrap();
        assert!(g.n_groups() >= 2);
        let total: usize = g.bytes.iter().sum();
        assert_eq!(total, b.crs_bytes());
    }

    #[test]
    fn window_fits_budget_when_feasible() {
        let a = gen::stencil_2d_5pt(32, 32);
        let (b, lv) = bfs_reorder(&a, 0);
        let c = 32 << 10;
        let g = group_levels(&b, &lv, 3, c, 50);
        // per-level data is small here, so the guarantee must hold
        assert!(g.max_window_bytes(4) <= c, "window {} > C {}", g.max_window_bytes(4), c);
    }

    #[test]
    fn bulky_level_is_split() {
        // 1D star-ish: one huge level. tridiag has 1-row levels; instead use
        // a stencil and a tiny budget so every level is "bulky".
        let a = gen::stencil_2d_5pt(64, 64);
        let (b, lv) = bfs_reorder(&a, 0);
        let g = group_levels(&b, &lv, 2, 4 << 10, 50);
        g.validate(b.n_rows()).unwrap();
        // middle levels have ~64 rows * ~60B > 1.3KiB per-group share
        assert!(g.n_groups() > lv.n_levels(), "expected split groups");
    }

    #[test]
    fn recursion_cap_limits_splitting() {
        let a = gen::stencil_2d_5pt(64, 64);
        let (b, lv) = bfs_reorder(&a, 0);
        let g1 = group_levels(&b, &lv, 2, 2 << 10, 2);
        let g2 = group_levels(&b, &lv, 2, 2 << 10, 64);
        assert!(g2.n_groups() >= g1.n_groups());
    }

    #[test]
    fn giant_budget_gives_one_group() {
        let a = gen::stencil_2d_5pt(16, 16);
        let (b, lv) = bfs_reorder(&a, 0);
        let g = group_levels(&b, &lv, 2, usize::MAX / 8, 50);
        assert_eq!(g.n_groups(), 1);
        assert_eq!(g.ranges[0], (0, 256));
    }
}
