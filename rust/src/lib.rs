//! # DLB-MPK — Distributed Level-Blocked Matrix Power Kernels
//!
//! A reproduction of *"Cache Blocking of Distributed-Memory Parallel Matrix
//! Power Kernels"* (Lacey, Alappat, Lange, Hager, Fehske, Wellein — 2024).
//!
//! The Matrix Power Kernel (MPK) computes `y_p = A^p x` for `p = 1..p_m`.
//! Implemented traditionally as back-to-back SpMVs it is memory-bandwidth
//! bound; this crate implements the paper's **DLB-MPK** scheme, which keeps
//! the matrix data of a window of BFS levels cache-resident across powers
//! while fulfilling all inter-process data dependencies with exactly the
//! halo exchange a traditional distributed SpMV needs — no extra halo
//! elements and no redundant computation (unlike CA-MPK).
//!
//! ## Layout
//!
//! * [`matrix`] — CRS/ELL/COO sparse formats, MatrixMarket IO, matrix
//!   generators (stencils, synthetic SuiteSparse analogues, Anderson model).
//! * [`graph`] — matrix↔graph correspondence, BFS levels, distance classes.
//! * [`race`] — RACE-style level grouping under a cache budget and the
//!   wavefront (Lp-diagram diagonal) schedule.
//! * [`partition`] — row-wise partitioners (block, greedy graph growing,
//!   recursive bisection + KL refinement) standing in for METIS.
//! * [`distsim`] — simulated-MPI runtime: rank-local matrices, halo plans,
//!   byte-accurate communication accounting, comm cost model.
//! * [`exec`] — rank executors: the `Communicator` halo-exchange contract
//!   (`docs/COMMUNICATOR.md`) with sequential (`SimComm`), multi-threaded
//!   (`ThreadComm`, one OS thread per rank over mpsc channels), and
//!   multi-process (`SockComm`, one OS process per rank over Unix-domain
//!   sockets, launched via `dlb-mpk launch`) transports, plus the threaded
//!   drivers measuring real parallel wall-clock.
//! * [`engine`] — **the public execution API**: `MpkEngine`, a
//!   prepare-once/apply-many session owning the variant plan, tail-plan
//!   cache, workspaces, and (threads executor) a persistent rank pool.
//! * [`inner`] — within-rank shared-memory wavefront execution: each rank's
//!   inner thread pool runs dependency-safe step batches concurrently
//!   (`MpkEngine::builder().inner_threads(k)`), giving ranks × inner-threads
//!   hierarchical parallelism like MPI+OpenMP.
//! * [`mpk`] — the three MPK variants: `trad`, `ca` (baseline from
//!   Mohiyuddin et al. 2009), and `dlb` (the paper's contribution).
//! * [`cachesim`] — LRU cache simulator replaying MPK reference streams to
//!   count main-memory traffic.
//! * [`trace`] — per-rank span tracing + metrics: chrome-trace export and
//!   aggregated wait/compute/flow summaries behind an engine knob.
//! * [`perf`] — roofline model (paper Eq. 4), bandwidth measurement, timers.
//! * [`apps`] — Chebyshev time propagation of the Anderson model (paper §7).
//! * [`runtime`] — PJRT/XLA execution of the AOT Pallas/JAX artifacts.
//! * [`verify`] — static race & communication-plan checker: machine-checks
//!   schedules, halo plans, and the unsafe inner-pool seams at prepare time
//!   (`MpkEngine::builder().verify_plans(true)`, `dlb-mpk verify`).
//! * [`coordinator`] — configuration + end-to-end drivers wiring the above.

// Every `unsafe` block and impl must carry a `// SAFETY:` comment stating
// the invariant it relies on (see `inner` and `matrix::csr`).
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod apps;
pub mod cachesim;
pub mod coordinator;
pub mod distsim;
pub mod engine;
pub mod exec;
pub mod graph;
pub mod inner;
pub mod matrix;
pub mod mpk;
pub mod partition;
pub mod perf;
pub mod race;
pub mod runtime;
pub mod trace;
pub mod util;
pub mod verify;
