//! Traditional distributed MPK (paper Alg. 1): back-to-back SpMVs, one halo
//! exchange per power, full local sweep per SpMV. The matrix streams from
//! main memory `p_m` times — the baseline DLB-MPK beats by cache blocking.

use crate::distsim::{exchange_halo, CommStats, DistMatrix};
use crate::mpk::dlb::Recurrence;
use crate::mpk::{MpkResult, SpmvBackend};

pub fn trad_mpk(
    dist: &DistMatrix,
    x: &[f64],
    p_m: usize,
    backend: &mut dyn SpmvBackend,
) -> MpkResult {
    trad_recurrence(dist, x, None, p_m, Recurrence::Power, backend)
}

/// TRAD generalized over a three-term recurrence (Chebyshev baseline for
/// paper §7: "previous state-of-the-art implementations … perform
/// back-to-back SpMVs").
pub fn trad_recurrence(
    dist: &DistMatrix,
    x: &[f64],
    x_m1: Option<&[f64]>,
    p_m: usize,
    rec: Recurrence,
    backend: &mut dyn SpmvBackend,
) -> MpkResult {
    assert!(p_m >= 1);
    let nr = dist.n_ranks();
    // ys[p][rank] = local vector (with halo tail) of power p
    let mut ys: Vec<Vec<Vec<f64>>> = Vec::with_capacity(p_m + 1);
    ys.push(dist.scatter(x));
    for _ in 0..p_m {
        ys.push(dist.ranks.iter().map(|r| r.new_vec()).collect());
    }
    let ym1: Option<Vec<Vec<f64>>> = x_m1.map(|v| dist.scatter(v));

    let mut comm = CommStats::default();
    let mut flop_nnz = 0usize;
    for p in 1..=p_m {
        // y[:, p-1] <- haloComm(y[:, p-1])
        exchange_halo(&dist.ranks, &mut ys[p - 1], &mut comm);
        // y[:, p] <- SpMV(y[:, p-1], A_i) (+ recurrence combine)
        let (prevs, cur) = ys.split_at_mut(p);
        for i in 0..nr {
            let r = &dist.ranks[i];
            backend.spmv_range(&r.a, 0, r.n_local(), &prevs[p - 1][i], &mut cur[0][i]);
            if rec == Recurrence::Chebyshev {
                let sub: Option<&[f64]> = if p >= 2 {
                    Some(&prevs[p - 2][i])
                } else {
                    ym1.as_ref().map(|v| &v[i][..])
                };
                if let Some(sub) = sub {
                    let out = &mut cur[0][i];
                    for rr in 0..r.n_local() {
                        out[rr] = 2.0 * out[rr] - sub[rr];
                    }
                }
            }
            flop_nnz += r.a.nnz();
        }
    }

    MpkResult {
        powers: (1..=p_m).map(|p| dist.gather(&ys[p])).collect(),
        comm,
        flop_nnz,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::mpk::NativeBackend;
    use crate::partition::{partition, Method};

    /// Serial reference: y_p = A^p x by repeated full SpMV.
    pub fn serial_mpk(a: &crate::matrix::CsrMatrix, x: &[f64], p_m: usize) -> Vec<Vec<f64>> {
        let mut out = Vec::new();
        let mut cur = x.to_vec();
        for _ in 0..p_m {
            let mut y = vec![0.0; a.n_rows()];
            a.spmv(&cur, &mut y);
            out.push(y.clone());
            cur = y;
        }
        out
    }

    #[test]
    fn trad_matches_serial_reference() {
        let a = gen::stencil_2d_5pt(10, 8);
        let x: Vec<f64> = (0..80).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let want = serial_mpk(&a, &x, 4);
        for np in [1, 2, 3, 5] {
            let p = partition(&a, np, Method::Block);
            let d = crate::distsim::DistMatrix::build(&a, &p);
            let got = trad_mpk(&d, &x, 4, &mut NativeBackend);
            assert_eq!(got.powers.len(), 4);
            for (gp, wp) in got.powers.iter().zip(&want) {
                for (u, v) in gp.iter().zip(wp) {
                    assert!((u - v).abs() < 1e-11, "np={np}: {u} vs {v}");
                }
            }
            // one exchange round per power
            assert_eq!(got.comm.rounds, 4);
            assert_eq!(got.flop_nnz, 4 * a.nnz());
        }
    }

    #[test]
    fn trad_comm_bytes_scale_with_halo() {
        let a = gen::stencil_2d_5pt(16, 16);
        let p = partition(&a, 4, Method::Block);
        let d = crate::distsim::DistMatrix::build(&a, &p);
        let x = vec![1.0; 256];
        let got = trad_mpk(&d, &x, 3, &mut NativeBackend);
        assert_eq!(got.comm.bytes, 3 * d.total_halo() * 8);
    }
}
