//! Traditional distributed MPK (paper Alg. 1): back-to-back SpMVs, one halo
//! exchange per power, full local sweep per SpMV. The matrix streams from
//! main memory `p_m` times — the baseline DLB-MPK beats by cache blocking.
//!
//! Two executable forms over the same compute primitive
//! ([`crate::mpk::kernel_step`]): [`trad_rank`] is the single-rank kernel
//! over a [`Communicator`] (what each OS thread runs under the threaded
//! executor), and [`trad_recurrence`] is the sequential all-ranks driver
//! that advances every rank in lockstep over [`SimComm`] endpoints —
//! today's exact byte accounting.

use crate::distsim::{merge_rank_stats, DistMatrix, RankLocal};
use crate::exec::comm::{lockstep_halo_exchange, sim_comms, Communicator};
use crate::exec::RankRun;
use crate::inner::InnerExec;
use crate::mpk::dlb::Recurrence;
use crate::mpk::{kernel_step, MpkResult, SpmvBackend};
use crate::trace::{Span, TraceSession};

pub fn trad_mpk(
    dist: &DistMatrix,
    x: &[f64],
    p_m: usize,
    backend: &mut dyn SpmvBackend,
) -> MpkResult {
    trad_recurrence(dist, x, None, p_m, Recurrence::Power, backend)
}

/// Single-rank TRAD kernel: `p_m` rounds of {halo exchange of `y_{p-1}`,
/// full local SpMV}. `x0` is this rank's scattered input (halo tail
/// ignored); round `p` uses message tag `p - 1`. A parallel `inner`
/// executor row-splits each full sweep across its participants (all chunks
/// share one power, so they are trivially independent).
#[allow(clippy::too_many_arguments)]
pub fn trad_rank(
    r: &RankLocal,
    x0: &[f64],
    x_m1: Option<&[f64]>,
    p_m: usize,
    rec: Recurrence,
    comm: &mut dyn Communicator,
    backend: &mut dyn SpmvBackend,
    inner: &mut InnerExec,
) -> RankRun {
    assert!(p_m >= 1);
    debug_assert!(
        crate::verify::debug_check_rank(r).is_empty(),
        "trad_rank: halo plans failed verification:\n{}",
        crate::verify::render(&crate::verify::debug_check_rank(r))
    );
    let nl = r.n_local();
    let mut ys: Vec<Vec<f64>> = Vec::with_capacity(p_m + 1);
    ys.push(x0.to_vec());
    for _ in 0..p_m {
        ys.push(r.new_vec());
    }
    let mut flop_nnz = 0usize;
    for p in 1..=p_m {
        let (prevs, cur) = ys.split_at_mut(p);
        comm.exchange(r, (p - 1) as u64, &mut prevs[p - 1]);
        let prev2: Option<&[f64]> = if p >= 2 { Some(&prevs[p - 2][..]) } else { x_m1 };
        if inner.is_parallel() {
            flop_nnz += crate::inner::run_split_range(
                inner,
                &r.a,
                rec,
                prev2,
                &prevs[p - 1],
                &mut cur[0],
                0,
                nl,
                p,
                backend,
                comm.tracer(),
            );
        } else {
            let t0 = comm.tracer().now();
            flop_nnz += kernel_step(&r.a, rec, prev2, &prevs[p - 1], &mut cur[0], 0, nl, backend);
            comm.tracer().closed_span(Span::TradSpmv { power: p as u32 }, t0);
        }
    }
    comm.tracer().counter("flop_nnz", flop_nnz as f64);
    RankRun { ys, flop_nnz }
}

/// TRAD generalized over a three-term recurrence (Chebyshev baseline for
/// paper §7: "previous state-of-the-art implementations … perform
/// back-to-back SpMVs"). Sequential lockstep execution over [`SimComm`].
pub fn trad_recurrence(
    dist: &DistMatrix,
    x: &[f64],
    x_m1: Option<&[f64]>,
    p_m: usize,
    rec: Recurrence,
    backend: &mut dyn SpmvBackend,
) -> MpkResult {
    trad_recurrence_traced(dist, x, x_m1, p_m, rec, backend, None, None)
}

/// [`trad_recurrence`] with an optional [`TraceSession`]: each rank's
/// [`SimComm`] gets an attached recorder, compute steps are wrapped in
/// `trad.spmv(p)` spans, and the drained events are absorbed back. Ranks
/// whose entry in `inners` is a parallel [`InnerExec`] row-split each sweep
/// and emit `inner.task` spans instead.
#[allow(clippy::too_many_arguments)]
pub fn trad_recurrence_traced(
    dist: &DistMatrix,
    x: &[f64],
    x_m1: Option<&[f64]>,
    p_m: usize,
    rec: Recurrence,
    backend: &mut dyn SpmvBackend,
    mut trace: Option<&mut TraceSession>,
    mut inners: Option<&mut [InnerExec]>,
) -> MpkResult {
    assert!(p_m >= 1);
    let nr = dist.n_ranks();
    // ys[p][rank] = local vector (with halo tail) of power p
    let mut ys: Vec<Vec<Vec<f64>>> = Vec::with_capacity(p_m + 1);
    ys.push(dist.scatter(x));
    for _ in 0..p_m {
        ys.push(dist.ranks.iter().map(|r| r.new_vec()).collect());
    }
    let ym1: Option<Vec<Vec<f64>>> = x_m1.map(|v| dist.scatter(v));

    let mut comms = sim_comms(nr);
    if let Some(ts) = trace.as_deref() {
        for (i, c) in comms.iter_mut().enumerate() {
            c.set_tracer(ts.recorder(i));
        }
    }
    let mut flop_nnz = 0usize;
    for p in 1..=p_m {
        // y[:, p-1] <- haloComm(y[:, p-1])
        lockstep_halo_exchange(&mut comms, &dist.ranks, (p - 1) as u64, &mut ys[p - 1]);
        // y[:, p] <- SpMV(y[:, p-1], A_i) (+ recurrence combine)
        let (prevs, cur) = ys.split_at_mut(p);
        for i in 0..nr {
            let r = &dist.ranks[i];
            let prev2: Option<&[f64]> = if p >= 2 {
                Some(&prevs[p - 2][i][..])
            } else {
                ym1.as_ref().map(|v| &v[i][..])
            };
            let par = inners.as_deref_mut().map(|v| &mut v[i]).filter(|e| e.is_parallel());
            if let Some(ie) = par {
                flop_nnz += crate::inner::run_split_range(
                    ie,
                    &r.a,
                    rec,
                    prev2,
                    &prevs[p - 1][i],
                    &mut cur[0][i],
                    0,
                    r.n_local(),
                    p,
                    backend,
                    comms[i].tracer(),
                );
            } else {
                let t0 = comms[i].tracer().now();
                flop_nnz += kernel_step(
                    &r.a,
                    rec,
                    prev2,
                    &prevs[p - 1][i],
                    &mut cur[0][i],
                    0,
                    r.n_local(),
                    backend,
                );
                comms[i].tracer().closed_span(Span::TradSpmv { power: p as u32 }, t0);
            }
        }
    }

    if let Some(ts) = trace.as_deref_mut() {
        for (i, c) in comms.iter_mut().enumerate() {
            ts.absorb(i, c.take_trace_events());
        }
    }
    let per_rank: Vec<_> = comms.iter().map(|c| c.stats().clone()).collect();
    MpkResult {
        powers: (1..=p_m).map(|p| dist.gather(&ys[p])).collect(),
        comm: merge_rank_stats(&per_rank),
        flop_nnz,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::mpk::NativeBackend;
    use crate::partition::{partition, Method};

    /// Serial reference: y_p = A^p x by repeated full SpMV.
    pub fn serial_mpk(a: &crate::matrix::CsrMatrix, x: &[f64], p_m: usize) -> Vec<Vec<f64>> {
        let mut out = Vec::new();
        let mut cur = x.to_vec();
        for _ in 0..p_m {
            let mut y = vec![0.0; a.n_rows()];
            a.spmv(&cur, &mut y);
            out.push(y.clone());
            cur = y;
        }
        out
    }

    #[test]
    fn trad_matches_serial_reference() {
        let a = gen::stencil_2d_5pt(10, 8);
        let x: Vec<f64> = (0..80).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let want = serial_mpk(&a, &x, 4);
        for np in [1, 2, 3, 5] {
            let p = partition(&a, np, Method::Block);
            let d = crate::distsim::DistMatrix::build(&a, &p);
            let got = trad_mpk(&d, &x, 4, &mut NativeBackend);
            assert_eq!(got.powers.len(), 4);
            for (gp, wp) in got.powers.iter().zip(&want) {
                for (u, v) in gp.iter().zip(wp) {
                    assert!((u - v).abs() < 1e-11, "np={np}: {u} vs {v}");
                }
            }
            // one exchange round per power
            assert_eq!(got.comm.rounds, 4);
            assert_eq!(got.flop_nnz, 4 * a.nnz());
        }
    }

    #[test]
    fn trad_comm_bytes_scale_with_halo() {
        let a = gen::stencil_2d_5pt(16, 16);
        let p = partition(&a, 4, Method::Block);
        let d = crate::distsim::DistMatrix::build(&a, &p);
        let x = vec![1.0; 256];
        let got = trad_mpk(&d, &x, 3, &mut NativeBackend);
        assert_eq!(got.comm.bytes, 3 * d.total_halo() * 8);
    }
}
