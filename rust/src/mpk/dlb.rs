//! DLB-MPK — the paper's Distributed Level-Blocked Matrix Power Kernel
//! (paper §5, Alg. 2, Fig. 6).
//!
//! Three phases per rank:
//!
//! 1. **Initial halo exchange** of the input vector (identical to TRAD's
//!    first exchange).
//! 2. **Local level-blocked MPK**: a cache-blocked wavefront over
//!    boundary-rooted BFS levels promotes the bulk `M` (distance ≥ p_m from
//!    the halo) all the way to power `p_m`, and each distance class `I_k`
//!    (k < p_m) up to power `k` — the maximum its halo dependencies permit.
//! 3. **Iterative remainder**: `p_m − 1` rounds of {halo exchange of
//!    `y_p`, promote every unfinished class by one power}. Round `p`
//!    advances `I_k` from power `p + k − 1` to `p + k` for `k ≤ p_m − p`.
//!
//! ## Pipelined remainder (`DlbOptions::async_remainder`)
//!
//! In round `p` only class `I_1` (exactly the boundary rows) reads the
//! incoming halo of `y_p`; every deeper class reads already-final local
//! data. The async remainder exploits this: the plan splits `I_1` by which
//! peer's halo segment feeds each row ([`DlbRankPlan::seg_rows`] /
//! [`DlbRankPlan::multi_rows`]), receives complete in **arrival order**
//! ([`Communicator::recv_any`] over the round's posted receives, with a
//! nonblocking `try_recv` sweep first), and a segment's exclusive rows
//! advance the moment that segment lands — while the other messages are
//! still in flight. Multi-peer rows and the deeper classes follow once the
//! round's halo is complete, and intermediate rounds close without a
//! barrier ([`Communicator::advance_round`]; the sweep's final round still
//! barriers to keep cross-sweep tag reuse safe). Every row is advanced
//! exactly once from fully-final inputs by the same per-row kernel, so the
//! result is bitwise identical to the lockstep path in any completion
//! order.
//!
//! Tag scheme: phase 1 uses tag `0`; remainder round `p` uses tag `p` for
//! every peer, and a receive is identified by the pair `(from, p)` — one
//! message per (round, peer-segment), matched out of order by the
//! transport's unexpected-message queue.
//!
//! Level structure: local vertices are labeled by graph distance from the
//! halo (multi-source BFS seeded at halo slots), so distance class `I_k`
//! *is* BFS level `k − 1`, and the distance shells continue inward through
//! `M` — giving RACE-style levels for cache blocking and the class
//! bookkeeping in one structure. Vertices unreachable from any halo (or all
//! vertices, in a single-rank run) get ordinary BFS levels appended after
//! the reachable ones; they belong to `M` and never interact with the halo.

use crate::distsim::{merge_rank_stats, DistMatrix, RankLocal};
use crate::exec::comm::{lockstep_halo_exchange, sim_comms, Communicator};
use crate::exec::RankRun;
use crate::graph::distance::multi_source_distances;
use crate::graph::{bfs_levels, Adjacency, Levels};
use crate::inner::{InnerExec, InnerWork, MatPtr, SharedBuf, SharedBufMut};
use crate::mpk::{kernel_step, MpkResult, SpmvBackend};
use crate::race::grouping::group_levels_solo_prefix;
use crate::race::schedule::{parallel_batches, wavefront_capped, Step};
use crate::trace::{RankRecorder, Span, TraceSession};

/// Tuning knobs mirroring the paper's RACE parameters (§6.2).
#[derive(Clone, Copy, Debug)]
pub struct DlbOptions {
    /// Cache budget `C` in bytes (per rank).
    pub cache_bytes: usize,
    /// Maximum recursion stage `s_m` (bulky-level split cap).
    pub s_m: usize,
    /// Pipeline phase 3: complete each remainder round's receives in
    /// arrival order and advance the class-`I_1` rows fed by a peer's halo
    /// segment the moment that segment lands, closing intermediate rounds
    /// without a barrier (see the module docs). Bitwise identical to the
    /// lockstep path; off by default.
    pub async_remainder: bool,
}

impl Default for DlbOptions {
    fn default() -> Self {
        Self { cache_bytes: 32 << 20, s_m: 50, async_remainder: false }
    }
}

/// Per-rank preprocessing result (reusable across runs with the same
/// matrix/partition/p_m — the paper's setup cost is likewise amortized).
#[derive(Clone, Debug)]
pub struct DlbRankPlan {
    /// Permutation applied to the rank (perm[new] = old).
    pub perm: Vec<usize>,
    /// Levels of the permuted local matrix: level `k-1` = class `I_k` for
    /// `k < p_m`; all later levels are the bulk `M`.
    pub levels: Levels,
    /// Group row ranges (permuted indexing).
    pub ranges: Vec<(usize, usize)>,
    /// Power cap per group for phase 2.
    pub caps: Vec<usize>,
    /// Phase-2 wavefront schedule.
    pub schedule: Vec<Step>,
    /// [`schedule`](Self::schedule) regrouped into dependency-free batches
    /// ([`parallel_batches`]) for a parallel [`InnerExec`]; flattening the
    /// batches yields a valid schedule over the same step multiset.
    pub batches: Vec<Vec<Step>>,
    /// Row ranges of classes `I_1..I_{p_m-1}` (phase 3 work lists):
    /// `class_ranges[k-1]` = rows of `I_k`; empty if the class is empty.
    pub class_ranges: Vec<(usize, usize)>,
    /// |M| — bulk size (for Eq. 2 overhead).
    pub bulk_rows: usize,
    /// Async phase-3 work split: `seg_rows[j]` = class-`I_1` rows whose
    /// halo reads all fall inside recv plan `j`'s slot segment (sorted
    /// ascending — advanceable the moment peer `j`'s message lands).
    pub seg_rows: Vec<Vec<u32>>,
    /// Class-`I_1` rows reading two or more peers' segments (or none, for
    /// structurally one-sided couplings): advanced only after every
    /// segment of the round has landed. Together with
    /// [`seg_rows`](Self::seg_rows) this partitions `class_ranges[0]`.
    pub multi_rows: Vec<u32>,
    /// Copied from [`DlbOptions::async_remainder`] so per-rank kernels and
    /// pool workers see the knob through the plan they already carry.
    pub async_remainder: bool,
}

/// The full distributed plan: permuted rank-locals + per-rank plans.
pub struct DlbPlan {
    pub dist: std::sync::Arc<DistMatrix>,
    pub ranks: Vec<DlbRankPlan>,
    pub p_m: usize,
}

/// p-independent preprocessing: boundary-distance levels + the local
/// permutation, computed once per (matrix, partition). Re-plan cheaply for
/// any `(p_m, C, s_m)` with [`plan_from_pre`] — mirrors how RACE amortizes
/// its preprocessing across tuning runs (paper §6.2).
pub struct DlbPre {
    pub dist: std::sync::Arc<DistMatrix>,
    levels: Vec<Levels>,
}

/// Output of [`dlb_mpk`]: the result plus the plan's overhead metrics.
pub struct DlbOutput {
    pub result: MpkResult,
    /// Paper Eq. (3) global overhead.
    pub overhead: f64,
}

/// Build the per-rank level/schedule plan and permute the local matrices.
pub fn plan(dist: &DistMatrix, p_m: usize, opts: &DlbOptions) -> DlbPlan {
    plan_from_pre(&preprocess(dist), p_m, opts)
}

/// Compute levels + permutation once (see [`DlbPre`]).
pub fn preprocess(dist: &DistMatrix) -> DlbPre {
    let mut dist = dist.clone();
    let mut levels = Vec::with_capacity(dist.n_ranks());
    for r in &mut dist.ranks {
        levels.push(preprocess_rank(r));
    }
    DlbPre { dist: std::sync::Arc::new(dist), levels }
}

/// Build a plan for `(p_m, opts)` from shared preprocessing.
pub fn plan_from_pre(pre: &DlbPre, p_m: usize, opts: &DlbOptions) -> DlbPlan {
    assert!(p_m >= 1);
    let plans = pre
        .dist
        .ranks
        .iter()
        .zip(&pre.levels)
        .map(|(r, lv)| finish_rank_plan(r, lv, p_m, opts))
        .collect();
    DlbPlan { dist: pre.dist.clone(), ranks: plans, p_m }
}

/// Levels (boundary-rooted) + permutation for one rank; permutes `r`.
fn preprocess_rank(r: &mut RankLocal) -> Levels {
    let nl = r.n_local();
    let nv = r.vec_len();

    // adjacency over local + halo vertices (halo edges come from the local
    // rows that reference them)
    let g = if local_block_symmetric(&r.a, nl) {
        Adjacency::from_local_block(&r.a, nl)
    } else {
        Adjacency::from_matrix(&padded_square(&r.a, nv))
    };

    // distance from halo; level k-1 = distance k
    let level_of: Vec<u32> = if r.n_halo() == 0 {
        // single rank / no halo: plain BFS levels, all bulk
        let res = bfs_levels(&g, 0);
        res.level_of[..nl].to_vec()
    } else {
        let sources: Vec<u32> = (nl as u32..nv as u32).collect();
        let dist_from_halo = multi_source_distances(&g, &sources);
        let max_d = (0..nl)
            .map(|v| dist_from_halo[v])
            .filter(|&d| d != u32::MAX)
            .max()
            .unwrap_or(0);
        // unreachable vertices: plain BFS levels appended after max_d
        let mut level_of = vec![0u32; nl];
        let mut unreachable: Vec<u32> = Vec::new();
        for v in 0..nl {
            if dist_from_halo[v] == u32::MAX {
                unreachable.push(v as u32);
            } else {
                level_of[v] = dist_from_halo[v] - 1;
            }
        }
        if !unreachable.is_empty() {
            // BFS restricted to unreachable set (no edges to reachable set
            // exist, by definition of reachability)
            let sub = bfs_levels_subset(&g, &unreachable);
            for (i, &v) in unreachable.iter().enumerate() {
                level_of[v as usize] = max_d + sub[i];
            }
        }
        level_of
    };
    let n_levels = level_of.iter().copied().max().map_or(0, |m| m as usize + 1);
    let levels = Levels::from_level_of(&level_of, n_levels);

    // permute the rank so levels are contiguous
    r.permute_local(&levels.perm);
    levels
}

/// Grouping, caps, schedule, class ranges for one (p_m, opts) — cheap
/// relative to [`preprocess_rank`].
fn finish_rank_plan(r: &RankLocal, levels: &Levels, p_m: usize, opts: &DlbOptions) -> DlbRankPlan {
    let nl = r.n_local();
    let n_levels = levels.n_levels();

    // caps: class I_k (level k-1) stops at power k when there IS a halo
    let solo = if r.n_halo() == 0 { 0 } else { (p_m - 1).min(n_levels) };
    let groups = group_levels_solo_prefix(&r.a, levels, p_m, opts.cache_bytes, opts.s_m, solo);
    let caps: Vec<usize> = groups
        .level_span
        .iter()
        .map(|&(lo, _)| if r.n_halo() == 0 { p_m } else { (lo + 1).min(p_m) })
        .collect();
    let schedule = wavefront_capped(&groups, n_levels, p_m, &caps);
    let batches = parallel_batches(&schedule, &groups);

    // class row ranges for phase 3 (level k-1 = class k)
    let class_ranges: Vec<(usize, usize)> = (0..p_m.saturating_sub(1))
        .map(|k| {
            if r.n_halo() == 0 || k >= n_levels {
                (0, 0)
            } else {
                let rg = levels.rows(k);
                (rg.start, rg.end)
            }
        })
        .collect();
    let bulk_rows = if r.n_halo() == 0 {
        nl
    } else {
        let first_bulk = (p_m - 1).min(n_levels);
        nl - levels.level_ptr[first_bulk]
    };

    // async phase-3 split of I_1 by feeding peer segment: a row whose halo
    // reads all land in one recv plan's slots advances as soon as that
    // message arrives; rows coupling several peers (or none, if the
    // symmetrized graph adjacency has no matching column) wait for the
    // full round.
    let n_halo = r.n_halo();
    let mut seg_rows: Vec<Vec<u32>> = vec![Vec::new(); r.recv.len()];
    let mut multi_rows: Vec<u32> = Vec::new();
    if n_halo > 0 {
        if let Some(&(c_lo, c_hi)) = class_ranges.first() {
            let mut slot_owner = vec![usize::MAX; n_halo];
            for (j, rp) in r.recv.iter().enumerate() {
                for s in rp.slots.clone() {
                    slot_owner[s] = j;
                }
            }
            for row in c_lo..c_hi {
                let mut owner: Option<usize> = None;
                let mut multi = false;
                for &c in r.a.row_cols(row) {
                    let c = c as usize;
                    if c >= nl {
                        let j = slot_owner[c - nl];
                        match owner {
                            None => owner = Some(j),
                            Some(o) if o != j => {
                                multi = true;
                                break;
                            }
                            Some(_) => {}
                        }
                    }
                }
                match owner {
                    Some(j) if !multi => seg_rows[j].push(row as u32),
                    _ => multi_rows.push(row as u32),
                }
            }
        }
    }

    DlbRankPlan {
        perm: levels.perm.clone(),
        levels: levels.clone(),
        ranges: groups.ranges.clone(),
        caps,
        schedule,
        batches,
        class_ranges,
        bulk_rows,
        seg_rows,
        multi_rows,
        async_remainder: opts.async_remainder,
    }
}

/// Collapse a sorted row list into maximal contiguous `[lo, hi)` runs so
/// segment advances reuse the range kernel — bitwise identical to one
/// contiguous call, since `spmv_range` computes each row independently.
pub fn contiguous_runs(rows: &[u32]) -> Vec<(usize, usize)> {
    let mut runs: Vec<(usize, usize)> = Vec::new();
    for &row in rows {
        let row = row as usize;
        match runs.last_mut() {
            Some((_, hi)) if *hi == row => *hi += 1,
            _ => runs.push((row, row + 1)),
        }
    }
    runs
}

/// Advance the contiguous `runs` of one class from power `power - 1` to
/// `power` — the per-segment compute of the async remainder. Serial mode
/// records `span` once around all runs; a parallel inner pool gets one
/// row-split batch over the runs (emitting `inner.task` spans instead).
#[allow(clippy::too_many_arguments)]
fn advance_runs(
    a: &crate::matrix::CsrMatrix,
    runs: &[(usize, usize)],
    power: usize,
    rec: Recurrence,
    prev2: Option<&[f64]>,
    prev: &[f64],
    cur: &mut [f64],
    span: Span,
    backend: &mut dyn SpmvBackend,
    inner: Option<&mut InnerExec>,
    tracer: &mut RankRecorder,
) -> usize {
    if runs.is_empty() {
        return 0;
    }
    match inner {
        Some(ie) if ie.is_parallel() => {
            crate::inner::run_split_runs(ie, a, rec, prev2, prev, cur, runs, power, backend, tracer)
        }
        _ => {
            let t0 = tracer.now();
            let mut nnz = 0usize;
            for &(lo, hi) in runs {
                nnz += kernel_step(a, rec, prev2, prev, cur, lo, hi, backend);
            }
            tracer.closed_span(span, t0);
            nnz
        }
    }
}

/// Check that the local-local sub-pattern is symmetric (fast-path guard).
fn local_block_symmetric(a: &crate::matrix::CsrMatrix, nl: usize) -> bool {
    for r in 0..nl {
        for &c in a.row_cols(r) {
            let c = c as usize;
            if c < nl && c != r && a.row_cols(c).binary_search(&(r as u32)).is_err() {
                return false;
            }
        }
    }
    true
}

/// Embed the rectangular local block (nl × nv) into an nv × nv square so the
/// graph view covers halo vertices too (their rows are empty; symmetrization
/// supplies the back-edges).
fn padded_square(a: &crate::matrix::CsrMatrix, nv: usize) -> crate::matrix::CsrMatrix {
    let mut rowptr = a.rowptr.clone();
    rowptr.resize(nv + 1, *a.rowptr.last().unwrap());
    crate::matrix::CsrMatrix {
        n_rows: nv,
        n_cols: nv,
        rowptr,
        colidx: a.colidx.clone(),
        values: a.values.clone(),
    }
}

/// BFS levels over an induced subset (restarting per component); returns the
/// level of each subset vertex, aligned with `verts`.
fn bfs_levels_subset(g: &Adjacency, verts: &[u32]) -> Vec<u32> {
    let mut in_set = std::collections::HashMap::new();
    for (i, &v) in verts.iter().enumerate() {
        in_set.insert(v, i);
    }
    let mut level = vec![u32::MAX; verts.len()];
    let mut next_level = 0u32;
    for start in 0..verts.len() {
        if level[start] != u32::MAX {
            continue;
        }
        let mut frontier = vec![verts[start]];
        level[start] = next_level;
        let mut cur = next_level;
        while !frontier.is_empty() {
            let mut nf = Vec::new();
            for &u in &frontier {
                for &v in g.neighbors(u as usize) {
                    if let Some(&i) = in_set.get(&v) {
                        if level[i] == u32::MAX {
                            level[i] = cur + 1;
                            nf.push(v);
                        }
                    }
                }
            }
            frontier = nf;
            cur += 1;
        }
        next_level = cur + 1;
    }
    level
}

/// Which three-term structure the wavefront promotes (the dependency
/// pattern is identical, so DLB applies unchanged — paper §7).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Recurrence {
    /// `y_p = A y_{p-1}` — the matrix power kernel.
    Power,
    /// `y_p = 2 A y_{p-1} − y_{p-2}` — the Chebyshev recurrence (Eq. 6).
    /// `y_{-1}` is supplied by the caller (`x_m1`); if absent, step 1 is the
    /// wind-up `y_1 = A y_0` (Eq. 7).
    Chebyshev,
}

/// Execute DLB-MPK with a prebuilt plan.
pub fn execute(
    plan: &DlbPlan,
    x: &[f64],
    backend: &mut dyn SpmvBackend,
) -> MpkResult {
    execute_recurrence(plan, x, None, Recurrence::Power, backend)
}

/// Reusable power-vector workspace: avoids re-allocating and re-zeroing
/// `(p_m + 1) × ranks` vectors on every MPK invocation (the dominant
/// overhead for repeated small/medium runs — EXPERIMENTS.md §Perf L3-1).
#[derive(Default)]
pub struct Workspace {
    ys: Vec<Vec<Vec<f64>>>,
    ym1: Vec<Vec<f64>>,
}

impl Workspace {
    /// Ensure shape `(p_m + 1) × ranks × vec_len`; reuse existing buffers.
    fn prepare(&mut self, dist: &DistMatrix, p_m: usize, need_ym1: bool) {
        self.ys.resize_with(p_m + 1, Vec::new);
        for pw in &mut self.ys {
            pw.resize_with(dist.n_ranks(), Vec::new);
            for (r, v) in dist.ranks.iter().zip(pw.iter_mut()) {
                v.resize(r.vec_len(), 0.0);
            }
        }
        if need_ym1 {
            self.ym1.resize_with(dist.n_ranks(), Vec::new);
            for (r, v) in dist.ranks.iter().zip(self.ym1.iter_mut()) {
                v.resize(r.vec_len(), 0.0);
            }
        }
    }

    /// Scatter a global vector into the rank-local layout of `power`.
    fn scatter_into(&mut self, dist: &DistMatrix, power: usize, x: &[f64]) {
        for (r, v) in dist.ranks.iter().zip(self.ys[power].iter_mut()) {
            for (l, &g) in r.owned.iter().enumerate() {
                v[l] = x[g];
            }
        }
    }
}

/// Generalized DLB driver over a three-term recurrence (see [`Recurrence`]).
pub fn execute_recurrence(
    plan: &DlbPlan,
    x: &[f64],
    x_m1: Option<&[f64]>,
    rec: Recurrence,
    backend: &mut dyn SpmvBackend,
) -> MpkResult {
    let mut ws = Workspace::default();
    execute_recurrence_with(plan, x, x_m1, rec, backend, &mut ws)
}

/// Workspace-reusing variant of [`execute_recurrence`].
pub fn execute_recurrence_with(
    plan: &DlbPlan,
    x: &[f64],
    x_m1: Option<&[f64]>,
    rec: Recurrence,
    backend: &mut dyn SpmvBackend,
    ws: &mut Workspace,
) -> MpkResult {
    execute_recurrence_traced(plan, x, x_m1, rec, backend, ws, None, None)
}

/// [`execute_recurrence_with`] with an optional [`TraceSession`]: per-rank
/// recorders ride the [`SimComm`] endpoints, wavefront steps become
/// `dlb.wavefront(g,p)` spans and remainder advances `dlb.remainder(r,k)`
/// spans, and the drained events are absorbed back. Ranks whose entry in
/// `inners` is a parallel [`InnerExec`] run phase 2 batch-by-batch and
/// phase 3 row-split, emitting `inner.task` spans instead of the coarse
/// per-step ones.
#[allow(clippy::too_many_arguments)]
pub fn execute_recurrence_traced(
    plan: &DlbPlan,
    x: &[f64],
    x_m1: Option<&[f64]>,
    rec: Recurrence,
    backend: &mut dyn SpmvBackend,
    ws: &mut Workspace,
    mut trace: Option<&mut TraceSession>,
    mut inners: Option<&mut [InnerExec]>,
) -> MpkResult {
    let p_m = plan.p_m;
    let dist = &plan.dist;
    let nr = dist.n_ranks();

    ws.prepare(dist, p_m, x_m1.is_some());
    ws.scatter_into(dist, 0, x);
    if let Some(v) = x_m1 {
        for (r, w) in dist.ranks.iter().zip(ws.ym1.iter_mut()) {
            for (l, &g) in r.owned.iter().enumerate() {
                w[l] = v[g];
            }
        }
    }
    let (ys, ym1_store) = (&mut ws.ys, &ws.ym1);
    let ym1: Option<&[Vec<f64>]> = x_m1.map(|_| ym1_store.as_slice());

    let mut comms = sim_comms(nr);
    if let Some(ts) = trace.as_deref() {
        for (i, c) in comms.iter_mut().enumerate() {
            c.set_tracer(ts.recorder(i));
        }
    }
    let mut flop_nnz = 0usize;

    // One wavefront/class step for rank `i`: y_p[lo..hi] from y_{p-1} (and
    // y_{p-2} for Chebyshev) via the shared compute primitive.
    let do_step = |ys: &mut [Vec<Vec<f64>>],
                   ym1: &Option<&[Vec<f64>]>,
                   flop_nnz: &mut usize,
                   i: usize,
                   lo: usize,
                   hi: usize,
                   p: usize,
                   backend: &mut dyn SpmvBackend| {
        let r = &dist.ranks[i];
        let (prevs, cur) = ys.split_at_mut(p);
        let prev2: Option<&[f64]> = if p >= 2 {
            Some(&prevs[p - 2][i][..])
        } else {
            ym1.map(|v| &v[i][..])
        };
        *flop_nnz +=
            kernel_step(&r.a, rec, prev2, &prevs[p - 1][i], &mut cur[0][i], lo, hi, backend);
    };

    // ---- phase 1: initial halo exchange (same routine as TRAD)
    lockstep_halo_exchange(&mut comms, &dist.ranks, 0, &mut ys[0]);

    // ---- phase 2: local level-blocked wavefront (cache-blocked)
    for i in 0..nr {
        let pl = &plan.ranks[i];
        let par = inners.as_deref_mut().map(|v| &mut v[i]).filter(|e| e.is_parallel());
        if let Some(ie) = par {
            let r = &dist.ranks[i];
            let xm1v = ym1.map(|v| SharedBuf::of(&v[i]));
            let views: Vec<SharedBufMut> =
                ys.iter_mut().map(|pw| SharedBufMut::of(&mut pw[i])).collect();
            for batch in &pl.batches {
                let work: Vec<InnerWork> = batch
                    .iter()
                    .map(|s| {
                        let (lo, hi) = pl.ranges[s.group];
                        let p = s.power;
                        InnerWork::Range {
                            a: MatPtr::of(&r.a),
                            rec,
                            prev2: if p >= 2 { Some(views[p - 2].read()) } else { xm1v },
                            prev: views[p - 1].read(),
                            cur: views[p],
                            lo,
                            hi,
                            span: Span::InnerTask { group: s.group as u32, power: p as u32 },
                        }
                    })
                    .collect();
                flop_nnz += ie.run_batch(work, backend, comms[i].tracer());
            }
        } else {
            for s in &pl.schedule {
                let (lo, hi) = pl.ranges[s.group];
                let t0 = comms[i].tracer().now();
                do_step(ys, &ym1, &mut flop_nnz, i, lo, hi, s.power, backend);
                comms[i].tracer().closed_span(
                    Span::DlbWavefront { group: s.group as u32, power: s.power as u32 },
                    t0,
                );
            }
        }
    }

    // ---- phase 3: p_m - 1 rounds of {exchange, advance classes}
    let async_rem = plan.ranks.first().map_or(false, |rp| rp.async_remainder);
    for p in 1..p_m {
        if async_rem {
            // Pipelined variant: round 1 sends are posted here; every later
            // round's sends were already posted by the previous round's
            // `async_round` (right after its class-`I_1` advance), so by the
            // time rank `i` drains round `p` the full halo is in its
            // mailbox and the nonblocking sweep completes deterministically
            // in recv-plan order.
            if p == 1 {
                for ((c, r), xv) in comms.iter_mut().zip(&dist.ranks).zip(ys[1].iter()) {
                    c.post_halo_sends(r, 1, xv);
                }
            }
            for i in 0..nr {
                let r = &dist.ranks[i];
                let pl = &plan.ranks[i];
                let par =
                    inners.as_deref_mut().map(|v| &mut v[i]).filter(|e| e.is_parallel());
                let mut stack: Vec<&mut Vec<f64>> =
                    ys.iter_mut().map(|pw| &mut pw[i]).collect();
                async_round(
                    r,
                    pl,
                    p_m,
                    p,
                    &mut stack,
                    rec,
                    &mut comms[i],
                    backend,
                    par,
                    &mut flop_nnz,
                );
            }
            continue;
        }
        lockstep_halo_exchange(&mut comms, &dist.ranks, p as u64, &mut ys[p]);
        for i in 0..nr {
            let pl = &plan.ranks[i];
            let par = inners.as_deref_mut().map(|v| &mut v[i]).filter(|e| e.is_parallel());
            if let Some(ie) = par {
                let r = &dist.ranks[i];
                for k in 1..=(p_m - p) {
                    let (lo, hi) = pl.class_ranges[k - 1];
                    if lo == hi {
                        continue;
                    }
                    // advance I_k from power p + k - 1 to p + k, row-split
                    let (prevs, cur) = ys.split_at_mut(p + k);
                    let prev2: Option<&[f64]> = if p + k >= 2 {
                        Some(&prevs[p + k - 2][i][..])
                    } else {
                        ym1.map(|v| &v[i][..])
                    };
                    flop_nnz += crate::inner::run_split_range(
                        ie,
                        &r.a,
                        rec,
                        prev2,
                        &prevs[p + k - 1][i],
                        &mut cur[0][i],
                        lo,
                        hi,
                        p + k,
                        backend,
                        comms[i].tracer(),
                    );
                }
            } else {
                for k in 1..=(p_m - p) {
                    let (lo, hi) = pl.class_ranges[k - 1];
                    if lo == hi {
                        continue;
                    }
                    // advance I_k from power p + k - 1 to p + k
                    let t0 = comms[i].tracer().now();
                    do_step(ys, &ym1, &mut flop_nnz, i, lo, hi, p + k, backend);
                    comms[i].tracer().closed_span(
                        Span::DlbRemainder { round: p as u32, class: k as u32 },
                        t0,
                    );
                }
            }
        }
    }

    if let Some(ts) = trace.as_deref_mut() {
        for (i, c) in comms.iter_mut().enumerate() {
            ts.absorb(i, c.take_trace_events());
        }
    }
    let per_rank: Vec<_> = comms.iter().map(|c| c.stats().clone()).collect();
    MpkResult {
        powers: (1..=p_m).map(|p| dist.gather(&ys[p])).collect(),
        comm: merge_rank_stats(&per_rank),
        flop_nnz,
    }
}

/// One async remainder round `p` for one rank (`DlbOptions::async_remainder`):
/// complete the round's posted receives in **arrival order** (nonblocking
/// `try_recv` sweep, then `recv_any`), advancing each landed segment's
/// exclusive `I_1` rows immediately; once the whole halo landed, advance
/// the multi-peer rows, post the next round's sends, and advance the
/// deeper classes. Intermediate rounds close without a barrier
/// ([`Communicator::advance_round`]); the final round keeps the real
/// [`Communicator::end_round`] so cross-sweep tag reuse stays safe.
///
/// `ys` is one rank's power stack (`ys[q]` = `y_q`, halo tail included) —
/// borrowed per power so both the per-rank kernel and the lockstep driver
/// (whose storage is `[power][rank]`) can call this. Every row is advanced
/// exactly once from fully-final inputs by the same per-row kernel as the
/// lockstep path, so results are bitwise identical in any completion
/// order.
#[allow(clippy::too_many_arguments)]
fn async_round(
    r: &RankLocal,
    pl: &DlbRankPlan,
    p_m: usize,
    p: usize,
    ys: &mut [&mut Vec<f64>],
    rec: Recurrence,
    comm: &mut dyn Communicator,
    backend: &mut dyn SpmvBackend,
    mut inner: Option<&mut InnerExec>,
    flop_nnz: &mut usize,
) {
    let nl = r.n_local();
    let tag = p as u64;
    let mut outstanding: Vec<usize> = (0..r.recv.len()).collect();
    comm.tracer().counter("dlb.outstanding", outstanding.len() as f64);
    while !outstanding.is_empty() {
        // Opportunistic nonblocking sweep first, then block for whichever
        // posted receive lands next.
        let hit = outstanding
            .iter()
            .enumerate()
            .find_map(|(pos, &j)| comm.try_recv(r.recv[j].from, tag).map(|pay| (pos, pay)));
        let (pos, payload) = match hit {
            Some(x) => x,
            None => {
                let reqs: Vec<(usize, u64)> =
                    outstanding.iter().map(|&j| (r.recv[j].from, tag)).collect();
                comm.recv_any(&reqs)
            }
        };
        let j = outstanding.remove(pos);
        let rp = &r.recv[j];
        debug_assert_eq!(payload.len(), rp.slots.len(), "halo payload length");
        ys[p][nl + rp.slots.start..nl + rp.slots.end].copy_from_slice(&payload);
        comm.tracer().counter("dlb.outstanding", outstanding.len() as f64);
        // Advance the rows fed only by this segment from power p to p + 1.
        let runs = contiguous_runs(&pl.seg_rows[j]);
        let (prevs, cur) = ys.split_at_mut(p + 1);
        *flop_nnz += advance_runs(
            &r.a,
            &runs,
            p + 1,
            rec,
            Some(&prevs[p - 1][..]),
            &prevs[p][..],
            &mut cur[0][..],
            Span::DlbSegment { round: p as u32, class: 1, peer: rp.from as u32 },
            backend,
            inner.as_mut().map(|i| &mut **i),
            comm.tracer(),
        );
    }
    // Multi-peer rows complete class I_1 now that the whole halo landed.
    {
        let runs = contiguous_runs(&pl.multi_rows);
        let (prevs, cur) = ys.split_at_mut(p + 1);
        *flop_nnz += advance_runs(
            &r.a,
            &runs,
            p + 1,
            rec,
            Some(&prevs[p - 1][..]),
            &prevs[p][..],
            &mut cur[0][..],
            Span::DlbRemainder { round: p as u32, class: 1 },
            backend,
            inner.as_mut().map(|i| &mut **i),
            comm.tracer(),
        );
    }
    if p + 1 < p_m {
        // Same early post as the lockstep path: y_{p+1} is final on every
        // send row once all of I_1 reached power p + 1.
        comm.post_halo_sends(r, (p + 1) as u64, &ys[p + 1][..]);
    }
    // Deeper classes read only local, already-final data.
    for k in 2..=(p_m - p) {
        let (lo, hi) = pl.class_ranges[k - 1];
        if lo == hi {
            continue;
        }
        let (prevs, cur) = ys.split_at_mut(p + k);
        *flop_nnz += advance_runs(
            &r.a,
            &[(lo, hi)],
            p + k,
            rec,
            Some(&prevs[p + k - 2][..]),
            &prevs[p + k - 1][..],
            &mut cur[0][..],
            Span::DlbRemainder { round: p as u32, class: k as u32 },
            backend,
            inner.as_mut().map(|i| &mut **i),
            comm.tracer(),
        );
    }
    if p + 1 < p_m {
        comm.advance_round();
    } else {
        comm.end_round();
    }
}

/// Single-rank DLB kernel over a [`Communicator`] — what each OS thread
/// runs under the threaded executor.
///
/// Same three phases as the lockstep driver, with one crucial difference:
/// the halo **sends** of each remainder round are posted as soon as their
/// payload rows are final, so the messages travel while this rank is still
/// computing — `y_1`'s sends go out mid-wavefront (overlapping the bulk of
/// phase 2), and round `p+1`'s sends go out right after the class-`I_1`
/// advance of round `p` (overlapping the deeper-class advances). This
/// realizes the paper's §5 communication/computation overlap with real
/// nonblocking messages. Tags: phase 1 uses `0`, remainder round `p` uses
/// `p`.
#[allow(clippy::too_many_arguments)]
pub fn dlb_rank(
    r: &RankLocal,
    pl: &DlbRankPlan,
    p_m: usize,
    x0: &[f64],
    x_m1: Option<&[f64]>,
    rec: Recurrence,
    comm: &mut dyn Communicator,
    backend: &mut dyn SpmvBackend,
    inner: &mut InnerExec,
) -> RankRun {
    assert!(p_m >= 1);
    debug_assert!(
        crate::verify::debug_check_dlb_rank(r, pl).is_empty(),
        "dlb_rank: plan failed verification:\n{}",
        crate::verify::render(&crate::verify::debug_check_dlb_rank(r, pl))
    );
    let mut ys: Vec<Vec<f64>> = Vec::with_capacity(p_m + 1);
    ys.push(x0.to_vec());
    for _ in 0..p_m {
        ys.push(r.new_vec());
    }
    let mut flop_nnz = 0usize;

    // ---- phase 1: initial halo exchange
    comm.exchange(r, 0, &mut ys[0]);

    // ---- phase 2: cache-blocked wavefront, y_1 sends posted the moment
    // every send-plan row has reached power 1
    let send_max_row = r
        .send
        .iter()
        .flat_map(|sp| sp.rows.iter())
        .map(|&row| row as usize + 1)
        .max()
        .unwrap_or(0);
    let mut await_post = p_m >= 2;
    let mut groups_left = pl.ranges.iter().filter(|&&(lo, _)| lo < send_max_row).count();
    if await_post && groups_left == 0 {
        comm.post_halo_sends(r, 1, &ys[1]);
        await_post = false;
    }
    if inner.is_parallel() {
        let xm1v = x_m1.map(SharedBuf::of);
        let views: Vec<SharedBufMut> = ys.iter_mut().map(|v| SharedBufMut::of(v)).collect();
        for batch in &pl.batches {
            let work: Vec<InnerWork> = batch
                .iter()
                .map(|s| {
                    let (lo, hi) = pl.ranges[s.group];
                    let p = s.power;
                    InnerWork::Range {
                        a: MatPtr::of(&r.a),
                        rec,
                        prev2: if p >= 2 { Some(views[p - 2].read()) } else { xm1v },
                        prev: views[p - 1].read(),
                        cur: views[p],
                        lo,
                        hi,
                        span: Span::InnerTask { group: s.group as u32, power: p as u32 },
                    }
                })
                .collect();
            flop_nnz += inner.run_batch(work, backend, comm.tracer());
            if await_post {
                for s in batch {
                    if s.power == 1 && pl.ranges[s.group].0 < send_max_row {
                        groups_left -= 1;
                    }
                }
                if groups_left == 0 {
                    comm.post_halo_sends(r, 1, &ys[1]);
                    await_post = false;
                }
            }
        }
    } else {
        for s in &pl.schedule {
            let (lo, hi) = pl.ranges[s.group];
            let p = s.power;
            {
                let (prevs, cur) = ys.split_at_mut(p);
                let prev2: Option<&[f64]> = if p >= 2 { Some(&prevs[p - 2][..]) } else { x_m1 };
                let t0 = comm.tracer().now();
                flop_nnz +=
                    kernel_step(&r.a, rec, prev2, &prevs[p - 1], &mut cur[0], lo, hi, backend);
                comm.tracer().closed_span(
                    Span::DlbWavefront { group: s.group as u32, power: p as u32 },
                    t0,
                );
            }
            if await_post && p == 1 && lo < send_max_row {
                groups_left -= 1;
                if groups_left == 0 {
                    comm.post_halo_sends(r, 1, &ys[1]);
                    await_post = false;
                }
            }
        }
    }
    if await_post {
        comm.post_halo_sends(r, 1, &ys[1]);
    }

    // ---- phase 3: p_m - 1 rounds of {wait halo, advance classes}, with
    // the next round's sends posted right after the I_1 advance. With
    // `async_remainder`, receives complete in arrival order and each
    // landed segment's I_1 rows advance while the other messages are
    // still in flight (see the module docs).
    for p in 1..p_m {
        if pl.async_remainder {
            let mut stack: Vec<&mut Vec<f64>> = ys.iter_mut().collect();
            async_round(
                r,
                pl,
                p_m,
                p,
                &mut stack,
                rec,
                comm,
                backend,
                Some(inner),
                &mut flop_nnz,
            );
            continue;
        }
        comm.wait_halo(r, p as u64, &mut ys[p]);
        for k in 1..=(p_m - p) {
            let (lo, hi) = pl.class_ranges[k - 1];
            if lo != hi {
                // advance I_k from power p + k - 1 to p + k
                let (prevs, cur) = ys.split_at_mut(p + k);
                let prev2: Option<&[f64]> =
                    if p + k >= 2 { Some(&prevs[p + k - 2][..]) } else { x_m1 };
                if inner.is_parallel() {
                    flop_nnz += crate::inner::run_split_range(
                        inner,
                        &r.a,
                        rec,
                        prev2,
                        &prevs[p + k - 1],
                        &mut cur[0],
                        lo,
                        hi,
                        p + k,
                        backend,
                        comm.tracer(),
                    );
                } else {
                    let t0 = comm.tracer().now();
                    flop_nnz += kernel_step(
                        &r.a,
                        rec,
                        prev2,
                        &prevs[p + k - 1],
                        &mut cur[0],
                        lo,
                        hi,
                        backend,
                    );
                    comm.tracer().closed_span(
                        Span::DlbRemainder { round: p as u32, class: k as u32 },
                        t0,
                    );
                }
            }
            if k == 1 && p + 1 < p_m {
                // y_{p+1} is now final on every send row (deeper classes
                // reached power ≥ p+1 earlier): ship it while the deeper
                // classes of this round are still being advanced.
                comm.post_halo_sends(r, (p + 1) as u64, &ys[p + 1]);
            }
        }
    }

    comm.tracer().counter("flop_nnz", flop_nnz as f64);
    RankRun { ys, flop_nnz }
}

/// One-shot plan + execute (see [`plan`]/[`execute`] to amortize setup).
pub fn dlb_mpk(
    dist: &DistMatrix,
    x: &[f64],
    p_m: usize,
    opts: &DlbOptions,
    backend: &mut dyn SpmvBackend,
) -> DlbOutput {
    let pl = plan(dist, p_m, opts);
    let overhead = crate::mpk::overheads::dlb_overhead_from_plan(&pl);
    let result = execute(&pl, x, backend);
    DlbOutput { result, overhead }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::mpk::{trad_mpk, NativeBackend};
    use crate::partition::{partition, Method};

    fn check_equiv(a: &crate::matrix::CsrMatrix, np: usize, p_m: usize, cache: usize) {
        let x: Vec<f64> = (0..a.n_rows()).map(|i| ((i * 37 % 101) as f64) / 101.0).collect();
        let part = partition(a, np, Method::Block);
        let d = DistMatrix::build(a, &part);
        let want = trad_mpk(&d, &x, p_m, &mut NativeBackend);
        let opts = DlbOptions { cache_bytes: cache, s_m: 50, async_remainder: false };
        let got = dlb_mpk(&d, &x, p_m, &opts, &mut NativeBackend);
        assert_eq!(got.result.powers.len(), p_m);
        for (p, (gp, wp)) in got.result.powers.iter().zip(&want.powers).enumerate() {
            for (r, (u, v)) in gp.iter().zip(wp).enumerate() {
                assert!(
                    (u - v).abs() < 1e-10 * (1.0 + v.abs()),
                    "np={np} p_m={p_m} power={} row={r}: {u} vs {v}",
                    p + 1
                );
            }
        }
        // identical communication volume (the paper's headline property)
        assert_eq!(got.result.comm.bytes, want.comm.bytes, "DLB must match TRAD comm");
        assert_eq!(got.result.comm.rounds, want.comm.rounds);
        // zero redundant computation
        assert_eq!(got.result.flop_nnz, want.flop_nnz, "DLB must not recompute");
    }

    #[test]
    fn dlb_equals_trad_2d_stencil() {
        let a = gen::stencil_2d_5pt(12, 10);
        for np in [1, 2, 4] {
            for p_m in [1, 2, 3, 5] {
                check_equiv(&a, np, p_m, 8 << 10);
            }
        }
    }

    #[test]
    fn dlb_equals_trad_tridiag_tiny_cache() {
        let a = gen::tridiag(64);
        check_equiv(&a, 2, 4, 1); // 1-byte budget: maximal splitting
        check_equiv(&a, 3, 3, 1 << 20); // giant budget: single bulk group
    }

    #[test]
    fn dlb_equals_trad_random_banded() {
        let a = gen::random_banded_sym(600, 12, 40, 9);
        for np in [1, 3] {
            for p_m in [2, 4, 6] {
                check_equiv(&a, np, p_m, 16 << 10);
            }
        }
    }

    #[test]
    fn dlb_equals_trad_anderson() {
        let cfg = crate::matrix::anderson::AndersonConfig::isotropic(8, 2.0, 5);
        let a = crate::matrix::anderson::anderson(&cfg);
        check_equiv(&a, 4, 4, 8 << 10);
    }

    #[test]
    fn plan_classes_partition_local_rows() {
        let a = gen::stencil_2d_5pt(16, 16);
        let part = partition(&a, 4, Method::GreedyGrow);
        let d = DistMatrix::build(&a, &part);
        let p_m = 4;
        let pl = plan(&d, p_m, &DlbOptions::default());
        for (r, rp) in pl.dist.ranks.iter().zip(&pl.ranks) {
            // class ranges are disjoint ascending and lie before the bulk
            let mut prev_end = 0usize;
            for &(lo, hi) in &rp.class_ranges {
                if lo == hi {
                    continue;
                }
                assert_eq!(lo, prev_end);
                prev_end = hi;
            }
            assert_eq!(r.n_local() - rp.bulk_rows, prev_end);
            // boundary rows (touch halo) are exactly class I_1
            if r.n_halo() > 0 {
                let (lo, hi) = rp.class_ranges[0];
                let boundary = r.boundary_rows();
                assert_eq!(boundary.len(), hi - lo);
                assert!(boundary.iter().all(|&b| (b as usize) >= lo && (b as usize) < hi));
            }
        }
    }

    #[test]
    fn single_rank_dlb_is_pure_lb_mpk() {
        let a = gen::stencil_2d_5pt(20, 20);
        let part = partition(&a, 1, Method::Block);
        let d = DistMatrix::build(&a, &part);
        let x = vec![1.0; 400];
        let out = dlb_mpk(&d, &x, 3, &DlbOptions { cache_bytes: 4 << 10, s_m: 50, async_remainder: false }, &mut NativeBackend);
        assert_eq!(out.result.comm.bytes, 0);
        assert_eq!(out.overhead, 0.0, "no halo -> zero DLB overhead");
    }
}
