//! Communication-Avoiding MPK (Mohiyuddin et al. 2009) — the baseline whose
//! overheads motivate DLB-MPK (paper §4, Fig. 4b, Fig. 5).
//!
//! CA-MPK fetches an *extended* halo up front (distance classes
//! `E_0 … E_{p_m−1}` beyond the MPI boundary) and performs *redundant*
//! SpMVs on external vertices (`E_k` promoted to power `p_m − 1 − k`) so
//! that all `p_m` local powers complete with a single exchange.
//!
//! Implemented as both an exact overhead counter (Fig. 5: extra halo
//! elements and recomputed non-zeros as functions of `p_m` and ranks) and an
//! executable kernel (equivalence-tested against TRAD/DLB).

use std::collections::HashMap;

use crate::distsim::{CommStats, DistMatrix, RankLocal};
use crate::exec::{Communicator, RankRun};
use crate::inner::InnerExec;
use crate::matrix::CsrMatrix;
use crate::mpk::MpkResult;
use crate::trace::{RankRecorder, Span, TraceSession};

/// Exact CA-MPK overheads (accumulated over all ranks).
#[derive(Clone, Debug, Default)]
pub struct CaOverheads {
    /// Halo elements TRAD/DLB would fetch (Σ_i |E_0|).
    pub base_halo: usize,
    /// Additional halo elements CA fetches (Σ_i |E_1 ∪ … ∪ E_{p_m−1}|).
    pub extra_halo: usize,
    /// Redundant non-zero products: Σ_i Σ_k nnz(rows of E_k) · (p_m−1−k).
    pub redundant_nnz: usize,
    /// Redundant row-SpMV applications (vertex count × powers recomputed).
    pub redundant_rows: usize,
}

impl CaOverheads {
    /// Fig. 5 left: extra halo relative to total rows.
    pub fn rel_extra_halo(&self, n_rows: usize) -> f64 {
        self.extra_halo as f64 / n_rows as f64
    }

    /// Fig. 5 right: recomputed non-zeros relative to total non-zeros.
    pub fn rel_redundant(&self, nnz: usize) -> f64 {
        self.redundant_nnz as f64 / nnz as f64
    }
}

/// External distance classes of one rank: `ext[k]` = global ids at graph
/// distance `k+1` from the owned set (so `ext[0] = E_0 = B`, the TRAD halo).
fn external_classes(a: &CsrMatrix, owned_mask: &[bool], e0: &[usize], depth: usize) -> Vec<Vec<usize>> {
    let mut classes = vec![e0.to_vec()];
    let mut dist: HashMap<usize, usize> = e0.iter().map(|&g| (g, 0)).collect();
    for k in 1..depth {
        let mut next = Vec::new();
        for &g in &classes[k - 1] {
            for &c in a.row_cols(g) {
                let c = c as usize;
                if owned_mask[c] || dist.contains_key(&c) {
                    continue;
                }
                dist.insert(c, k);
                next.push(c);
            }
        }
        next.sort_unstable();
        classes.push(next);
    }
    classes
}

/// The CA plan + overhead counters for a distributed matrix.
pub struct CaPlan {
    /// Per rank: external classes `E_0..E_{p_m-1}` (global ids).
    pub ext: Vec<Vec<Vec<usize>>>,
    pub overheads: CaOverheads,
    pub p_m: usize,
}

/// Build the CA plan (needs the *global* matrix for external rows).
pub fn ca_plan(a: &CsrMatrix, dist: &DistMatrix, p_m: usize) -> CaPlan {
    assert!(p_m >= 1);
    let mut ext = Vec::with_capacity(dist.n_ranks());
    let mut ov = CaOverheads::default();
    for r in &dist.ranks {
        let mut owned_mask = vec![false; a.n_rows()];
        for &g in &r.owned {
            owned_mask[g] = true;
        }
        let classes = external_classes(a, &owned_mask, &r.halo_globals, p_m.max(1));
        ov.base_halo += classes[0].len();
        for (k, cls) in classes.iter().enumerate() {
            if k >= 1 {
                ov.extra_halo += cls.len();
            }
            // E_k is promoted to power p_m-1-k (redundantly; the owner also
            // computes it). E_{p_m-1} is fetch-only.
            let promotions = p_m.saturating_sub(1).saturating_sub(k);
            if promotions > 0 {
                let nnz: usize = cls.iter().map(|&g| a.row_cols(g).len()).sum();
                ov.redundant_nnz += nnz * promotions;
                ov.redundant_rows += cls.len() * promotions;
            }
        }
        ext.push(classes);
    }
    CaPlan { ext, overheads: ov, p_m }
}

/// Output of [`ca_mpk`].
pub struct CaOutput {
    pub result: MpkResult,
    pub overheads: CaOverheads,
}

/// Execute CA-MPK: one extended exchange, then purely local (redundant)
/// computation. Requires the global matrix to extract external rows —
/// exactly what a real implementation ships during setup.
pub fn ca_mpk(dist: &DistMatrix, x: &[f64], p_m: usize) -> CaOutput {
    // Reconstruct the global matrix from rank blocks for external rows.
    // (Benchmarks pass the original matrix via `ca_mpk_with`; this
    // convenience path rebuilds it.)
    let a = reassemble_global(dist);
    ca_mpk_with(&a, dist, x, p_m)
}

pub fn ca_mpk_with(a: &CsrMatrix, dist: &DistMatrix, x: &[f64], p_m: usize) -> CaOutput {
    let plan = ca_plan(a, dist, p_m);
    ca_execute_planned(a, dist, &plan, x)
}

/// Execute CA-MPK with a prebuilt [`CaPlan`] — the sequential
/// (counting-simulator) path of [`crate::engine::MpkEngine`], which caches
/// the plan across sweeps instead of rebuilding it per call.
pub fn ca_execute_planned(a: &CsrMatrix, dist: &DistMatrix, plan: &CaPlan, x: &[f64]) -> CaOutput {
    ca_execute_planned_traced(a, dist, plan, x, None, None)
}

/// [`ca_execute_planned`] with an optional [`TraceSession`]. The sequential
/// CA path has no communicator endpoints, so per-rank recorders are created
/// directly: the accounting pass becomes a `ca.exchange` span wrapping
/// zero-duration synthetic `comm.recv` spans (one per peer message, real
/// byte counts, so metrics flows still sum to [`CommStats`]), and each
/// promotion round a `ca.promote(p)` span. A parallel per-rank [`InnerExec`]
/// (if supplied) fans each promotion round out as `inner.task` spans.
pub fn ca_execute_planned_traced(
    a: &CsrMatrix,
    dist: &DistMatrix,
    plan: &CaPlan,
    x: &[f64],
    mut trace: Option<&mut TraceSession>,
    mut inners: Option<&mut [InnerExec]>,
) -> CaOutput {
    let p_m = plan.p_m;
    let mut comm = CommStats::default();
    let mut flop_nnz = 0usize;
    let n = a.n_rows();
    let mut powers: Vec<Vec<f64>> = (0..=p_m).map(|_| vec![0.0; n]).collect();
    powers[0].copy_from_slice(x);

    let mut recorders: Vec<RankRecorder> = match trace.as_deref() {
        Some(ts) => (0..dist.n_ranks()).map(|i| ts.recorder(i)).collect(),
        None => (0..dist.n_ranks()).map(|_| RankRecorder::disabled()).collect(),
    };

    // one "big" exchange: every rank receives x for all its external
    // classes — one message per (rank, peer owner) pair, sized by the run
    // of that owner's global ids (matching [`ca_rank`]'s receiver-side
    // accounting bitwise, max_message_bytes included)
    comm.rounds = 1;
    comm.wait_ns.push(0);
    for ((rank, _r), classes) in dist.ranks.iter().enumerate().zip(&plan.ext) {
        let rec = &mut recorders[rank];
        rec.begin(Span::CaExchange);
        let mut owners: Vec<u32> =
            classes.iter().flatten().map(|&g| dist.owner_of[g]).collect();
        owners.sort_unstable();
        let mut s = 0usize;
        while s < owners.len() {
            let mut e = s;
            while e < owners.len() && owners[e] == owners[s] {
                e += 1;
            }
            let bytes = (e - s) * std::mem::size_of::<f64>();
            comm.messages += 1;
            comm.bytes += bytes;
            comm.max_message_bytes = comm.max_message_bytes.max(bytes);
            let tr = rec.now();
            rec.closed_span(
                Span::CommRecv { from: owners[s], bytes: bytes.min(u32::MAX as usize) as u32 },
                tr,
            );
            s = e;
        }
        let tw = rec.now();
        rec.closed_span(Span::CommWait { round: 0 }, tw);
        rec.end();
    }

    // local phase per rank: promote owned to p_m, E_k to p_m-1-k. We emulate
    // rank locality by only reading values the rank legitimately holds;
    // since every rank computes into disjoint `powers` slots for owned rows
    // and recomputes external rows redundantly (same values), a shared
    // global buffer reproduces the numerics exactly while the counters
    // capture the redundancy.
    for ((rank, r), classes) in dist.ranks.iter().enumerate().zip(&plan.ext) {
        for p in 1..=p_m {
            let (prevs, curs) = powers.split_at_mut(p);
            let par = inners.as_deref_mut().map(|v| &mut v[rank]).filter(|e| e.is_parallel());
            if let Some(ie) = par {
                flop_nnz += crate::inner::run_ca_round(
                    ie,
                    a,
                    &r.owned,
                    classes,
                    p_m,
                    p,
                    &prevs[p - 1],
                    &mut curs[0],
                    &mut recorders[rank],
                );
            } else {
                let t0 = recorders[rank].now();
                flop_nnz +=
                    ca_promote_round(a, &r.owned, classes, p_m, p, &prevs[p - 1], &mut curs[0]);
                recorders[rank].closed_span(Span::CaPromote { power: p as u32 }, t0);
            }
        }
    }

    if let Some(ts) = trace.as_deref_mut() {
        for (i, mut rec) in recorders.into_iter().enumerate() {
            ts.absorb(i, rec.take_events());
        }
    }

    CaOutput {
        result: MpkResult {
            powers: powers.into_iter().skip(1).collect(),
            comm,
            flop_nnz,
        },
        overheads: plan.overheads.clone(),
    }
}

/// Per-rank communication plan for the executable CA kernel: who ships
/// which input values to whom for the single up-front extended exchange.
/// Derived once from the global [`CaPlan`] (in a real implementation this
/// handshake happens during setup).
pub struct CaExecPlan {
    pub p_m: usize,
    /// `sends[rank]` = (peer, local rows of the input to ship), ascending
    /// peer.
    pub sends: Vec<Vec<(usize, Vec<u32>)>>,
    /// `recvs[rank]` = (peer, global ids received from it), ascending peer;
    /// ids sorted by global id within a peer.
    pub recvs: Vec<Vec<(usize, Vec<usize>)>>,
    /// `ext[rank]` = external classes `E_0..E_{p_m-1}` (global ids), as in
    /// [`CaPlan::ext`].
    pub ext: Vec<Vec<Vec<usize>>>,
}

/// Build the per-rank exec plan for `p_m` from scratch (one-shot callers).
pub fn ca_exec_plan(a: &CsrMatrix, dist: &DistMatrix, p_m: usize) -> CaExecPlan {
    let plan = ca_plan(a, dist, p_m);
    ca_exec_plan_from(dist, &plan)
}

/// Derive the per-rank exec plan from an existing global [`CaPlan`]
/// (so a cached plan is not recomputed — see [`crate::engine::MpkEngine`]).
pub fn ca_exec_plan_from(dist: &DistMatrix, plan: &CaPlan) -> CaExecPlan {
    let nr = dist.n_ranks();
    let mut recvs: Vec<Vec<(usize, Vec<usize>)>> = vec![Vec::new(); nr];
    let mut sends: Vec<Vec<(usize, Vec<u32>)>> = vec![Vec::new(); nr];
    for (i, classes) in plan.ext.iter().enumerate() {
        let mut wanted: Vec<usize> = classes.iter().flatten().copied().collect();
        wanted.sort_unstable_by_key(|&g| (dist.owner_of[g], g));
        let mut s = 0usize;
        while s < wanted.len() {
            let owner = dist.owner_of[wanted[s]] as usize;
            let mut e = s;
            while e < wanted.len() && dist.owner_of[wanted[e]] as usize == owner {
                e += 1;
            }
            let gids = wanted[s..e].to_vec();
            sends[owner].push((i, gids.iter().map(|&g| dist.local_of[g]).collect()));
            recvs[i].push((owner, gids));
            s = e;
        }
    }
    for sp in &mut sends {
        sp.sort_by_key(|&(peer, _)| peer);
    }
    CaExecPlan { p_m: plan.p_m, sends, recvs, ext: plan.ext.clone() }
}

/// One CA promotion round: owned rows to power `p`, plus every external
/// class `E_k` still below its target `p_m − 1 − k`, reading power `p − 1`
/// values from `prev` and writing `cur`. Returns the non-zeros touched.
///
/// Shared by the sequential driver ([`ca_mpk_with`]) and the per-rank
/// kernel ([`ca_rank`]) so the two execution paths cannot drift — same
/// role [`crate::mpk::kernel_step`] plays for TRAD/DLB.
fn ca_promote_round(
    a: &CsrMatrix,
    owned: &[usize],
    ext: &[Vec<usize>],
    p_m: usize,
    p: usize,
    prev: &[f64],
    cur: &mut [f64],
) -> usize {
    let mut flop_nnz = 0usize;
    for &g in owned {
        cur[g] = row_dot(a, g, prev);
        flop_nnz += a.row_cols(g).len();
    }
    for (k, cls) in ext.iter().enumerate() {
        let target = p_m.saturating_sub(1).saturating_sub(k);
        if p <= target {
            for &g in cls {
                cur[g] = row_dot(a, g, prev);
                flop_nnz += a.row_cols(g).len();
            }
        }
    }
    flop_nnz
}

/// Single-rank CA kernel over a [`Communicator`]: one extended exchange of
/// the input vector (tag 0), then purely local redundant computation —
/// identical operation order to [`ca_mpk_with`] (shared
/// [`ca_promote_round`]), so results and counters are bitwise equal across
/// executors.
///
/// The rank works in a global-index workspace but only two rotating
/// buffers of it (power `p` reads nothing older than `p − 1`), and only
/// ever reads rows in its owned ∪ external closure (the CA invariant), so
/// per-rank memory is `2 × N` instead of `(p_m + 1) × N`.
#[allow(clippy::too_many_arguments)]
pub fn ca_rank(
    a: &CsrMatrix,
    r: &RankLocal,
    sends: &[(usize, Vec<u32>)],
    recvs: &[(usize, Vec<usize>)],
    ext: &[Vec<usize>],
    x0: &[f64],
    p_m: usize,
    comm: &mut dyn Communicator,
    inner: &mut InnerExec,
) -> RankRun {
    debug_assert!(
        crate::verify::debug_check_rank(r).is_empty(),
        "ca_rank: halo plans failed verification:\n{}",
        crate::verify::render(&crate::verify::debug_check_rank(r))
    );
    let n = a.n_rows();
    let mut prev = vec![0.0; n];
    let mut cur = vec![0.0; n];
    for (l, &g) in r.owned.iter().enumerate() {
        prev[g] = x0[l];
    }

    // one "big" exchange: ship input values peers fetch, receive all
    // external classes (transports record the comm.send/recv/wait spans;
    // the ca.exchange umbrella span wraps the whole phase)
    comm.tracer().begin(Span::CaExchange);
    for (peer, rows) in sends {
        let payload: Vec<f64> = rows.iter().map(|&l| x0[l as usize]).collect();
        comm.send(*peer, 0, payload);
    }
    for (peer, gids) in recvs {
        let payload = comm.recv(*peer, 0);
        debug_assert_eq!(payload.len(), gids.len());
        for (&g, &v) in gids.iter().zip(&payload) {
            prev[g] = v;
        }
    }
    comm.end_round();
    comm.tracer().end();

    // local phase: promote owned to p_m, E_k to p_m-1-k (redundantly),
    // extracting the rank's owned slice of each power as it completes
    let extract = |buf: &[f64]| -> Vec<f64> { r.owned.iter().map(|&g| buf[g]).collect() };
    let mut ys: Vec<Vec<f64>> = Vec::with_capacity(p_m + 1);
    ys.push(extract(&prev));
    let mut flop_nnz = 0usize;
    for p in 1..=p_m {
        if inner.is_parallel() {
            flop_nnz += crate::inner::run_ca_round(
                inner,
                a,
                &r.owned,
                ext,
                p_m,
                p,
                &prev,
                &mut cur,
                comm.tracer(),
            );
        } else {
            let t0 = comm.tracer().now();
            flop_nnz += ca_promote_round(a, &r.owned, ext, p_m, p, &prev, &mut cur);
            comm.tracer().closed_span(Span::CaPromote { power: p as u32 }, t0);
        }
        ys.push(extract(&cur));
        std::mem::swap(&mut prev, &mut cur);
    }
    comm.tracer().counter("flop_nnz", flop_nnz as f64);
    RankRun { ys, flop_nnz }
}

/// Plain CSR row dot product — the CA compute primitive. `pub(crate)` so
/// [`crate::inner`]'s `Rows` tasks reproduce the serial numerics exactly.
#[inline]
pub(crate) fn row_dot(a: &CsrMatrix, r: usize, x: &[f64]) -> f64 {
    let mut sum = 0.0;
    for k in a.rowptr[r]..a.rowptr[r + 1] {
        sum += a.values[k] * x[a.colidx[k] as usize];
    }
    sum
}

/// Rebuild the global matrix from the rank-local blocks (inverse of
/// `DistMatrix::build`; used by the convenience `ca_mpk` path and tests).
pub fn reassemble_global(dist: &DistMatrix) -> CsrMatrix {
    let n = dist.n_global;
    let mut coo = crate::matrix::CooMatrix::new(n, n);
    for r in &dist.ranks {
        for lr in 0..r.n_local() {
            let g = r.owned[lr];
            for k in r.a.rowptr[lr]..r.a.rowptr[lr + 1] {
                let lc = r.a.colidx[k] as usize;
                let gc = if lc < r.n_local() {
                    r.owned[lc]
                } else {
                    r.halo_globals[lc - r.n_local()]
                };
                coo.push(g, gc, r.a.values[k]);
            }
        }
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::mpk::{trad_mpk, NativeBackend};
    use crate::partition::{partition, Method};

    #[test]
    fn ca_matches_trad() {
        let a = gen::stencil_2d_5pt(10, 10);
        let x: Vec<f64> = (0..100).map(|i| (i as f64 * 0.7).sin()).collect();
        for np in [2, 4] {
            let part = partition(&a, np, Method::Block);
            let d = DistMatrix::build(&a, &part);
            let want = trad_mpk(&d, &x, 3, &mut NativeBackend);
            let got = ca_mpk_with(&a, &d, &x, 3);
            for (gp, wp) in got.result.powers.iter().zip(&want.powers) {
                for (u, v) in gp.iter().zip(wp) {
                    assert!((u - v).abs() < 1e-11);
                }
            }
            // CA does strictly more flops (redundant work), single round
            assert!(got.result.flop_nnz > want.flop_nnz);
            assert_eq!(got.result.comm.rounds, 1);
        }
    }

    #[test]
    fn reassemble_inverts_build() {
        let a = gen::random_banded_sym(300, 8, 30, 4);
        let part = partition(&a, 3, Method::GreedyGrow);
        let d = DistMatrix::build(&a, &part);
        assert_eq!(reassemble_global(&d), a);
    }

    #[test]
    fn overheads_grow_with_power_and_ranks() {
        let a = gen::stencil_2d_5pt(20, 20);
        let ov = |np: usize, p_m: usize| {
            let part = partition(&a, np, Method::Block);
            let d = DistMatrix::build(&a, &part);
            ca_plan(&a, &d, p_m).overheads
        };
        let o_p2 = ov(4, 2);
        let o_p6 = ov(4, 6);
        assert!(o_p6.extra_halo > o_p2.extra_halo);
        assert!(o_p6.redundant_nnz > o_p2.redundant_nnz);
        let o_n2 = ov(2, 4);
        let o_n8 = ov(8, 4);
        assert!(o_n8.extra_halo > o_n2.extra_halo);
        // p_m = 1: no extra halo, no redundancy (single SpMV)
        let o1 = ov(4, 1);
        assert_eq!(o1.extra_halo, 0);
        assert_eq!(o1.redundant_nnz, 0);
    }

    #[test]
    fn e0_matches_trad_halo() {
        let a = gen::stencil_2d_5pt(12, 12);
        let part = partition(&a, 3, Method::Block);
        let d = DistMatrix::build(&a, &part);
        let plan = ca_plan(&a, &d, 4);
        assert_eq!(plan.overheads.base_halo, d.total_halo());
    }
}
