//! The paper's overhead metrics (Eq. 1–3).

use crate::distsim::DistMatrix;
use crate::mpk::dlb::DlbPlan;

/// Paper Eq. (1): `O_MPI = Σ_i N_{h,i} / N_r` — re-exported convenience.
pub fn mpi_overhead(dist: &DistMatrix) -> f64 {
    dist.mpi_overhead()
}

/// Paper Eq. (2): per-rank DLB overhead `1 − |M_i| / N_{i,r}`.
pub fn dlb_local_overhead(bulk_rows: usize, n_local: usize) -> f64 {
    if n_local == 0 {
        0.0
    } else {
        1.0 - bulk_rows as f64 / n_local as f64
    }
}

/// Paper Eq. (3): row-weighted global DLB overhead.
pub fn dlb_overhead_from_plan(plan: &DlbPlan) -> f64 {
    let n_r: usize = plan.dist.ranks.iter().map(|r| r.n_local()).sum();
    if n_r == 0 {
        return 0.0;
    }
    let weighted: f64 = plan
        .dist
        .ranks
        .iter()
        .zip(&plan.ranks)
        .map(|(r, rp)| r.n_local() as f64 * dlb_local_overhead(rp.bulk_rows, r.n_local()))
        .sum();
    weighted / n_r as f64
}

/// Convenience: build a DLB plan just to measure Eq. (3).
pub fn dlb_overhead(dist: &DistMatrix, p_m: usize, opts: &crate::mpk::DlbOptions) -> f64 {
    dlb_overhead_from_plan(&crate::mpk::dlb::plan(dist, p_m, opts))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::mpk::DlbOptions;
    use crate::partition::{partition, Method};

    #[test]
    fn overhead_grows_with_p_and_ranks() {
        let a = gen::stencil_2d_5pt(24, 24);
        let mk = |np: usize, p_m: usize| {
            let p = partition(&a, np, Method::Block);
            let d = DistMatrix::build(&a, &p);
            dlb_overhead(&d, p_m, &DlbOptions::default())
        };
        // growing power eats into the bulk (paper §6.4)
        assert!(mk(2, 2) < mk(2, 6));
        // more ranks -> more boundary -> more overhead
        assert!(mk(2, 4) < mk(8, 4));
        // single rank has zero overhead
        assert_eq!(mk(1, 8), 0.0);
    }

    #[test]
    fn local_overhead_formula() {
        assert_eq!(dlb_local_overhead(75, 100), 0.25);
        assert_eq!(dlb_local_overhead(100, 100), 0.0);
        assert_eq!(dlb_local_overhead(0, 0), 0.0);
    }
}
