//! SpMV execution backends.
//!
//! The MPK drivers are generic over *how* a row range of the local matrix is
//! multiplied: [`NativeBackend`] is the optimized rust loop used by all
//! benchmarks (cache-blocking speedups are a hardware effect the interpret-
//! mode XLA path cannot exhibit); `runtime::XlaBackend` routes the same row
//! ranges through the AOT Pallas/JAX artifacts via PJRT, proving the
//! three-layer composition (see DESIGN.md §Execution backends).

use crate::matrix::CsrMatrix;

pub trait SpmvBackend {
    /// `y[lo..hi] = (A x)[lo..hi]` for a rank-local matrix `a`.
    fn spmv_range(&mut self, a: &CsrMatrix, lo: usize, hi: usize, x: &[f64], y: &mut [f64]);

    fn name(&self) -> &'static str;
}

/// Plain rust CRS row-range kernel.
pub struct NativeBackend;

impl SpmvBackend for NativeBackend {
    #[inline]
    fn spmv_range(&mut self, a: &CsrMatrix, lo: usize, hi: usize, x: &[f64], y: &mut [f64]) {
        a.spmv_range(lo, hi, x, y);
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    #[test]
    fn native_backend_matches_reference() {
        let a = gen::stencil_2d_5pt(8, 8);
        let x: Vec<f64> = (0..64).map(|i| (i as f64).cos()).collect();
        let mut y1 = vec![0.0; 64];
        let mut y2 = vec![0.0; 64];
        a.spmv(&x, &mut y1);
        let mut b = NativeBackend;
        b.spmv_range(&a, 0, 32, &x, &mut y2);
        b.spmv_range(&a, 32, 64, &x, &mut y2);
        // unrolled kernel reassociates the row sum: tolerance, not equality
        for (u, v) in y1.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-13);
        }
    }
}
