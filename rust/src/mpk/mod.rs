//! The three distributed Matrix Power Kernel variants (paper §4–5).
//!
//! * [`trad`] — traditional back-to-back SpMVs with one halo exchange per
//!   power (paper Alg. 1). The baseline every speedup is measured against.
//! * [`ca`] — communication-avoiding MPK (Mohiyuddin et al. 2009): one
//!   up-front extended halo exchange, redundant SpMVs on external vertices,
//!   no further communication. Implemented both as an exact overhead counter
//!   (Fig. 5) and as an executable kernel.
//! * [`dlb`] — the paper's contribution: TRAD's halo traffic, CA's cache
//!   blocking, zero redundant work (paper Alg. 2, Fig. 6).
//!
//! All variants produce bitwise-comparable results (same floating-point
//! operation order per row) and are cross-validated in `rust/tests/`.
//!
//! Each variant exists in two executable forms sharing the same compute
//! helpers (so numerics are bit-identical): the original all-ranks
//! sequential drivers here (now routed through [`crate::exec::SimComm`]
//! lockstep exchanges), and single-rank kernels (`trad_rank`, `dlb_rank`,
//! `ca_rank`) over [`crate::exec::Communicator`] that the threaded
//! executor ([`crate::exec`]) runs with one OS thread per rank.
//!
//! These are the *kernels*. The public way to run them is
//! [`crate::engine::MpkEngine`], a prepare-once/apply-many session that
//! owns the plans and workspaces, caches tail-block plans, and keeps a
//! persistent rank pool under the threads executor; [`run`] below remains
//! as the minimal one-shot convenience dispatcher.

pub mod ca;
pub mod dlb;
pub mod overheads;
pub mod trad;
pub mod traits;

pub use ca::{ca_mpk, CaOverheads};
pub use dlb::{dlb_mpk, DlbOptions};
pub use overheads::dlb_overhead;
pub use trad::trad_mpk;
pub use traits::{NativeBackend, SpmvBackend};

use crate::distsim::{CommStats, DistMatrix};

/// Which MPK variant to run (see [`run`]).
#[derive(Clone, Copy, Debug)]
pub enum MpkVariant {
    Trad,
    Ca,
    Dlb { cache_bytes: usize },
}

/// Result of a distributed MPK run.
#[derive(Clone, Debug)]
pub struct MpkResult {
    /// `powers[p-1]` = the global vector `y_p = A^p x`, `p = 1..=p_m`.
    pub powers: Vec<Vec<f64>>,
    /// Communication performed.
    pub comm: CommStats,
    /// Total SpMV row-nonzero products executed (redundant work shows up
    /// here: CA > TRAD == DLB).
    pub flop_nnz: usize,
}

/// One row-range step of a three-term recurrence: `cur[lo..hi] =
/// (A prev)[lo..hi]`, then for Chebyshev `cur <- 2·cur − prev2` (no `prev2`
/// = the wind-up step, Eq. 7). Returns the non-zeros touched.
///
/// This is the single compute primitive shared by the sequential drivers
/// and the per-rank kernels — keeping both execution paths bitwise equal.
pub(crate) fn kernel_step(
    a: &crate::matrix::CsrMatrix,
    rec: dlb::Recurrence,
    prev2: Option<&[f64]>,
    prev: &[f64],
    cur: &mut [f64],
    lo: usize,
    hi: usize,
    backend: &mut dyn SpmvBackend,
) -> usize {
    backend.spmv_range(a, lo, hi, prev, cur);
    if rec == dlb::Recurrence::Chebyshev {
        if let Some(sub) = prev2 {
            for r in lo..hi {
                cur[r] = 2.0 * cur[r] - sub[r];
            }
        }
    }
    a.rowptr[hi] - a.rowptr[lo]
}

/// Convenience dispatcher over the three variants with the native backend.
pub fn run(dist: &DistMatrix, x: &[f64], p_m: usize, variant: MpkVariant) -> MpkResult {
    let mut backend = NativeBackend;
    match variant {
        MpkVariant::Trad => trad_mpk(dist, x, p_m, &mut backend),
        MpkVariant::Ca => ca_mpk(dist, x, p_m).result,
        MpkVariant::Dlb { cache_bytes } => {
            let opts = DlbOptions { cache_bytes, ..DlbOptions::default() };
            dlb_mpk(dist, x, p_m, &opts, &mut backend).result
        }
    }
}
