//! The three distributed Matrix Power Kernel variants (paper §4–5).
//!
//! * [`trad`] — traditional back-to-back SpMVs with one halo exchange per
//!   power (paper Alg. 1). The baseline every speedup is measured against.
//! * [`ca`] — communication-avoiding MPK (Mohiyuddin et al. 2009): one
//!   up-front extended halo exchange, redundant SpMVs on external vertices,
//!   no further communication. Implemented both as an exact overhead counter
//!   (Fig. 5) and as an executable kernel.
//! * [`dlb`] — the paper's contribution: TRAD's halo traffic, CA's cache
//!   blocking, zero redundant work (paper Alg. 2, Fig. 6).
//!
//! All variants produce bitwise-comparable results (same floating-point
//! operation order per row) and are cross-validated in `rust/tests/`.

pub mod ca;
pub mod dlb;
pub mod overheads;
pub mod trad;
pub mod traits;

pub use ca::{ca_mpk, CaOverheads};
pub use dlb::{dlb_mpk, DlbOptions};
pub use overheads::dlb_overhead;
pub use trad::trad_mpk;
pub use traits::{NativeBackend, SpmvBackend};

use crate::distsim::{CommStats, DistMatrix};

/// Which MPK variant to run (see [`run`]).
#[derive(Clone, Copy, Debug)]
pub enum MpkVariant {
    Trad,
    Ca,
    Dlb { cache_bytes: usize },
}

/// Result of a distributed MPK run.
#[derive(Clone, Debug)]
pub struct MpkResult {
    /// `powers[p-1]` = the global vector `y_p = A^p x`, `p = 1..=p_m`.
    pub powers: Vec<Vec<f64>>,
    /// Communication performed.
    pub comm: CommStats,
    /// Total SpMV row-nonzero products executed (redundant work shows up
    /// here: CA > TRAD == DLB).
    pub flop_nnz: usize,
}

/// Convenience dispatcher over the three variants with the native backend.
pub fn run(dist: &DistMatrix, x: &[f64], p_m: usize, variant: MpkVariant) -> MpkResult {
    let mut backend = NativeBackend;
    match variant {
        MpkVariant::Trad => trad_mpk(dist, x, p_m, &mut backend),
        MpkVariant::Ca => ca_mpk(dist, x, p_m).result,
        MpkVariant::Dlb { cache_bytes } => {
            let opts = DlbOptions { cache_bytes, ..DlbOptions::default() };
            dlb_mpk(dist, x, p_m, &opts, &mut backend).result
        }
    }
}
