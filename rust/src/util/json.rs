//! Minimal JSON parser — just enough for `artifacts/manifest.json`.
//!
//! The AOT manifest is machine-generated (python/compile/aot.py) with a flat
//! {name: {key: value}} structure of strings and integers; this parser
//! supports the full JSON value grammar anyway so the runtime fails loudly on
//! malformed input rather than mis-parsing.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character '{}'", c as char))),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Advance over one UTF-8 scalar.
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let s = r#"{"and32_spmv": {"kind": "spmv", "rows": 32768, "width": 7, "file": "a.hlo.txt"}}"#;
        let j = Json::parse(s).unwrap();
        let e = j.get("and32_spmv").unwrap();
        assert_eq!(e.get("kind").unwrap().as_str(), Some("spmv"));
        assert_eq!(e.get("rows").unwrap().as_usize(), Some(32768));
    }

    #[test]
    fn parses_scalars_arrays_escapes() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse(r#"["a\n", 2, {}]"#).unwrap(),
            Json::Arr(vec![
                Json::Str("a\n".into()),
                Json::Num(2.0),
                Json::Obj(Default::default())
            ])
        );
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nulll").is_err());
    }
}
