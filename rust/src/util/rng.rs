//! Seeded, dependency-free PRNG (splitmix64 + xoshiro256**).
//!
//! Every stochastic component in the crate (matrix generators, Anderson
//! disorder, partition seeds, property tests) takes an explicit seed so all
//! experiments are exactly reproducible.

/// xoshiro256** with splitmix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 to fill the state; never all-zero.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in `[0, n)` (n > 0), unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
