//! Small dependency-free utilities: seeded RNG, mini JSON parser, formatting.

pub mod json;
pub mod rng;

/// Format a byte count as a human-readable string (binary units).
pub fn fmt_bytes(bytes: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Round-to-nearest MiB, matching the paper's Table 4 convention.
pub fn mib(bytes: usize) -> usize {
    (bytes + (1 << 19)) >> 20
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(3 << 20), "3.0 MiB");
    }

    #[test]
    fn mib_rounds_to_nearest() {
        assert_eq!(mib(1 << 20), 1);
        assert_eq!(mib((1 << 20) + (1 << 19)), 2); // 1.5 MiB rounds up
        assert_eq!(mib(100), 0);
    }
}
