//! `MpkEngine` — the prepare-once / apply-many session API.
//!
//! The paper's whole point is *amortization*: pay for partitioning, level
//! permutation, and schedule construction once, then reuse the matrix data
//! across many power sweeps (its flagship §7 result comes from an
//! application repeatedly driving MPK sweeps with one matrix). RACE
//! (Alappat et al. 2020) and the level-blocked MPK work (arXiv:2205.01598)
//! expose the same shape: a preprocessed engine handle applied many times.
//!
//! [`MpkEngine`] is that handle. Build it once from a
//! [`crate::distsim::DistMatrix`]:
//!
//! ```ignore
//! let mut eng = MpkEngine::builder(&dist)
//!     .p_m(8)
//!     .variant(Variant::Dlb(DlbOptions::default()))
//!     .executor(ExecutorKind::Threads { n: 0 })
//!     .backend(BackendSpec::Native)
//!     .build()?;
//! let out = eng.sweep(&x, None, Recurrence::Power); // y_p = A^p x, p = 1..=8
//! ```
//!
//! It owns everything sweeps reuse:
//!
//! * the **variant plan** — DLB level permutation + wavefront schedule, or
//!   the CA extended-halo exchange plan (TRAD needs none);
//! * a **tail-plan cache** keyed by `p_m`, so recurrences whose term count
//!   is not a multiple of the block size (Chebyshev propagation) reuse
//!   their short final-block plans instead of rebuilding them every step;
//! * reusable **workspaces** for the sequential executor;
//! * for the threads executor, a **persistent rank pool**
//!   (`pool::RankPool`): `n_ranks` long-lived rank threads parked on job
//!   channels, so a propagator running thousands of sweeps pays thread and
//!   communicator setup exactly once instead of per call;
//! * for the processes executor, this rank's **socket endpoint**
//!   ([`crate::exec::SockComm`]) plus its inner pool — the engine runs
//!   SPMD, one engine per launched rank process (see
//!   `docs/ARCHITECTURE.md`).
//!
//! [`MpkEngine::sweep`] / [`MpkEngine::sweep_len`] is the one entry point
//! subsuming `mpk::run`, `exec::run`, the `*_threaded` drivers, and the
//! per-variant recurrence helpers. Both executors produce bitwise-identical
//! powers and identical merged [`crate::distsim::CommStats`]
//! (cross-validated in `rust/tests/exec_equivalence.rs` and
//! `rust/tests/engine_session.rs`).
//!
//! This is also the seam transports plug into with zero app changes: the
//! multi-process socket transport ([`crate::exec::SockComm`]) slots in as
//! `ExecutorKind::Processes` behind the same builder knobs, and an
//! MPI-backed [`crate::exec::Communicator`] would follow the identical
//! path. Under the processes executor every launched rank process builds
//! the same engine from the same inputs (SPMD); `sweep` runs only this
//! rank's kernel, then an allgather over the socket control plane gives
//! every process the full bitwise-identical [`SweepResult`].

pub mod pool;

use std::collections::HashMap;
use std::sync::Arc;

use crate::distsim::{CommStats, DistMatrix};
use crate::exec::executor::assemble;
use crate::exec::sock::{ctrl_tag, RankEnv, SockComm, CTRL_GATHER, CTRL_TRACE};
use crate::exec::{Communicator, ExecutorKind, RankRun};
use crate::inner::InnerExec;
use crate::matrix::CsrMatrix;
use crate::mpk::ca::{self, CaExecPlan, CaOverheads, CaPlan};
use crate::mpk::dlb::{self, DlbOptions, DlbPlan, DlbPre, Recurrence, Workspace};
use crate::mpk::trad::{self, trad_recurrence_traced};
use crate::mpk::{MpkResult, NativeBackend, SpmvBackend};
use crate::trace::{wire, Metrics, TraceSession};

use pool::{Job, RankPool};
pub use pool::PoolStats;

/// What one sweep produces: the global power vectors `powers[p-1] = y_p`,
/// the communication performed, and the flop count (see [`MpkResult`]).
pub type SweepResult = MpkResult;

/// Which MPK variant the engine runs (the planning-aware sibling of
/// [`crate::mpk::MpkVariant`], carrying full [`DlbOptions`]).
#[derive(Clone, Copy, Debug)]
pub enum Variant {
    /// Back-to-back SpMVs, one halo exchange per power (paper Alg. 1).
    Trad,
    /// Communication-avoiding MPK: one extended exchange, redundant work.
    /// Supports only the plain power recurrence, and its redundant-work
    /// kernel computes with its own fixed row loop — the configured
    /// [`BackendSpec`] does not reach CA sweeps (only
    /// [`MpkEngine::backend`] host products).
    Ca,
    /// The paper's cache-blocked DLB-MPK (Alg. 2).
    Dlb(DlbOptions),
}

impl Variant {
    /// Short label for reports (`trad` / `ca` / `dlb`).
    pub fn label(&self) -> &'static str {
        match self {
            Self::Trad => "trad",
            Self::Ca => "ca",
            Self::Dlb(_) => "dlb",
        }
    }
}

/// How sweeps multiply a row range: the default native CRS loop, or a
/// custom factory (the seam for the XLA/PJRT backend — each rank thread
/// gets its own instance from the factory). Reaches every TRAD/DLB sweep
/// and the host-side [`MpkEngine::backend`]; the CA kernel has no backend
/// seam (see [`Variant::Ca`]).
#[derive(Clone)]
pub enum BackendSpec {
    Native,
    Custom(Arc<dyn Fn() -> Box<dyn SpmvBackend + Send> + Send + Sync>),
}

impl BackendSpec {
    /// Instantiate one backend (called once for the host, once per rank
    /// thread).
    pub fn make(&self) -> Box<dyn SpmvBackend + Send> {
        match self {
            Self::Native => Box::new(NativeBackend),
            Self::Custom(f) => f(),
        }
    }
}

impl Default for BackendSpec {
    fn default() -> Self {
        Self::Native
    }
}

impl std::fmt::Debug for BackendSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Native => f.write_str("Native"),
            Self::Custom(_) => f.write_str("Custom(..)"),
        }
    }
}

/// The builder knobs as a plain value, for callers (apps, configs) that
/// construct their own distributed matrix before building the engine.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub variant: Variant,
    pub executor: ExecutorKind,
    pub backend: BackendSpec,
    /// Record per-rank span timelines (see [`crate::trace`]). Off by
    /// default: the disabled recorders cost one branch per would-be event
    /// and results are bitwise identical either way.
    pub trace: bool,
    /// Inner (within-rank) threads per rank — the second level of the
    /// ranks × inner-threads hierarchy (see [`crate::inner`]). `1` (the
    /// default) is today's serial per-rank code; `k >= 2` runs each rank's
    /// compute as dependency-free task batches on a `k`-participant inner
    /// pool, bitwise identical to serial.
    pub inner_threads: usize,
    /// Statically verify every plan the engine builds (schedule races,
    /// inner-split aliasing, communication matching/progress/tags, the DLB
    /// async partition — see [`crate::verify`]) at prepare time: `build`
    /// fails with the diagnostic report, and tail-plan cache misses assert.
    /// On by default in debug builds, off in release; either way nothing
    /// runs on the sweep hot path.
    pub verify_plans: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            variant: Variant::Dlb(DlbOptions::default()),
            executor: ExecutorKind::Sim,
            backend: BackendSpec::Native,
            trace: false,
            inner_threads: 1,
            verify_plans: cfg!(debug_assertions),
        }
    }
}

/// Builder for [`MpkEngine`] (see the module docs for the full shape).
pub struct MpkEngineBuilder<'a> {
    dist: &'a DistMatrix,
    p_m: usize,
    cfg: EngineConfig,
}

impl<'a> MpkEngineBuilder<'a> {
    /// Planned maximum power / recurrence block size (default 4). Shorter
    /// sweeps use the tail-plan cache; see [`MpkEngine::sweep_len`].
    pub fn p_m(mut self, p_m: usize) -> Self {
        self.p_m = p_m;
        self
    }

    pub fn variant(mut self, v: Variant) -> Self {
        self.cfg.variant = v;
        self
    }

    pub fn executor(mut self, e: ExecutorKind) -> Self {
        self.cfg.executor = e;
        self
    }

    pub fn backend(mut self, b: BackendSpec) -> Self {
        self.cfg.backend = b;
        self
    }

    /// Enable per-rank span tracing (see [`EngineConfig::trace`]).
    pub fn trace(mut self, on: bool) -> Self {
        self.cfg.trace = on;
        self
    }

    /// Inner threads per rank (see [`EngineConfig::inner_threads`]);
    /// `k <= 1` keeps the serial per-rank path.
    pub fn inner_threads(mut self, k: usize) -> Self {
        self.cfg.inner_threads = k.max(1);
        self
    }

    /// Pipeline DLB's phase-3 remainder (see
    /// [`DlbOptions::async_remainder`]). No-op for non-DLB variants.
    pub fn async_remainder(mut self, on: bool) -> Self {
        if let Variant::Dlb(ref mut opts) = self.cfg.variant {
            opts.async_remainder = on;
        }
        self
    }

    /// Statically verify plans at prepare time (see
    /// [`EngineConfig::verify_plans`]; defaults to on in debug builds).
    pub fn verify_plans(mut self, on: bool) -> Self {
        self.cfg.verify_plans = on;
        self
    }

    pub fn build(self) -> anyhow::Result<MpkEngine> {
        MpkEngine::from_config(self.dist, self.p_m, &self.cfg)
    }
}

/// CA session state: the global overhead plan plus the per-rank exchange
/// plan derived from it, cached together per `p_m`.
struct CaSession {
    plan: CaPlan,
    exec: Arc<CaExecPlan>,
}

/// This rank's endpoint under the processes executor: the socket
/// communicator, a dedicated kernel backend, the rank's inner pool, and a
/// per-sweep generation counter that keeps control-plane tags
/// (gather/trace) unique across sweeps.
struct ProcExec {
    comm: SockComm,
    backend: Box<dyn SpmvBackend + Send>,
    inner: InnerExec,
    gen: u64,
}

enum VariantState {
    Trad,
    Dlb {
        pre: DlbPre,
        opts: DlbOptions,
        plans: HashMap<usize, Arc<DlbPlan>>,
        ws: Workspace,
    },
    Ca {
        a: Arc<CsrMatrix>,
        sessions: HashMap<usize, Arc<CaSession>>,
    },
}

/// A prepared MPK session: variant plan + workspaces + (for the threads
/// executor) the persistent rank pool. See the module docs.
pub struct MpkEngine {
    /// I/O-layout distributed matrix: the DLB-permuted clone for the DLB
    /// variant (shared by every cached plan), the caller's layout otherwise.
    dist: Arc<DistMatrix>,
    p_m: usize,
    variant: Variant,
    executor: ExecutorKind,
    state: VariantState,
    pool: Option<RankPool>,
    /// This rank's socket endpoint under the processes executor (`None`
    /// otherwise). SPMD: each launched process holds exactly one.
    proc: Option<ProcExec>,
    /// Configured inner threads per rank (1 = serial per-rank compute).
    inner_threads: usize,
    /// Per-rank inner pools for the *sequential* executor (empty when
    /// `inner_threads <= 1`; the threads executor's pool workers own their
    /// own [`InnerExec`]s instead).
    inners: Vec<InnerExec>,
    /// Span-trace collection (`None` unless [`EngineConfig::trace`]).
    trace: Option<TraceSession>,
    /// Host-side backend: runs every kernel under the sequential executor,
    /// and is exposed via [`MpkEngine::backend`] for ancillary products
    /// (e.g. the CG loop's full-matrix SpMV) so a whole solver honors one
    /// configured [`BackendSpec`].
    host_backend: Box<dyn SpmvBackend + Send>,
    plans_built: usize,
    sweeps: usize,
    /// Verify tail plans built on cache miss (see
    /// [`EngineConfig::verify_plans`]).
    verify_plans: bool,
}

impl MpkEngine {
    /// Start building an engine over `dist` (defaults: `p_m = 4`,
    /// DLB variant, sequential executor, native backend).
    pub fn builder(dist: &DistMatrix) -> MpkEngineBuilder<'_> {
        MpkEngineBuilder { dist, p_m: 4, cfg: EngineConfig::default() }
    }

    /// Build from a plain [`EngineConfig`] (what apps store in their own
    /// configuration structs). For the TRAD/CA variants this clones the
    /// caller's distributed matrix to own it — callers already holding an
    /// `Arc` avoid the copy with [`MpkEngine::from_shared`]. (DLB always
    /// works on its own level-permuted clone either way.)
    pub fn from_config(dist: &DistMatrix, p_m: usize, cfg: &EngineConfig) -> anyhow::Result<Self> {
        let shared = match cfg.variant {
            Variant::Dlb(_) => None, // preprocessing makes the permuted copy
            _ => Some(Arc::new(dist.clone())),
        };
        Self::construct(shared, dist, p_m, cfg)
    }

    /// Like [`MpkEngine::from_config`], but shares the caller's
    /// `Arc<DistMatrix>` instead of cloning the matrix data (TRAD/CA keep
    /// the caller's layout, so no copy is needed at all).
    pub fn from_shared(
        dist: Arc<DistMatrix>,
        p_m: usize,
        cfg: &EngineConfig,
    ) -> anyhow::Result<Self> {
        Self::construct(Some(dist.clone()), &dist, p_m, cfg)
    }

    /// Common constructor: `shared` must be `Some` for TRAD/CA (their
    /// I/O-layout matrix), and is ignored for DLB (which owns the permuted
    /// clone made by preprocessing).
    fn construct(
        shared: Option<Arc<DistMatrix>>,
        dist: &DistMatrix,
        p_m: usize,
        cfg: &EngineConfig,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(p_m >= 1, "engine p_m must be >= 1");
        cfg.executor.validate(dist.n_ranks())?;

        let mut plans_built = 0usize;
        let (dist_io, state) = match &cfg.variant {
            Variant::Trad => {
                (shared.expect("TRAD construct needs the shared matrix"), VariantState::Trad)
            }
            Variant::Dlb(opts) => {
                let pre = dlb::preprocess(dist);
                let mut plans = HashMap::new();
                plans.insert(p_m, Arc::new(dlb::plan_from_pre(&pre, p_m, opts)));
                plans_built += 1;
                let dist_io = pre.dist.clone();
                (dist_io, VariantState::Dlb { pre, opts: *opts, plans, ws: Workspace::default() })
            }
            Variant::Ca => {
                let a = Arc::new(ca::reassemble_global(dist));
                let plan = ca::ca_plan(&a, dist, p_m);
                let exec = Arc::new(ca::ca_exec_plan_from(dist, &plan));
                let mut sessions = HashMap::new();
                sessions.insert(p_m, Arc::new(CaSession { plan, exec }));
                plans_built += 1;
                (
                    shared.expect("CA construct needs the shared matrix"),
                    VariantState::Ca { a, sessions },
                )
            }
        };

        let inner_threads = cfg.inner_threads.max(1);
        if cfg.verify_plans {
            let v = crate::verify::Verifier::with_inner_threads(inner_threads);
            let report = match &state {
                VariantState::Trad => v.check_trad(&dist_io, p_m),
                VariantState::Dlb { plans, .. } => {
                    let plan = &plans[&p_m];
                    v.check_all(&dist_io, &plan.ranks, p_m)
                }
                VariantState::Ca { sessions, .. } => v.check_ca(&dist_io, &sessions[&p_m].exec),
            };
            report.into_result()?;
        }
        let trace = if cfg.trace { Some(TraceSession::new(dist_io.n_ranks())) } else { None };
        let (pool, proc, inners) = match cfg.executor {
            ExecutorKind::Sim => {
                let inners = if inner_threads >= 2 {
                    (0..dist_io.n_ranks())
                        .map(|r| InnerExec::new(inner_threads, r, &cfg.backend, trace.as_ref()))
                        .collect()
                } else {
                    Vec::new()
                };
                (None, None, inners)
            }
            ExecutorKind::Threads { .. } => {
                let pool =
                    RankPool::spawn(dist_io.n_ranks(), &cfg.backend, trace.as_ref(), inner_threads);
                (Some(pool), None, Vec::new())
            }
            ExecutorKind::Processes { .. } => {
                let env = RankEnv::from_env().ok_or_else(|| {
                    anyhow::anyhow!(
                        "the processes executor is SPMD: run this command under \
                         `dlb-mpk launch --np {} -- ...` (or set DLB_MPK_RANK / \
                         DLB_MPK_WORLD / DLB_MPK_SOCK_DIR yourself)",
                        dist_io.n_ranks()
                    )
                })?;
                anyhow::ensure!(
                    env.world == dist_io.n_ranks(),
                    "launched world size {} does not match the matrix's {} ranks",
                    env.world,
                    dist_io.n_ranks()
                );
                let mut comm = SockComm::from_env_for(&env, crate::exec::next_epoch())?;
                if let Some(ts) = &trace {
                    comm.set_tracer(ts.recorder(env.rank));
                }
                let inner = InnerExec::new(inner_threads, env.rank, &cfg.backend, trace.as_ref());
                (
                    None,
                    Some(ProcExec { comm, backend: cfg.backend.make(), inner, gen: 0 }),
                    Vec::new(),
                )
            }
        };

        Ok(Self {
            dist: dist_io,
            p_m,
            variant: cfg.variant,
            executor: cfg.executor,
            state,
            pool,
            proc,
            inner_threads,
            inners,
            trace,
            host_backend: cfg.backend.make(),
            plans_built,
            sweeps: 0,
            verify_plans: cfg.verify_plans,
        })
    }

    /// One full sweep at the planned `p_m`: `powers[p-1] = y_p` under the
    /// configured recurrence, with `y_0 = x0` (and `y_{-1} = x_m1` for
    /// Chebyshev; `None` = wind-up step).
    pub fn sweep(&mut self, x0: &[f64], x_m1: Option<&[f64]>, rec: Recurrence) -> SweepResult {
        self.sweep_len(self.p_m, x0, x_m1, rec)
    }

    /// A sweep of `p_m` powers, which may differ from the planned block
    /// size (tail blocks of a long recurrence). Plans for off-size sweeps
    /// are built from the shared p-independent preprocessing and cached, so
    /// a propagator pays for each distinct tail length once per engine.
    pub fn sweep_len(
        &mut self,
        p_m: usize,
        x0: &[f64],
        x_m1: Option<&[f64]>,
        rec: Recurrence,
    ) -> SweepResult {
        assert!(p_m >= 1, "sweep needs p_m >= 1");
        if matches!(self.state, VariantState::Ca { .. }) {
            assert!(
                rec == Recurrence::Power && x_m1.is_none(),
                "CA-MPK supports only the plain power recurrence"
            );
        }
        self.sweeps += 1;
        if self.pool.is_some() {
            self.sweep_pool(p_m, x0, x_m1, rec)
        } else if self.proc.is_some() {
            self.sweep_proc(p_m, x0, x_m1, rec)
        } else {
            self.sweep_sim(p_m, x0, x_m1, rec)
        }
    }

    /// Sequential lockstep execution (exact counters, no parallelism).
    fn sweep_sim(
        &mut self,
        p_m: usize,
        x0: &[f64],
        x_m1: Option<&[f64]>,
        rec: Recurrence,
    ) -> SweepResult {
        if matches!(self.state, VariantState::Trad) {
            let inners = sim_inners(&mut self.inners);
            return trad_recurrence_traced(
                &self.dist,
                x0,
                x_m1,
                p_m,
                rec,
                self.host_backend.as_mut(),
                self.trace.as_mut(),
                inners,
            );
        }
        if matches!(self.state, VariantState::Dlb { .. }) {
            let plan = self.dlb_plan_for(p_m);
            let inners = sim_inners(&mut self.inners);
            let (ws, trace) = match &mut self.state {
                VariantState::Dlb { ws, .. } => (ws, self.trace.as_mut()),
                _ => unreachable!(),
            };
            return dlb::execute_recurrence_traced(
                &plan,
                x0,
                x_m1,
                rec,
                self.host_backend.as_mut(),
                ws,
                trace,
                inners,
            );
        }
        let sess = self.ca_session_for(p_m);
        let a = match &self.state {
            VariantState::Ca { a, .. } => a.clone(),
            _ => unreachable!(),
        };
        let inners = sim_inners(&mut self.inners);
        ca::ca_execute_planned_traced(&a, &self.dist, &sess.plan, x0, self.trace.as_mut(), inners)
            .result
    }

    /// Dispatch one sweep over the persistent rank pool and merge the
    /// per-rank outputs deterministically (rank-ascending, exactly like the
    /// spawn-per-sweep drivers).
    fn sweep_pool(
        &mut self,
        p_m: usize,
        x0: &[f64],
        x_m1: Option<&[f64]>,
        rec: Recurrence,
    ) -> SweepResult {
        let dist = self.dist.clone();
        let n = dist.n_ranks();
        let xs = dist.scatter(x0);
        let xm1s: Vec<Option<Vec<f64>>> = match x_m1 {
            Some(v) => dist.scatter(v).into_iter().map(Some).collect(),
            None => vec![None; n],
        };

        let jobs: Vec<Job> = if matches!(self.state, VariantState::Trad) {
            xs.into_iter()
                .zip(xm1s)
                .map(|(x, x_m1)| Job::Trad { dist: dist.clone(), x, x_m1, p_m, rec })
                .collect()
        } else if matches!(self.state, VariantState::Dlb { .. }) {
            let plan = self.dlb_plan_for(p_m);
            xs.into_iter()
                .zip(xm1s)
                .map(|(x, x_m1)| Job::Dlb { plan: plan.clone(), x, x_m1, rec })
                .collect()
        } else {
            let sess = self.ca_session_for(p_m);
            let a = match &self.state {
                VariantState::Ca { a, .. } => a.clone(),
                _ => unreachable!(),
            };
            xs.into_iter()
                .map(|x| Job::Ca {
                    a: a.clone(),
                    dist: dist.clone(),
                    plan: sess.exec.clone(),
                    x,
                    p_m,
                })
                .collect()
        };

        let outs = self.pool.as_mut().expect("threads executor has a pool").sweep(jobs);
        assemble(&dist, p_m, outs)
    }

    /// SPMD sweep under the processes executor: run *this* rank's kernel
    /// against the socket communicator, then allgather every rank's
    /// `(RankRun, CommStats)` over the control plane so each process
    /// assembles the identical global [`SweepResult`] — the same
    /// rank-ascending merge as [`assemble`] under the other executors, so
    /// results are bitwise identical across all three. Ends by shipping
    /// ranks' trace buffers to rank 0 when tracing (a collective, so it
    /// must happen inside the sweep, not at export time).
    fn sweep_proc(
        &mut self,
        p_m: usize,
        x0: &[f64],
        x_m1: Option<&[f64]>,
        rec: Recurrence,
    ) -> SweepResult {
        let dist = self.dist.clone();
        let n = dist.n_ranks();
        // Resolve the tail plan before borrowing the endpoint (both need
        // `&mut self`); every process builds the same plan from the same
        // inputs, so plan caches stay in lockstep without communication.
        enum Kernel {
            Trad,
            Dlb(Arc<DlbPlan>),
            Ca(Arc<CsrMatrix>, Arc<CaSession>),
        }
        let kernel = if matches!(self.state, VariantState::Trad) {
            Kernel::Trad
        } else if matches!(self.state, VariantState::Dlb { .. }) {
            Kernel::Dlb(self.dlb_plan_for(p_m))
        } else {
            let sess = self.ca_session_for(p_m);
            let a = match &self.state {
                VariantState::Ca { a, .. } => a.clone(),
                _ => unreachable!(),
            };
            Kernel::Ca(a, sess)
        };
        let xs = dist.scatter(x0);
        let xm1s = x_m1.map(|v| dist.scatter(v));

        let proc = self.proc.as_mut().expect("processes executor has an endpoint");
        proc.gen += 1;
        let i = proc.comm.rank();
        let xm1 = xm1s.as_ref().map(|v| v[i].as_slice());
        let before = proc.comm.stats().clone();
        let run = match &kernel {
            Kernel::Trad => trad::trad_rank(
                &dist.ranks[i],
                &xs[i],
                xm1,
                p_m,
                rec,
                &mut proc.comm,
                proc.backend.as_mut(),
                &mut proc.inner,
            ),
            Kernel::Dlb(plan) => dlb::dlb_rank(
                &plan.dist.ranks[i],
                &plan.ranks[i],
                plan.p_m,
                &xs[i],
                xm1,
                rec,
                &mut proc.comm,
                proc.backend.as_mut(),
                &mut proc.inner,
            ),
            Kernel::Ca(a, sess) => ca::ca_rank(
                a,
                &dist.ranks[i],
                &sess.exec.sends[i],
                &sess.exec.recvs[i],
                &sess.exec.ext[i],
                &xs[i],
                p_m,
                &mut proc.comm,
                &mut proc.inner,
            ),
        };
        let delta = proc.comm.stats().delta_since(&before);

        // Allgather: every rank ships its (run, delta) to every peer with a
        // generation-tagged control frame (invisible to CommStats), then
        // receives each peer's. The kernel's final end_round barrier has
        // already synchronized everyone, so frames can't cross sweeps even
        // before the generation tag makes that structurally impossible.
        let tag = ctrl_tag(CTRL_GATHER, proc.gen);
        let mine = encode_rank_out(&run, &delta, p_m, dist.ranks[i].owned.len());
        for to in (0..n).filter(|&t| t != i) {
            proc.comm.send_ctrl(to, tag, mine.clone());
        }
        let mut outs: Vec<(RankRun, CommStats)> = Vec::with_capacity(n);
        for from in 0..n {
            if from == i {
                outs.push((
                    RankRun { ys: run.ys.clone(), flop_nnz: run.flop_nnz },
                    delta.clone(),
                ));
            } else {
                let payload = proc.comm.recv_ctrl(from, tag);
                outs.push(decode_rank_out(&payload, p_m, dist.ranks[from].owned.len()));
            }
        }
        let result = assemble(&dist, p_m, outs);
        self.harvest_proc();
        result
    }

    /// Collective trace harvest under the processes executor: ranks `> 0`
    /// encode their drained main + inner-lane streams
    /// ([`wire::encode_streams`]) and ship them to rank 0, which absorbs
    /// everything into its [`TraceSession`]. No-op unless tracing. Runs at
    /// the end of every `sweep_proc` — every process executes it, which is
    /// what makes the exchange safe to block on.
    fn harvest_proc(&mut self) {
        let Some(ts) = self.trace.as_mut() else {
            return;
        };
        let proc = self.proc.as_mut().expect("processes executor has an endpoint");
        let i = proc.comm.rank();
        let n = proc.comm.n_ranks();
        let main = proc.comm.take_trace_events();
        let lanes = proc.inner.harvest();
        let tag = ctrl_tag(CTRL_TRACE, proc.gen);
        if i == 0 {
            ts.absorb(0, main);
            for (lane, ev) in lanes {
                if !ev.is_empty() {
                    ts.absorb_lane(0, lane, ev);
                }
            }
            for from in 1..n {
                let payload = proc.comm.recv_ctrl(from, tag);
                let (m, ls) = wire::decode_streams(&payload);
                ts.absorb(from, m);
                for (lane, ev) in ls {
                    if !ev.is_empty() {
                        ts.absorb_lane(from, lane, ev);
                    }
                }
            }
        } else {
            let payload = wire::encode_streams(&main, &lanes);
            proc.comm.send_ctrl(0, tag, payload);
        }
    }

    /// Cached DLB plan for a sweep length, building (and counting) on miss.
    fn dlb_plan_for(&mut self, p_m: usize) -> Arc<DlbPlan> {
        let mut built = false;
        let plan = match &mut self.state {
            VariantState::Dlb { pre, opts, plans, .. } => plans
                .entry(p_m)
                .or_insert_with(|| {
                    built = true;
                    Arc::new(dlb::plan_from_pre(pre, p_m, opts))
                })
                .clone(),
            _ => unreachable!("dlb_plan_for on a non-DLB engine"),
        };
        if built {
            self.plans_built += 1;
            if self.verify_plans {
                let rep = crate::verify::Verifier::with_inner_threads(self.inner_threads)
                    .check_all(&self.dist, &plan.ranks, p_m);
                assert!(rep.is_ok(), "tail plan (p_m = {p_m}) failed verification:\n{rep}");
            }
        }
        plan
    }

    /// Cached CA session for a sweep length, building (and counting) on
    /// miss.
    fn ca_session_for(&mut self, p_m: usize) -> Arc<CaSession> {
        let mut built = false;
        let dist = self.dist.clone();
        let sess = match &mut self.state {
            VariantState::Ca { a, sessions } => sessions
                .entry(p_m)
                .or_insert_with(|| {
                    built = true;
                    let plan = ca::ca_plan(a, &dist, p_m);
                    let exec = Arc::new(ca::ca_exec_plan_from(&dist, &plan));
                    Arc::new(CaSession { plan, exec })
                })
                .clone(),
            _ => unreachable!("ca_session_for on a non-CA engine"),
        };
        if built {
            self.plans_built += 1;
            if self.verify_plans {
                let rep = crate::verify::Verifier::with_inner_threads(self.inner_threads)
                    .check_ca(&self.dist, &sess.exec);
                assert!(rep.is_ok(), "tail CA session (p_m = {p_m}) failed verification:\n{rep}");
            }
        }
        sess
    }

    // ---- introspection --------------------------------------------------

    /// Planned (default) sweep length.
    pub fn p_m(&self) -> usize {
        self.p_m
    }

    pub fn n_ranks(&self) -> usize {
        self.dist.n_ranks()
    }

    pub fn variant(&self) -> Variant {
        self.variant
    }

    pub fn executor(&self) -> ExecutorKind {
        self.executor
    }

    /// The engine's I/O-layout distributed matrix (the DLB-permuted clone
    /// for the DLB variant).
    pub fn dist(&self) -> &DistMatrix {
        &self.dist
    }

    /// The host-side SpMV backend, for ancillary per-iteration products
    /// outside the sweeps (e.g. CG's `A·p`), so the whole solver honors the
    /// configured [`BackendSpec`].
    pub fn backend(&mut self) -> &mut dyn SpmvBackend {
        self.host_backend.as_mut()
    }

    /// How many variant plans this engine has constructed (primary + tail
    /// cache misses). A propagator stepping many times must see this stay
    /// constant after the first step — regression-tested in
    /// `rust/tests/engine_session.rs`.
    pub fn plans_built(&self) -> usize {
        self.plans_built
    }

    /// Total sweeps executed through this engine.
    pub fn sweeps_run(&self) -> usize {
        self.sweeps
    }

    /// Persistent-pool counters (`None` under the sequential executor).
    pub fn pool_stats(&self) -> Option<PoolStats> {
        self.pool.as_ref().map(|p| p.stats())
    }

    /// Configured inner threads per rank (1 = serial per-rank compute).
    pub fn inner_threads(&self) -> usize {
        self.inner_threads
    }

    /// Whether plans are statically verified at prepare time (see
    /// [`EngineConfig::verify_plans`]).
    pub fn verifies_plans(&self) -> bool {
        self.verify_plans
    }

    /// Whether per-rank span tracing is on (see [`EngineConfig::trace`]).
    pub fn is_tracing(&self) -> bool {
        self.trace.is_some()
    }

    /// Pull buffered trace events into the session: pool workers' main
    /// streams plus every inner-pool worker's lane stream (sim-executor
    /// main streams absorb eagerly; all worker threads buffer until
    /// harvested).
    fn harvest_pool(&mut self) {
        let Some(ts) = self.trace.as_mut() else {
            return;
        };
        if let Some(pool) = self.pool.as_mut() {
            for (rank, (main, lanes)) in pool.harvest().into_iter().enumerate() {
                ts.absorb(rank, main);
                for (lane, ev) in lanes {
                    if !ev.is_empty() {
                        ts.absorb_lane(rank, lane, ev);
                    }
                }
            }
        }
        for (rank, ie) in self.inners.iter_mut().enumerate() {
            for (lane, ev) in ie.harvest() {
                if !ev.is_empty() {
                    ts.absorb_lane(rank, lane, ev);
                }
            }
        }
    }

    /// Aggregated trace metrics over everything swept so far (`None` unless
    /// tracing is enabled). Harvests the rank pool first.
    pub fn metrics(&mut self) -> Option<Metrics> {
        self.harvest_pool();
        self.trace.as_ref().map(|ts| ts.metrics())
    }

    /// Chrome Trace Event Format JSON of everything swept so far (`None`
    /// unless tracing is enabled) — open in `chrome://tracing` or Perfetto.
    /// Harvests the rank pool first.
    pub fn chrome_trace_json(&mut self) -> Option<String> {
        self.harvest_pool();
        self.trace.as_ref().map(|ts| ts.chrome_trace_json())
    }

    /// Paper Eq. (3) DLB overhead of the primary plan (`None` for other
    /// variants).
    pub fn dlb_overhead(&self) -> Option<f64> {
        match &self.state {
            VariantState::Dlb { plans, .. } => plans
                .get(&self.p_m)
                .map(|p| crate::mpk::overheads::dlb_overhead_from_plan(p)),
            _ => None,
        }
    }

    /// CA extended-halo / redundant-work overheads of the primary plan
    /// (`None` for other variants).
    pub fn ca_overheads(&self) -> Option<CaOverheads> {
        match &self.state {
            VariantState::Ca { sessions, .. } => {
                sessions.get(&self.p_m).map(|s| s.plan.overheads.clone())
            }
            _ => None,
        }
    }
}

/// Encode one rank's sweep output for the processes-executor allgather:
/// `[flop_nnz][messages][bytes][rounds][max_message_bytes][wait_len]
/// [wait_ns...]` as `u64` bit patterns riding in `f64`s (lossless — pure
/// bit transport, same trick as [`wire`]), then the owned prefix
/// (`n_owned` entries) of each power vector `ys[1..=p_m]` verbatim. Halo
/// tails are scratch (see [`RankRun`]) and never cross the wire.
fn encode_rank_out(run: &RankRun, delta: &CommStats, p_m: usize, n_owned: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(6 + delta.wait_ns.len() + p_m * n_owned);
    out.push(f64::from_bits(run.flop_nnz as u64));
    out.push(f64::from_bits(delta.messages as u64));
    out.push(f64::from_bits(delta.bytes as u64));
    out.push(f64::from_bits(delta.rounds as u64));
    out.push(f64::from_bits(delta.max_message_bytes as u64));
    out.push(f64::from_bits(delta.wait_ns.len() as u64));
    out.extend(delta.wait_ns.iter().map(|&w| f64::from_bits(w)));
    for p in 1..=p_m {
        out.extend_from_slice(&run.ys[p][..n_owned]);
    }
    out
}

/// Decode a peer's [`encode_rank_out`] payload. `n_owned` is the peer's
/// owned-row count from the (SPMD-identical) partition — exactly what the
/// sender shipped per power vector, asserted by the exact split of the
/// trailing values into `p_m` vectors of `n_owned` entries each (all
/// [`assemble`] ever reads).
fn decode_rank_out(payload: &[f64], p_m: usize, n_owned: usize) -> (RankRun, CommStats) {
    let flop_nnz = payload[0].to_bits() as usize;
    let messages = payload[1].to_bits() as usize;
    let bytes = payload[2].to_bits() as usize;
    let rounds = payload[3].to_bits() as usize;
    let max_message_bytes = payload[4].to_bits() as usize;
    let wait_len = payload[5].to_bits() as usize;
    let mut pos = 6;
    let wait_ns: Vec<u64> = payload[pos..pos + wait_len].iter().map(|w| w.to_bits()).collect();
    pos += wait_len;
    let rest = &payload[pos..];
    assert!(
        p_m >= 1 && rest.len() % p_m == 0,
        "rank-out payload: {} trailing values do not split into {p_m} power vectors",
        rest.len()
    );
    let per = rest.len() / p_m;
    assert_eq!(per, n_owned, "peer shipped {per} values per power, partition owns {n_owned}");
    let mut ys = vec![Vec::new()]; // ys[0] (the input) is never read by assemble
    for p in 0..p_m {
        ys.push(rest[p * per..(p + 1) * per].to_vec());
    }
    (
        RankRun { ys, flop_nnz },
        CommStats { messages, bytes, rounds, max_message_bytes, wait_ns },
    )
}

/// The sim-executor inner pools as the kernels' optional seam: `None` when
/// every rank is serial (the default), so that path stays exactly today's
/// code.
fn sim_inners(inners: &mut [InnerExec]) -> Option<&mut [InnerExec]> {
    if inners.is_empty() {
        None
    } else {
        Some(inners)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::partition::{partition, Method};

    fn dist(np: usize) -> DistMatrix {
        let a = gen::stencil_2d_5pt(12, 10);
        let part = partition(&a, np, Method::Block);
        DistMatrix::build(&a, &part)
    }

    #[test]
    fn builder_defaults_and_validation() {
        let d = dist(3);
        let eng = MpkEngine::builder(&d).build().unwrap();
        assert_eq!(eng.p_m(), 4);
        assert_eq!(eng.n_ranks(), 3);
        assert!(eng.pool_stats().is_none());
        assert_eq!(eng.plans_built(), 1);
        // threads(n) must match the prebuilt matrix
        assert!(MpkEngine::builder(&d)
            .executor(ExecutorKind::Threads { n: 2 })
            .build()
            .is_err());
        assert!(MpkEngine::builder(&d)
            .executor(ExecutorKind::Threads { n: 3 })
            .build()
            .is_ok());
    }

    #[test]
    fn engine_matches_direct_kernels_per_variant() {
        let d = dist(4);
        let x: Vec<f64> = (0..d.n_global).map(|i| ((i % 13) as f64 - 6.0) / 7.0).collect();
        let p_m = 3;

        let want = crate::mpk::trad_mpk(&d, &x, p_m, &mut NativeBackend);
        let mut eng = MpkEngine::builder(&d).p_m(p_m).variant(Variant::Trad).build().unwrap();
        let got = eng.sweep(&x, None, Recurrence::Power);
        assert_eq!(want.powers, got.powers);
        assert_eq!(want.comm, got.comm);
        assert_eq!(want.flop_nnz, got.flop_nnz);

        let opts = DlbOptions { cache_bytes: 8 << 10, s_m: 50, async_remainder: false };
        let plan = dlb::plan(&d, p_m, &opts);
        let want = dlb::execute(&plan, &x, &mut NativeBackend);
        let mut eng =
            MpkEngine::builder(&d).p_m(p_m).variant(Variant::Dlb(opts)).build().unwrap();
        let got = eng.sweep(&x, None, Recurrence::Power);
        assert_eq!(want.powers, got.powers);
        assert_eq!(want.comm, got.comm);

        let a = ca::reassemble_global(&d);
        let want = ca::ca_mpk_with(&a, &d, &x, p_m);
        let mut eng = MpkEngine::builder(&d).p_m(p_m).variant(Variant::Ca).build().unwrap();
        let got = eng.sweep(&x, None, Recurrence::Power);
        assert_eq!(want.result.powers, got.powers);
        assert_eq!(want.result.comm, got.comm);
        assert_eq!(want.result.flop_nnz, got.flop_nnz);
        assert!(eng.ca_overheads().is_some());
    }

    #[test]
    fn tail_plans_are_cached() {
        let d = dist(2);
        let x = vec![1.0; d.n_global];
        let opts = DlbOptions { cache_bytes: 8 << 10, s_m: 50, async_remainder: false };
        let mut eng =
            MpkEngine::builder(&d).p_m(4).variant(Variant::Dlb(opts)).build().unwrap();
        assert_eq!(eng.plans_built(), 1);
        eng.sweep(&x, None, Recurrence::Power);
        assert_eq!(eng.plans_built(), 1, "primary sweep must reuse the build-time plan");
        eng.sweep_len(2, &x, None, Recurrence::Power);
        assert_eq!(eng.plans_built(), 2, "first tail length builds one plan");
        eng.sweep_len(2, &x, None, Recurrence::Power);
        eng.sweep_len(2, &x, None, Recurrence::Power);
        assert_eq!(eng.plans_built(), 2, "repeated tail sweeps hit the cache");
        assert_eq!(eng.sweeps_run(), 4);
    }

    #[test]
    fn async_remainder_builder_knob_is_bitwise_neutral() {
        let d = dist(3);
        let x: Vec<f64> = (0..d.n_global).map(|i| ((i % 11) as f64 - 5.0) / 3.0).collect();
        let opts = DlbOptions { cache_bytes: 8 << 10, s_m: 50, async_remainder: false };
        let mut sync_eng =
            MpkEngine::builder(&d).p_m(3).variant(Variant::Dlb(opts)).build().unwrap();
        let want = sync_eng.sweep(&x, None, Recurrence::Power);
        for exec in [ExecutorKind::Sim, ExecutorKind::Threads { n: 0 }] {
            let mut eng = MpkEngine::builder(&d)
                .p_m(3)
                .variant(Variant::Dlb(opts))
                .async_remainder(true)
                .executor(exec)
                .build()
                .unwrap();
            let got = eng.sweep(&x, None, Recurrence::Power);
            assert_eq!(want.powers, got.powers, "async remainder must be bitwise neutral");
            assert_eq!(want.comm, got.comm, "volume/round counters must match lockstep");
            assert_eq!(want.flop_nnz, got.flop_nnz);
        }
        // the knob is a no-op on non-DLB variants
        let mut eng = MpkEngine::builder(&d)
            .p_m(2)
            .variant(Variant::Trad)
            .async_remainder(true)
            .build()
            .unwrap();
        eng.sweep(&x, None, Recurrence::Power);
    }

    #[test]
    fn verify_plans_knob_gates_prepare_time_checks() {
        let d = dist(3);
        // Explicitly on: every variant's plans pass the static analyzers.
        for variant in [Variant::Trad, Variant::Ca, Variant::Dlb(DlbOptions::default())] {
            let eng = MpkEngine::builder(&d)
                .p_m(3)
                .variant(variant)
                .verify_plans(true)
                .build()
                .unwrap();
            assert!(eng.verifies_plans());
        }
        // Explicitly off: nothing verifies, results are unaffected.
        let x = vec![1.0; d.n_global];
        let mut on = MpkEngine::builder(&d).p_m(2).verify_plans(true).build().unwrap();
        let mut off = MpkEngine::builder(&d).p_m(2).verify_plans(false).build().unwrap();
        assert!(!off.verifies_plans());
        let a = on.sweep(&x, None, Recurrence::Power);
        let b = off.sweep(&x, None, Recurrence::Power);
        assert_eq!(a.powers, b.powers, "verification must be bitwise invisible");
        assert_eq!(a.comm, b.comm);
        // Tail plans built on cache miss verify too (asserting internally).
        on.sweep_len(1, &x, None, Recurrence::Power);
        assert_eq!(on.plans_built(), 2);
    }

    #[test]
    fn pool_survives_and_counts_sweeps() {
        let d = dist(3);
        let x = vec![1.0; d.n_global];
        let mut eng = MpkEngine::builder(&d)
            .p_m(2)
            .variant(Variant::Trad)
            .executor(ExecutorKind::Threads { n: 0 })
            .build()
            .unwrap();
        let a = eng.sweep(&x, None, Recurrence::Power);
        let b = eng.sweep(&x, None, Recurrence::Power);
        assert_eq!(a.powers, b.powers);
        assert_eq!(a.comm, b.comm, "per-sweep stats must not accumulate");
        let st = eng.pool_stats().unwrap();
        assert_eq!(st.threads, 3);
        assert_eq!(st.sweeps, 2);
    }
}
