//! The persistent rank pool behind [`crate::engine::MpkEngine`]'s threads
//! executor.
//!
//! [`crate::exec`]'s original threaded drivers spawn `n_ranks` OS threads
//! *per call* — fine for one-shot benchmarks, ruinous for an application
//! that drives thousands of MPK sweeps against the same matrix (a Chebyshev
//! propagator runs one sweep per `p_m` recurrence terms per plane per time
//! step). The pool spawns the rank threads **once**, each owning its
//! [`ThreadComm`] endpoint and its own [`SpmvBackend`] instance, and parks
//! them on a per-rank job channel. A sweep is then: send one [`Job`] per
//! rank, collect one `(RankRun, CommStats)` per rank — thread creation,
//! channel wiring, and barrier setup are all paid at engine build.
//!
//! ## Per-sweep statistics
//!
//! A persistent [`ThreadComm`] accumulates its counters across sweeps (the
//! round barrier *requires* the absolute round counters to stay aligned),
//! so each worker snapshots its stats before the kernel and reports the
//! difference — making every sweep's merged [`CommStats`] identical to a
//! fresh spawn-per-sweep run, which the engine-reuse equivalence tests
//! assert bitwise.
//!
//! ## Tag safety across sweeps
//!
//! Kernels tag messages with small per-sweep round numbers starting at 0,
//! so consecutive sweeps reuse tags. This is safe: within a sweep every
//! posted message is received before its round's barrier, and the final
//! round of a sweep ends with a barrier — by the time any rank starts the
//! next sweep, all channels and pending queues are empty.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::distsim::{CommStats, DistMatrix};
use crate::exec::comm::{thread_comms, Communicator, ThreadComm};
use crate::exec::RankRun;
use crate::inner::InnerExec;
use crate::matrix::CsrMatrix;
use crate::mpk::ca::CaExecPlan;
use crate::mpk::dlb::{DlbPlan, Recurrence};
use crate::mpk::SpmvBackend;
use crate::mpk::{ca, dlb, trad};
use crate::trace::{Event, Span, TraceSession};

use super::BackendSpec;

/// One rank's share of one sweep. Inputs are the rank's scattered local
/// vectors (halo tails scratch); plans ride along as `Arc`s so tail-block
/// sweeps can ship a different cached plan without touching the pool.
pub(crate) enum Job {
    Trad {
        dist: Arc<DistMatrix>,
        x: Vec<f64>,
        x_m1: Option<Vec<f64>>,
        p_m: usize,
        rec: Recurrence,
    },
    Dlb {
        plan: Arc<DlbPlan>,
        x: Vec<f64>,
        x_m1: Option<Vec<f64>>,
        rec: Recurrence,
    },
    Ca {
        a: Arc<CsrMatrix>,
        dist: Arc<DistMatrix>,
        plan: Arc<CaExecPlan>,
        x: Vec<f64>,
        p_m: usize,
    },
    /// Drain the worker's trace buffers — its main-thread events plus the
    /// `(lane, events)` streams of its inner pool (no sweep, no stats
    /// delta). The worker replies on the dedicated sender so the result
    /// channel's one-reply-per-sweep invariant is untouched.
    Harvest(Sender<(Vec<Event>, Vec<(usize, Vec<Event>)>)>),
}

/// Pool health/usage counters (see [`crate::engine::MpkEngine::pool_stats`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolStats {
    /// Rank threads spawned at engine build — constant for the engine's
    /// lifetime (the point of the pool: no per-sweep spawning).
    pub threads: usize,
    /// Sweeps dispatched through the pool since build.
    pub sweeps: usize,
}

/// `n_ranks` long-lived rank threads parked on per-rank job channels.
pub(crate) struct RankPool {
    jobs: Vec<Sender<Job>>,
    results: Vec<Receiver<(RankRun, CommStats)>>,
    handles: Vec<JoinHandle<()>>,
    n: usize,
    sweeps: usize,
}

impl RankPool {
    /// Spawn the rank threads, each with its [`ThreadComm`] endpoint, a
    /// private backend instance from `backend`, and (for
    /// `inner_threads >= 2`) its own [`InnerExec`] inner pool. With `trace`
    /// set, each endpoint gets an enabled recorder (shared session epoch)
    /// before it moves into its worker.
    pub(crate) fn spawn(
        n: usize,
        backend: &BackendSpec,
        trace: Option<&TraceSession>,
        inner_threads: usize,
    ) -> Self {
        let mut comms = thread_comms(n);
        if let Some(ts) = trace {
            for (i, c) in comms.iter_mut().enumerate() {
                c.set_tracer(ts.recorder(i));
            }
        }
        let mut jobs = Vec::with_capacity(n);
        let mut results = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for (i, comm) in comms.into_iter().enumerate() {
            let (job_tx, job_rx) = channel::<Job>();
            let (res_tx, res_rx) = channel::<(RankRun, CommStats)>();
            let be = backend.make();
            let inner = InnerExec::new(inner_threads, i, backend, trace);
            let handle = std::thread::Builder::new()
                .name(format!("mpk-rank-{i}"))
                .spawn(move || worker(i, comm, be, inner, job_rx, res_tx))
                .expect("spawn rank thread");
            jobs.push(job_tx);
            results.push(res_rx);
            handles.push(handle);
        }
        Self { jobs, results, handles, n, sweeps: 0 }
    }

    pub(crate) fn stats(&self) -> PoolStats {
        PoolStats { threads: self.n, sweeps: self.sweeps }
    }

    /// Run one sweep: dispatch `jobs[i]` to rank `i`, then collect results
    /// in ascending rank order (deterministic merge downstream).
    ///
    /// # Panics
    ///
    /// If a rank thread has died (its kernel panicked) — the poisoned
    /// barrier/channels make every peer fail too, so the error surfaces
    /// here instead of deadlocking.
    pub(crate) fn sweep(&mut self, jobs: Vec<Job>) -> Vec<(RankRun, CommStats)> {
        assert_eq!(jobs.len(), self.n, "one job per rank");
        for (tx, job) in self.jobs.iter().zip(jobs) {
            tx.send(job).expect("rank worker died before the sweep");
        }
        self.sweeps += 1;
        self.results
            .iter()
            .map(|rx| rx.recv().expect("rank worker panicked mid-sweep"))
            .collect()
    }

    /// Drain every worker's trace buffers (main events + inner-pool lanes),
    /// in rank order. Does not count as a sweep. Returns empty buffers when
    /// tracing is disabled.
    pub(crate) fn harvest(&mut self) -> Vec<(Vec<Event>, Vec<(usize, Vec<Event>)>)> {
        let mut out = Vec::with_capacity(self.n);
        for tx in &self.jobs {
            let (ev_tx, ev_rx) = channel();
            tx.send(Job::Harvest(ev_tx)).expect("rank worker died before harvest");
            out.push(ev_rx.recv().expect("rank worker died during harvest"));
        }
        out
    }
}

impl Drop for RankPool {
    fn drop(&mut self) {
        // Close the job channels so every parked worker's recv() errors and
        // the thread exits, then join. Join errors (a worker that panicked
        // during a sweep) are ignored here: the panic already surfaced to
        // the caller through `sweep`'s result recv.
        self.jobs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Rank thread body: park on the job channel, run the matching single-rank
/// kernel, report the run plus this sweep's communication-stat delta.
fn worker(
    i: usize,
    mut comm: ThreadComm,
    mut backend: Box<dyn SpmvBackend + Send>,
    mut inner: InnerExec,
    jobs: Receiver<Job>,
    results: Sender<(RankRun, CommStats)>,
) {
    let mut park_t0 = comm.tracer().now();
    while let Ok(job) = jobs.recv() {
        comm.tracer().closed_span(Span::JobPark, park_t0);
        let job = match job {
            Job::Harvest(tx) => {
                let ev = comm.tracer().take_events();
                let _ = tx.send((ev, inner.harvest()));
                park_t0 = comm.tracer().now();
                continue;
            }
            other => other,
        };
        let t0 = comm.tracer().now();
        let before = comm.stats().clone();
        let run = match job {
            Job::Trad { dist, x, x_m1, p_m, rec } => trad::trad_rank(
                &dist.ranks[i],
                &x,
                x_m1.as_deref(),
                p_m,
                rec,
                &mut comm,
                backend.as_mut(),
                &mut inner,
            ),
            Job::Dlb { plan, x, x_m1, rec } => dlb::dlb_rank(
                &plan.dist.ranks[i],
                &plan.ranks[i],
                plan.p_m,
                &x,
                x_m1.as_deref(),
                rec,
                &mut comm,
                backend.as_mut(),
                &mut inner,
            ),
            Job::Ca { a, dist, plan, x, p_m } => ca::ca_rank(
                &a,
                &dist.ranks[i],
                &plan.sends[i],
                &plan.recvs[i],
                &plan.ext[i],
                &x,
                p_m,
                &mut comm,
                &mut inner,
            ),
            Job::Harvest(_) => unreachable!("handled above"),
        };
        let delta = comm.stats().delta_since(&before);
        comm.tracer().closed_span(Span::JobDispatch, t0);
        if results.send((run, delta)).is_err() {
            break; // engine dropped mid-sweep
        }
        park_t0 = comm.tracer().now();
    }
}
