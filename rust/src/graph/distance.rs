//! Distance-from-boundary classes `I_k` (paper §5).
//!
//! On each MPI rank, local vertices are classified by their graph distance
//! `k` from the halo boundary `B`: `I_k` can be promoted only to power `k`
//! during the local cache-blocked phase; vertices with `k >= p_m` form the
//! bulk structure `M` where RACE blocks freely.

use crate::graph::Adjacency;

/// Multi-source BFS distances from `sources` (u32::MAX = unreachable).
pub fn multi_source_distances(g: &Adjacency, sources: &[u32]) -> Vec<u32> {
    let mut dist = vec![u32::MAX; g.n];
    let mut frontier: Vec<u32> = Vec::with_capacity(sources.len());
    for &s in sources {
        if dist[s as usize] == u32::MAX {
            dist[s as usize] = 0;
            frontier.push(s);
        }
    }
    let mut next = Vec::new();
    let mut d = 0u32;
    while !frontier.is_empty() {
        next.clear();
        for &u in &frontier {
            for &v in g.neighbors(u as usize) {
                if dist[v as usize] == u32::MAX {
                    dist[v as usize] = d + 1;
                    next.push(v);
                }
            }
        }
        std::mem::swap(&mut frontier, &mut next);
        d += 1;
    }
    dist
}

/// Distance classes of a rank-local graph.
///
/// `class_of[v] = min(dist(v, boundary), cap)` where `cap = p_m` lumps
/// everything at distance `>= p_m` (and unreachable vertices) into the bulk
/// `M`. Class indices `1..p_m` are the paper's `I_1 .. I_{p_m-1}` — note
/// `I_0 = B` is the *halo buffer*, which lives outside the local vertex set,
/// so local classes start at 1.
pub struct DistanceClasses {
    /// For each local vertex: its class in `[1, cap]`; `cap` = bulk `M`.
    pub class_of: Vec<u32>,
    pub cap: u32,
    /// Vertices per class, `counts[k-1]` = |I_k| for k in 1..=cap.
    pub counts: Vec<usize>,
}

/// Classify local vertices by distance from the boundary.
///
/// * `g` — adjacency of the rank-local graph over `n_local + n_halo`
///   vertices (halo vertices at indices `>= n_local`).
/// * `n_local` — number of owned vertices.
/// * `cap` — `p_m`; distances are clamped to it.
///
/// Distance 1 = local vertex adjacent to a halo vertex, matching the paper:
/// "internal vertices at a distance of k from the boundary B … can only be
/// elevated up to A^k x".
pub fn distance_classes(g: &Adjacency, n_local: usize, cap: u32) -> DistanceClasses {
    assert!(cap >= 1);
    let halo: Vec<u32> = (n_local as u32..g.n as u32).collect();
    let dist = multi_source_distances(g, &halo);
    let mut class_of = vec![0u32; n_local];
    let mut counts = vec![0usize; cap as usize];
    for v in 0..n_local {
        let d = dist[v];
        let k = if d == u32::MAX { cap } else { d.min(cap) };
        // Local vertices adjacent to the halo have d == 1 already; d == 0
        // can't happen for v < n_local because sources are halo-only.
        debug_assert!(k >= 1);
        class_of[v] = k;
        counts[(k - 1) as usize] += 1;
    }
    DistanceClasses { class_of, cap, counts }
}

impl DistanceClasses {
    /// |M| — vertices in the bulk structure (promotable to p_m locally).
    pub fn bulk_size(&self) -> usize {
        self.counts[self.cap as usize - 1]
    }

    /// Paper Eq. (2): local DLB overhead `1 - |M_i| / N_{i,r}`.
    pub fn local_overhead(&self) -> f64 {
        let n: usize = self.counts.iter().sum();
        if n == 0 {
            0.0
        } else {
            1.0 - self.bulk_size() as f64 / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Adjacency;
    use crate::matrix::gen;

    /// Path graph 0-1-2-3-4-5 where 4,5 are "halo".
    fn path_with_halo() -> Adjacency {
        Adjacency::from_matrix(&gen::tridiag(6))
    }

    #[test]
    fn distances_from_multiple_sources() {
        let g = path_with_halo();
        let d = multi_source_distances(&g, &[0, 5]);
        assert_eq!(d, vec![0, 1, 2, 2, 1, 0]);
    }

    #[test]
    fn classes_clamp_to_bulk() {
        let g = path_with_halo();
        // local = 0..4, halo = {4, 5}; distances from halo: [4,3,2,1]
        let dc = distance_classes(&g, 4, 3);
        assert_eq!(dc.class_of, vec![3, 3, 2, 1]);
        assert_eq!(dc.counts, vec![1, 1, 2]); // |I_1|=1, |I_2|=1, |M|=2
        assert_eq!(dc.bulk_size(), 2);
        assert!((dc.local_overhead() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn no_halo_means_all_bulk() {
        let g = Adjacency::from_matrix(&gen::tridiag(4));
        let dc = distance_classes(&g, 4, 5);
        assert_eq!(dc.bulk_size(), 4);
        assert_eq!(dc.local_overhead(), 0.0);
    }

    #[test]
    fn boundary_vertex_is_class_one() {
        let a = gen::stencil_2d_5pt(4, 4);
        // treat last row of the grid (12..16) as halo
        let g = Adjacency::from_matrix(&a);
        let dc = distance_classes(&g, 12, 4);
        // grid rows y=2 touch halo y=3 -> class 1
        for x in 0..4 {
            assert_eq!(dc.class_of[2 * 4 + x], 1);
            assert_eq!(dc.class_of[4 + x], 2);
            assert_eq!(dc.class_of[x], 3);
        }
    }
}
