//! Breadth-first level construction (paper §3, the `L(i)` definition).

use crate::graph::Adjacency;

/// Result of a full BFS traversal: `level_of[v]` for every vertex, plus the
/// number of levels. Disconnected components are handled the practical way
/// RACE does: when the frontier empties with unvisited vertices left, the
/// smallest-index unvisited vertex seeds the *next* level, so levels remain
/// mutually exclusive and jointly exhaustive.
pub struct BfsResult {
    pub level_of: Vec<u32>,
    pub n_levels: usize,
}

/// BFS levels from `root` (RACE uses row 0 by default).
pub fn bfs_levels(g: &Adjacency, root: usize) -> BfsResult {
    let n = g.n;
    let mut level_of = vec![u32::MAX; n];
    if n == 0 {
        return BfsResult { level_of, n_levels: 0 };
    }
    let mut frontier: Vec<u32> = vec![root as u32];
    level_of[root] = 0;
    let mut next: Vec<u32> = Vec::new();
    let mut level = 0u32;
    let mut visited = 1usize;
    let mut unvisited_scan = 0usize; // monotone scan pointer for restarts
    loop {
        next.clear();
        for &u in &frontier {
            for &v in g.neighbors(u as usize) {
                if level_of[v as usize] == u32::MAX {
                    level_of[v as usize] = level + 1;
                    next.push(v);
                    visited += 1;
                }
            }
        }
        if next.is_empty() {
            if visited == n {
                break;
            }
            // disconnected: seed next level with first unvisited vertex
            while level_of[unvisited_scan] != u32::MAX {
                unvisited_scan += 1;
            }
            level_of[unvisited_scan] = level + 1;
            next.push(unvisited_scan as u32);
            visited += 1;
        }
        std::mem::swap(&mut frontier, &mut next);
        level += 1;
    }
    BfsResult { level_of, n_levels: level as usize + 1 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Adjacency;
    use crate::matrix::gen;

    #[test]
    fn path_graph_levels_are_distance() {
        let a = gen::tridiag(6);
        let g = Adjacency::from_matrix(&a);
        let r = bfs_levels(&g, 0);
        assert_eq!(r.level_of, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(r.n_levels, 6);
    }

    #[test]
    fn stencil_levels_are_manhattan_distance() {
        let (nx, ny) = (5, 4);
        let a = gen::stencil_2d_5pt(nx, ny);
        let g = Adjacency::from_matrix(&a);
        let r = bfs_levels(&g, 0);
        for y in 0..ny {
            for x in 0..nx {
                assert_eq!(r.level_of[y * nx + x], (x + y) as u32);
            }
        }
    }

    #[test]
    fn disconnected_components_get_fresh_levels() {
        // two disjoint edges: {0,1}, {2,3}
        let mut coo = crate::matrix::CooMatrix::new(4, 4);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 1.0);
        coo.push(2, 3, 1.0);
        coo.push(3, 2, 1.0);
        let g = Adjacency::from_matrix(&coo.to_csr());
        let r = bfs_levels(&g, 0);
        assert_eq!(r.level_of[0], 0);
        assert_eq!(r.level_of[1], 1);
        // restart: vertex 2 lands in level 2, its neighbor 3 in level 3
        assert_eq!(r.level_of[2], 2);
        assert_eq!(r.level_of[3], 3);
        assert_eq!(r.n_levels, 4);
    }

    #[test]
    fn root_choice_shifts_levels() {
        let a = gen::tridiag(5);
        let g = Adjacency::from_matrix(&a);
        let r = bfs_levels(&g, 2);
        assert_eq!(r.level_of, vec![2, 1, 0, 1, 2]);
    }
}
