//! Matrix↔graph correspondence and level machinery (paper §3).
//!
//! A sparse matrix `A` corresponds to a graph `G(A)` whose vertices are rows
//! and whose edges are non-zeros. RACE's level-based SpMV formulation rests
//! on BFS levels of this graph: `N(L(i)) ⊆ {L(i-1), L(i), L(i+1)}`, so a
//! wavefront over levels can promote rows to higher powers of `A` while the
//! relevant matrix data is still in cache.
//!
//! Non-symmetric patterns are handled the way RACE does (paper footnote 4):
//! levels are computed on the *symmetrized* pattern `A + Aᵀ`; the fill-in
//! affects only level construction, never the numerics.

pub mod bfs;
pub mod distance;
pub mod levels;

pub use bfs::bfs_levels;
pub use distance::distance_classes;
pub use levels::Levels;

use crate::matrix::CsrMatrix;

/// Symmetrized adjacency (pattern of `A + Aᵀ`, self-loops removed).
///
/// Self-loops (diagonal entries) are irrelevant for BFS levels — a vertex is
/// trivially its own distance-0 neighbor — and removing them keeps level
/// invariants clean.
#[derive(Clone, Debug)]
pub struct Adjacency {
    pub n: usize,
    pub ptr: Vec<usize>,
    pub adj: Vec<u32>,
}

impl Adjacency {
    /// Fast path for pattern-symmetric matrices: adjacency = pattern minus
    /// the diagonal, no sort needed. Falls back to the general
    /// (symmetrizing) path otherwise.
    pub fn from_symmetric_or_general(a: &CsrMatrix) -> Self {
        if a.pattern_symmetric() {
            let n = a.n_rows;
            let mut ptr = Vec::with_capacity(n + 1);
            ptr.push(0usize);
            let mut adj = Vec::with_capacity(a.nnz());
            for r in 0..n {
                for &c in a.row_cols(r) {
                    if c as usize != r {
                        adj.push(c);
                    }
                }
                ptr.push(adj.len());
            }
            Self { n, ptr, adj }
        } else {
            Self::from_matrix(a)
        }
    }

    /// Adjacency of a rank-local block (`nl` owned rows, `nv − nl` halo
    /// slots as extra vertices). Assumes the local-local sub-pattern is
    /// symmetric (true whenever the global matrix is pattern-symmetric,
    /// which `distsim::DistMatrix::build` preserves); debug-asserted.
    /// Halo back-edges are derived by bucketing — no global sort.
    pub fn from_local_block(a: &CsrMatrix, nl: usize) -> Self {
        let nv = a.n_cols;
        debug_assert!(a.n_rows == nl && nv >= nl);
        // degree pass
        let mut ptr = vec![0usize; nv + 1];
        for r in 0..nl {
            for &c in a.row_cols(r) {
                let c = c as usize;
                if c == r {
                    continue;
                }
                ptr[r + 1] += 1;
                if c >= nl {
                    ptr[c + 1] += 1; // halo back-edge
                } else {
                    debug_assert!(
                        a.row_cols(c).binary_search(&(r as u32)).is_ok(),
                        "local block pattern not symmetric; use from_matrix"
                    );
                }
            }
        }
        for i in 0..nv {
            ptr[i + 1] += ptr[i];
        }
        let mut adj = vec![0u32; ptr[nv]];
        let mut fill = ptr.clone();
        for r in 0..nl {
            for &c in a.row_cols(r) {
                let c = c as usize;
                if c == r {
                    continue;
                }
                adj[fill[r]] = c as u32;
                fill[r] += 1;
                if c >= nl {
                    adj[fill[c]] = r as u32;
                    fill[c] += 1;
                }
            }
        }
        // halo rows were filled in ascending r automatically; local rows are
        // sorted because CSR columns are sorted
        Self { n: nv, ptr, adj }
    }

    pub fn from_matrix(a: &CsrMatrix) -> Self {
        assert_eq!(a.n_rows, a.n_cols, "graph view needs a square matrix");
        let n = a.n_rows;
        // degree count for A + Aᵀ without duplicates: collect pairs
        let mut pairs: Vec<(u32, u32)> = Vec::with_capacity(2 * a.nnz());
        for r in 0..n {
            for &c in a.row_cols(r) {
                if c as usize != r {
                    pairs.push((r as u32, c));
                    pairs.push((c, r as u32));
                }
            }
        }
        pairs.sort_unstable();
        pairs.dedup();
        let mut ptr = vec![0usize; n + 1];
        for &(r, _) in &pairs {
            ptr[r as usize + 1] += 1;
        }
        for i in 0..n {
            ptr[i + 1] += ptr[i];
        }
        let adj = pairs.into_iter().map(|(_, c)| c).collect();
        Self { n, ptr, adj }
    }

    #[inline]
    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.adj[self.ptr[v]..self.ptr[v + 1]]
    }

    pub fn degree(&self, v: usize) -> usize {
        self.ptr[v + 1] - self.ptr[v]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    #[test]
    fn adjacency_symmetrizes_and_drops_diagonal() {
        // Asymmetric 3x3: edge 0->2 only.
        let a = crate::matrix::CsrMatrix::new(
            3,
            3,
            vec![0, 2, 3, 4],
            vec![0, 2, 1, 2],
            vec![1.0; 4],
        );
        let g = Adjacency::from_matrix(&a);
        assert_eq!(g.neighbors(0), &[2]);
        assert_eq!(g.neighbors(2), &[0]); // symmetrized
        assert_eq!(g.neighbors(1), &[] as &[u32]); // diagonal removed
    }

    #[test]
    fn stencil_degrees() {
        let a = gen::stencil_2d_5pt(4, 4);
        let g = Adjacency::from_matrix(&a);
        assert_eq!(g.degree(0), 2); // corner
        assert_eq!(g.degree(5), 4); // interior
    }
}
