//! The `Levels` structure: BFS levels as contiguous row ranges after the
//! symmetric "BFS reordering" permutation (paper §3, Fig. 1c/1d).

use crate::graph::{bfs_levels, Adjacency};
use crate::matrix::CsrMatrix;

/// Levels of a (permuted) matrix.
///
/// After BFS reordering, level `i` occupies rows
/// `[level_ptr[i], level_ptr[i+1])` of the permuted matrix, and the key
/// invariant holds: every non-zero of a row in level `i` has its column in
/// levels `{i-1, i, i+1}`.
#[derive(Clone, Debug)]
pub struct Levels {
    /// `level_ptr[i]..level_ptr[i+1]` = rows of level i (permuted indexing).
    pub level_ptr: Vec<usize>,
    /// `perm[new] = old` — the symmetric BFS permutation applied.
    pub perm: Vec<usize>,
    /// `inv_perm[old] = new`.
    pub inv_perm: Vec<usize>,
}

impl Levels {
    /// Compute BFS levels of `a` from `root` and the stable-by-level
    /// permutation (original order preserved within a level).
    pub fn compute(a: &CsrMatrix, root: usize) -> Self {
        let g = Adjacency::from_symmetric_or_general(a);
        let r = bfs_levels(&g, root);
        Self::from_level_of(&r.level_of, r.n_levels)
    }

    /// Build from a level assignment (counting sort by level, stable).
    pub fn from_level_of(level_of: &[u32], n_levels: usize) -> Self {
        let n = level_of.len();
        let mut counts = vec![0usize; n_levels + 1];
        for &l in level_of {
            counts[l as usize + 1] += 1;
        }
        for i in 0..n_levels {
            counts[i + 1] += counts[i];
        }
        let level_ptr = counts.clone();
        let mut perm = vec![0usize; n];
        let mut fill = counts;
        for (old, &l) in level_of.iter().enumerate() {
            perm[fill[l as usize]] = old;
            fill[l as usize] += 1;
        }
        let mut inv_perm = vec![0usize; n];
        for (new, &old) in perm.iter().enumerate() {
            inv_perm[old] = new;
        }
        Self { level_ptr, perm, inv_perm }
    }

    pub fn n_levels(&self) -> usize {
        self.level_ptr.len() - 1
    }

    pub fn n_rows(&self) -> usize {
        *self.level_ptr.last().unwrap()
    }

    /// Row range of level `i` in the permuted matrix.
    #[inline]
    pub fn rows(&self, i: usize) -> std::ops::Range<usize> {
        self.level_ptr[i]..self.level_ptr[i + 1]
    }

    pub fn level_size(&self, i: usize) -> usize {
        self.level_ptr[i + 1] - self.level_ptr[i]
    }

    /// Level index of a permuted row (binary search).
    pub fn level_of_row(&self, row: usize) -> usize {
        match self.level_ptr.binary_search(&row) {
            Ok(i) => {
                // row == level_ptr[i]; empty levels share the same ptr value,
                // pick the first level that actually contains the row.
                let mut i = i;
                while self.level_ptr[i + 1] == row {
                    i += 1;
                }
                i
            }
            Err(i) => i - 1,
        }
    }

    /// Verify the level invariant on the *permuted* matrix `b`:
    /// all columns of rows in level `i` fall in levels `{i-1, i, i+1}`.
    pub fn validate(&self, b: &CsrMatrix) -> Result<(), String> {
        for i in 0..self.n_levels() {
            for r in self.rows(i) {
                for &c in b.row_cols(r) {
                    let lc = self.level_of_row(c as usize);
                    if lc + 1 < i || lc > i + 1 {
                        return Err(format!(
                            "row {r} (level {i}) references column {c} (level {lc})"
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Matrix bytes (CRS accounting) held by the level range `[lo, hi)` of
    /// the permuted matrix — the quantity the cache budget `C` constrains.
    pub fn bytes_of_levels(&self, b: &CsrMatrix, lo: usize, hi: usize) -> usize {
        let rows = self.level_ptr[hi] - self.level_ptr[lo];
        let nnz = b.rowptr[self.level_ptr[hi]] - b.rowptr[self.level_ptr[lo]];
        crate::matrix::crs_bytes(rows, nnz)
    }
}

/// Convenience: compute levels of `a` and return `(permuted_matrix, levels)`
/// — the standard RACE preprocessing step.
pub fn bfs_reorder(a: &CsrMatrix, root: usize) -> (CsrMatrix, Levels) {
    let levels = Levels::compute(a, root);
    let b = a.permute_symmetric(&levels.perm);
    (b, levels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    #[test]
    fn levels_partition_rows() {
        let a = gen::stencil_2d_5pt(7, 6);
        let (b, lv) = bfs_reorder(&a, 0);
        assert_eq!(lv.n_rows(), a.n_rows());
        assert_eq!(lv.n_levels(), 7 + 6 - 1);
        lv.validate(&b).unwrap();
        // permutation is a bijection
        let mut seen = vec![false; a.n_rows()];
        for &p in &lv.perm {
            assert!(!seen[p]);
            seen[p] = true;
        }
    }

    #[test]
    fn validate_rejects_wrong_levels() {
        // Matrix with a long-range edge 0 <-> 3: one-row-per-level
        // assignment violates the adjacency invariant (levels 0 and 3).
        let mut coo = crate::matrix::CooMatrix::new(4, 4);
        for i in 0..4 {
            coo.push(i, i, 1.0);
        }
        coo.push(0, 3, 1.0);
        coo.push(3, 0, 1.0);
        let a = coo.to_csr();
        let bad = Levels {
            level_ptr: vec![0, 1, 2, 3, 4],
            perm: (0..4).collect(),
            inv_perm: (0..4).collect(),
        };
        assert!(bad.validate(&a).is_err());
        // correct BFS levels pass
        let (b, lv) = bfs_reorder(&a, 0);
        lv.validate(&b).unwrap();
        // single level is trivially valid
        let one = Levels { level_ptr: vec![0, 4], perm: (0..4).collect(), inv_perm: (0..4).collect() };
        assert!(one.validate(&a).is_ok());
    }

    #[test]
    fn level_of_row_with_empty_levels() {
        let lv = Levels {
            level_ptr: vec![0, 2, 2, 5],
            perm: (0..5).collect(),
            inv_perm: (0..5).collect(),
        };
        assert_eq!(lv.level_of_row(0), 0);
        assert_eq!(lv.level_of_row(1), 0);
        assert_eq!(lv.level_of_row(2), 2); // level 1 is empty
        assert_eq!(lv.level_of_row(4), 2);
    }

    #[test]
    fn bytes_of_levels_sums_crs() {
        let a = gen::stencil_2d_5pt(8, 8);
        let (b, lv) = bfs_reorder(&a, 0);
        let total: usize = (0..lv.n_levels()).map(|i| lv.bytes_of_levels(&b, i, i + 1)).sum();
        assert_eq!(total, b.crs_bytes());
    }

    #[test]
    fn bfs_reorder_reduces_bandwidth_of_shuffled_stencil() {
        // a permuted stencil has terrible bandwidth; BFS reorder restores
        // level-locality
        let a = gen::stencil_2d_5pt(16, 16);
        let mut perm: Vec<usize> = (0..a.n_rows()).collect();
        let mut rng = crate::util::rng::Rng::new(1);
        rng.shuffle(&mut perm);
        let shuffled = a.permute_symmetric(&perm);
        let (b, lv) = bfs_reorder(&shuffled, 0);
        lv.validate(&b).unwrap();
        assert!(b.bandwidth() < shuffled.bandwidth());
    }
}
