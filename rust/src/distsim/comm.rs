//! Halo exchange — the simulated `haloComm` routine (paper Alg. 1/2).
//!
//! Byte-for-byte accounting of what real MPI would move: each (sender,
//! receiver) pair with a non-empty plan is one message of
//! `8 B × plan length`.

use super::RankLocal;

/// Accumulated communication statistics.
#[derive(Clone, Debug, Default)]
pub struct CommStats {
    /// Number of point-to-point messages.
    pub messages: usize,
    /// Total payload bytes.
    pub bytes: usize,
    /// Number of collective exchange rounds (bulk-synchronous steps).
    pub rounds: usize,
    /// Largest single message payload seen (bytes).
    pub max_message_bytes: usize,
    /// Time spent waiting at each round-closing barrier, `wait_ns[r]` for
    /// round `r` (so `len() == rounds`). Real on threaded transports,
    /// all-zero on the sequential simulator (paper §6.1: the sim counts
    /// volume exactly but has no wall-clock wait).
    pub wait_ns: Vec<u64>,
}

/// Deterministic-counter equality: wall-clock `wait_ns` is excluded (it
/// varies run to run on threaded transports), everything else must match —
/// this is what keeps `sim == threads` stat assertions bitwise meaningful.
impl PartialEq for CommStats {
    fn eq(&self, other: &Self) -> bool {
        self.messages == other.messages
            && self.bytes == other.bytes
            && self.rounds == other.rounds
            && self.max_message_bytes == other.max_message_bytes
    }
}

impl CommStats {
    /// Accumulate stats of a *subsequent* run (rounds add up, per-round
    /// waits concatenate). For combining the per-rank stats of one run use
    /// [`merge_rank_stats`], where rounds must agree instead.
    pub fn merge(&mut self, other: &CommStats) {
        self.messages += other.messages;
        self.bytes += other.bytes;
        self.rounds += other.rounds;
        self.max_message_bytes = self.max_message_bytes.max(other.max_message_bytes);
        self.wait_ns.extend_from_slice(&other.wait_ns);
    }

    /// Total barrier wait across all rounds.
    pub fn total_wait_ns(&self) -> u64 {
        self.wait_ns.iter().sum()
    }

    /// Counters accrued since the `before` snapshot of the same endpoint
    /// (the rank pool's per-sweep delta: persistent communicators
    /// accumulate across sweeps).
    pub fn delta_since(&self, before: &CommStats) -> CommStats {
        CommStats {
            messages: self.messages - before.messages,
            bytes: self.bytes - before.bytes,
            rounds: self.rounds - before.rounds,
            // message sizes are plan-determined and identical every sweep,
            // so the cumulative max is the per-sweep max
            max_message_bytes: self.max_message_bytes,
            wait_ns: self.wait_ns[before.wait_ns.len().min(self.wait_ns.len())..].to_vec(),
        }
    }
}

/// Merge the per-rank stats of a single run, deterministically: messages
/// and bytes sum in ascending rank order; the bulk-synchronous `rounds`
/// counter must agree across ranks (a divergence means an executor bug)
/// and is taken once. `max_message_bytes` is the max over ranks; the
/// per-round `wait_ns` sums element-wise (total rank-time blocked at each
/// round's barrier).
pub fn merge_rank_stats(per_rank: &[CommStats]) -> CommStats {
    let rounds = per_rank.first().map_or(0, |s| s.rounds);
    let mut out = CommStats { rounds, wait_ns: vec![0; rounds], ..CommStats::default() };
    for (rank, s) in per_rank.iter().enumerate() {
        assert_eq!(
            s.rounds, rounds,
            "rank {rank} performed {} exchange rounds, rank 0 performed {rounds}",
            s.rounds
        );
        out.messages += s.messages;
        out.bytes += s.bytes;
        out.max_message_bytes = out.max_message_bytes.max(s.max_message_bytes);
        for (r, w) in out.wait_ns.iter_mut().enumerate() {
            *w += s.wait_ns.get(r).copied().unwrap_or(0);
        }
    }
    out
}

/// Execute one bulk-synchronous halo exchange over all ranks: for every
/// rank's recv plan, copy the owner's current values into the halo tail.
///
/// `xs[i]` is rank i's local vector (length `vec_len()`); on return every
/// halo slot holds the owner's value.
pub fn exchange_halo(ranks: &[RankLocal], xs: &mut [Vec<f64>], stats: &mut CommStats) {
    assert_eq!(ranks.len(), xs.len());
    stats.rounds += 1;
    stats.wait_ns.push(0); // sequential: nobody waits
    for i in 0..ranks.len() {
        let nl = ranks[i].n_local();
        // iterate recv plans; pull from the peer's vector
        let plans: Vec<(usize, std::ops::Range<usize>)> =
            ranks[i].recv.iter().map(|rp| (rp.from, rp.slots.clone())).collect();
        for (from, slots) in plans {
            let sp = ranks[from]
                .send
                .iter()
                .find(|s| s.to == i)
                .expect("send plan missing for recv plan");
            debug_assert_eq!(sp.rows.len(), slots.len());
            // "receive" into a staging buffer, then write the halo segment —
            // mirrors MPI recv semantics and keeps the borrow checker happy.
            let payload: Vec<f64> = sp.rows.iter().map(|&r| xs[from][r as usize]).collect();
            xs[i][nl + slots.start..nl + slots.end].copy_from_slice(&payload);
            stats.messages += 1;
            let len = payload.len() * std::mem::size_of::<f64>();
            stats.bytes += len;
            stats.max_message_bytes = stats.max_message_bytes.max(len);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distsim::DistMatrix;
    use crate::matrix::gen;
    use crate::partition::{partition, Method};

    #[test]
    fn merge_rank_stats_sums_and_keeps_rounds() {
        let a = CommStats { messages: 2, bytes: 64, rounds: 3, ..Default::default() };
        let b = CommStats { messages: 1, bytes: 16, rounds: 3, ..Default::default() };
        let m = merge_rank_stats(&[a, b]);
        assert_eq!(m, CommStats { messages: 3, bytes: 80, rounds: 3, ..Default::default() });
        assert_eq!(merge_rank_stats(&[]), CommStats::default());
    }

    #[test]
    #[should_panic(expected = "exchange rounds")]
    fn merge_rank_stats_rejects_diverged_rounds() {
        let a = CommStats { messages: 0, bytes: 0, rounds: 2, ..Default::default() };
        let b = CommStats { messages: 0, bytes: 0, rounds: 3, ..Default::default() };
        merge_rank_stats(&[a, b]);
    }

    #[test]
    fn merge_rank_stats_sums_waits_and_maxes_messages() {
        let a = CommStats {
            messages: 2,
            bytes: 64,
            rounds: 2,
            max_message_bytes: 48,
            wait_ns: vec![10, 20],
        };
        let b = CommStats {
            messages: 1,
            bytes: 16,
            rounds: 2,
            max_message_bytes: 16,
            wait_ns: vec![5, 7],
        };
        let m = merge_rank_stats(&[a.clone(), b.clone()]);
        assert_eq!(m.max_message_bytes, 48, "merged max is the max over ranks");
        assert_eq!(m.wait_ns, vec![15, 27], "per-round waits sum element-wise");
        assert_eq!(m.total_wait_ns(), 42);
        // equality ignores wall-clock waits but not the max
        let mut a2 = a.clone();
        a2.wait_ns = vec![999, 999];
        assert_eq!(a, a2);
        a2.max_message_bytes = 8;
        assert_ne!(a, a2);
        // sequential accumulation concatenates waits
        let mut acc = a.clone();
        acc.merge(&b);
        assert_eq!(acc.rounds, 4);
        assert_eq!(acc.wait_ns, vec![10, 20, 5, 7]);
        assert_eq!(acc.max_message_bytes, 48);
        // per-sweep delta takes the wait tail
        let delta = acc.delta_since(&a);
        assert_eq!(delta.messages, b.messages);
        assert_eq!(delta.rounds, 2);
        assert_eq!(delta.wait_ns, vec![5, 7]);
    }

    #[test]
    fn exchange_fills_halo_with_owner_values() {
        let a = gen::stencil_2d_5pt(6, 6);
        let p = partition(&a, 3, Method::Block);
        let d = DistMatrix::build(&a, &p);
        let x: Vec<f64> = (0..36).map(|i| 100.0 + i as f64).collect();
        let mut xs = d.scatter(&x);
        let mut st = CommStats::default();
        exchange_halo(&d.ranks, &mut xs, &mut st);
        for (r, xv) in d.ranks.iter().zip(&xs) {
            for (s, &g) in r.halo_globals.iter().enumerate() {
                assert_eq!(xv[r.n_local() + s], x[g], "halo slot {s} of rank {}", r.rank);
            }
        }
        assert_eq!(st.rounds, 1);
        // block partition of a grid: each interior cut has 2 neighbors
        assert!(st.messages >= 4);
        let total_halo: usize = d.ranks.iter().map(|r| r.n_halo()).sum();
        assert_eq!(st.bytes, total_halo * 8);
    }

    #[test]
    fn stats_accumulate_over_rounds() {
        let a = gen::tridiag(12);
        let p = partition(&a, 2, Method::Block);
        let d = DistMatrix::build(&a, &p);
        let mut xs = d.scatter(&vec![1.0; 12]);
        let mut st = CommStats::default();
        exchange_halo(&d.ranks, &mut xs, &mut st);
        exchange_halo(&d.ranks, &mut xs, &mut st);
        assert_eq!(st.rounds, 2);
        assert_eq!(st.messages, 4); // 2 per round (1 each direction)
        assert_eq!(st.bytes, 2 * 2 * 8);
    }
}
