//! Halo exchange — the simulated `haloComm` routine (paper Alg. 1/2).
//!
//! Byte-for-byte accounting of what real MPI would move: each (sender,
//! receiver) pair with a non-empty plan is one message of
//! `8 B × plan length`.

use super::RankLocal;

/// Accumulated communication statistics.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommStats {
    /// Number of point-to-point messages.
    pub messages: usize,
    /// Total payload bytes.
    pub bytes: usize,
    /// Number of collective exchange rounds (bulk-synchronous steps).
    pub rounds: usize,
}

impl CommStats {
    /// Accumulate stats of a *subsequent* run (rounds add up). For
    /// combining the per-rank stats of one run use [`merge_rank_stats`],
    /// where rounds must agree instead.
    pub fn merge(&mut self, other: &CommStats) {
        self.messages += other.messages;
        self.bytes += other.bytes;
        self.rounds += other.rounds;
    }
}

/// Merge the per-rank stats of a single run, deterministically: messages
/// and bytes sum in ascending rank order; the bulk-synchronous `rounds`
/// counter must agree across ranks (a divergence means an executor bug)
/// and is taken once.
pub fn merge_rank_stats(per_rank: &[CommStats]) -> CommStats {
    let rounds = per_rank.first().map_or(0, |s| s.rounds);
    let mut out = CommStats { rounds, ..CommStats::default() };
    for (rank, s) in per_rank.iter().enumerate() {
        assert_eq!(
            s.rounds, rounds,
            "rank {rank} performed {} exchange rounds, rank 0 performed {rounds}",
            s.rounds
        );
        out.messages += s.messages;
        out.bytes += s.bytes;
    }
    out
}

/// Execute one bulk-synchronous halo exchange over all ranks: for every
/// rank's recv plan, copy the owner's current values into the halo tail.
///
/// `xs[i]` is rank i's local vector (length `vec_len()`); on return every
/// halo slot holds the owner's value.
pub fn exchange_halo(ranks: &[RankLocal], xs: &mut [Vec<f64>], stats: &mut CommStats) {
    assert_eq!(ranks.len(), xs.len());
    stats.rounds += 1;
    for i in 0..ranks.len() {
        let nl = ranks[i].n_local();
        // iterate recv plans; pull from the peer's vector
        let plans: Vec<(usize, std::ops::Range<usize>)> =
            ranks[i].recv.iter().map(|rp| (rp.from, rp.slots.clone())).collect();
        for (from, slots) in plans {
            let sp = ranks[from]
                .send
                .iter()
                .find(|s| s.to == i)
                .expect("send plan missing for recv plan");
            debug_assert_eq!(sp.rows.len(), slots.len());
            // "receive" into a staging buffer, then write the halo segment —
            // mirrors MPI recv semantics and keeps the borrow checker happy.
            let payload: Vec<f64> = sp.rows.iter().map(|&r| xs[from][r as usize]).collect();
            xs[i][nl + slots.start..nl + slots.end].copy_from_slice(&payload);
            stats.messages += 1;
            stats.bytes += payload.len() * std::mem::size_of::<f64>();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distsim::DistMatrix;
    use crate::matrix::gen;
    use crate::partition::{partition, Method};

    #[test]
    fn merge_rank_stats_sums_and_keeps_rounds() {
        let a = CommStats { messages: 2, bytes: 64, rounds: 3 };
        let b = CommStats { messages: 1, bytes: 16, rounds: 3 };
        let m = merge_rank_stats(&[a, b]);
        assert_eq!(m, CommStats { messages: 3, bytes: 80, rounds: 3 });
        assert_eq!(merge_rank_stats(&[]), CommStats::default());
    }

    #[test]
    #[should_panic(expected = "exchange rounds")]
    fn merge_rank_stats_rejects_diverged_rounds() {
        let a = CommStats { messages: 0, bytes: 0, rounds: 2 };
        let b = CommStats { messages: 0, bytes: 0, rounds: 3 };
        merge_rank_stats(&[a, b]);
    }

    #[test]
    fn exchange_fills_halo_with_owner_values() {
        let a = gen::stencil_2d_5pt(6, 6);
        let p = partition(&a, 3, Method::Block);
        let d = DistMatrix::build(&a, &p);
        let x: Vec<f64> = (0..36).map(|i| 100.0 + i as f64).collect();
        let mut xs = d.scatter(&x);
        let mut st = CommStats::default();
        exchange_halo(&d.ranks, &mut xs, &mut st);
        for (r, xv) in d.ranks.iter().zip(&xs) {
            for (s, &g) in r.halo_globals.iter().enumerate() {
                assert_eq!(xv[r.n_local() + s], x[g], "halo slot {s} of rank {}", r.rank);
            }
        }
        assert_eq!(st.rounds, 1);
        // block partition of a grid: each interior cut has 2 neighbors
        assert!(st.messages >= 4);
        let total_halo: usize = d.ranks.iter().map(|r| r.n_halo()).sum();
        assert_eq!(st.bytes, total_halo * 8);
    }

    #[test]
    fn stats_accumulate_over_rounds() {
        let a = gen::tridiag(12);
        let p = partition(&a, 2, Method::Block);
        let d = DistMatrix::build(&a, &p);
        let mut xs = d.scatter(&vec![1.0; 12]);
        let mut st = CommStats::default();
        exchange_halo(&d.ranks, &mut xs, &mut st);
        exchange_halo(&d.ranks, &mut xs, &mut st);
        assert_eq!(st.rounds, 2);
        assert_eq!(st.messages, 4); // 2 per round (1 each direction)
        assert_eq!(st.bytes, 2 * 2 * 8);
    }
}
