//! Construction of the distributed matrix from a global matrix + partition.

use crate::matrix::CsrMatrix;
use crate::partition::Partition;

use super::{RankLocal, RecvPlan, SendPlan};

/// The distributed matrix: every rank's local block plus bookkeeping to
/// reassemble global vectors (validation) and drive halo exchanges.
#[derive(Clone, Debug)]
pub struct DistMatrix {
    pub ranks: Vec<RankLocal>,
    pub n_global: usize,
    /// `owner_of[global_row]` = rank.
    pub owner_of: Vec<u32>,
    /// `local_of[global_row]` = local row index on its owner.
    pub local_of: Vec<u32>,
}

impl DistMatrix {
    /// Partition `a` row-wise according to `part` and build all rank-local
    /// structures (local blocks, halo maps, send/recv plans).
    pub fn build(a: &CsrMatrix, part: &Partition) -> Self {
        assert_eq!(a.n_rows, a.n_cols, "distributed MPK needs a square matrix");
        part.validate(a.n_rows()).expect("invalid partition");
        let n = a.n_rows();
        let np = part.n_parts;

        // owner / local index of every global row
        let mut owner_of = vec![0u32; n];
        let mut local_of = vec![0u32; n];
        let mut counters = vec![0u32; np];
        for r in 0..n {
            let p = part.part_of[r] as usize;
            owner_of[r] = p as u32;
            local_of[r] = counters[p];
            counters[p] += 1;
        }

        let mut ranks = Vec::with_capacity(np);
        for p in 0..np {
            let owned: Vec<usize> = (0..n).filter(|&r| part.part_of[r] == p as u32).collect();
            let nl = owned.len();

            // halo: distinct remote columns, sorted by (owner, global id)
            let mut halo: Vec<usize> = {
                let mut set = std::collections::HashSet::new();
                for &r in &owned {
                    for &c in a.row_cols(r) {
                        let c = c as usize;
                        if owner_of[c] != p as u32 {
                            set.insert(c);
                        }
                    }
                }
                set.into_iter().collect()
            };
            halo.sort_unstable_by_key(|&g| (owner_of[g], g));

            // slot index per halo global
            let slot_of: std::collections::HashMap<usize, u32> =
                halo.iter().enumerate().map(|(s, &g)| (g, s as u32)).collect();

            // local block with local column indexing
            let mut rowptr = Vec::with_capacity(nl + 1);
            rowptr.push(0usize);
            let mut colidx = Vec::new();
            let mut values = Vec::new();
            let mut scratch: Vec<(u32, f64)> = Vec::new();
            for &r in &owned {
                scratch.clear();
                for k in a.rowptr[r]..a.rowptr[r + 1] {
                    let c = a.colidx[k] as usize;
                    let lc = if owner_of[c] == p as u32 {
                        local_of[c]
                    } else {
                        nl as u32 + slot_of[&c]
                    };
                    scratch.push((lc, a.values[k]));
                }
                scratch.sort_unstable_by_key(|&(c, _)| c);
                for &(c, v) in &scratch {
                    colidx.push(c);
                    values.push(v);
                }
                rowptr.push(colidx.len());
            }
            let local =
                CsrMatrix::new(nl, nl + halo.len(), rowptr, colidx, values);

            // recv plans: contiguous owner segments of the sorted halo
            let mut recv = Vec::new();
            let mut s = 0usize;
            while s < halo.len() {
                let from = owner_of[halo[s]] as usize;
                let mut e = s;
                while e < halo.len() && owner_of[halo[e]] as usize == from {
                    e += 1;
                }
                recv.push(RecvPlan { from, slots: s..e });
                s = e;
            }

            ranks.push(RankLocal {
                rank: p,
                owned,
                a: local,
                halo_globals: halo,
                send: Vec::new(), // filled below
                recv,
            });
        }

        // send plans: mirror of every recv plan
        for p in 0..np {
            let requests: Vec<(usize, Vec<usize>)> = ranks[p]
                .recv
                .iter()
                .map(|rp| (rp.from, ranks[p].halo_globals[rp.slots.clone()].to_vec()))
                .collect();
            for (from, globals) in requests {
                let rows: Vec<u32> = globals.iter().map(|&g| local_of[g]).collect();
                ranks[from].send.push(SendPlan { to: p, rows });
            }
        }
        for r in &mut ranks {
            r.send.sort_by_key(|s| s.to);
        }

        DistMatrix { ranks, n_global: n, owner_of, local_of }
    }

    pub fn n_ranks(&self) -> usize {
        self.ranks.len()
    }

    /// Σ_i N_{h,i} — total halo elements (numerator of paper Eq. 1).
    pub fn total_halo(&self) -> usize {
        self.ranks.iter().map(|r| r.n_halo()).sum()
    }

    /// Paper Eq. (1): `O_MPI = Σ_i N_{h,i} / N_r`.
    pub fn mpi_overhead(&self) -> f64 {
        self.total_halo() as f64 / self.n_global as f64
    }

    /// Scatter a global vector into per-rank local vectors (halo zeroed).
    pub fn scatter(&self, x: &[f64]) -> Vec<Vec<f64>> {
        assert_eq!(x.len(), self.n_global);
        self.ranks
            .iter()
            .map(|r| {
                let mut v = r.new_vec();
                for (l, &g) in r.owned.iter().enumerate() {
                    v[l] = x[g];
                }
                v
            })
            .collect()
    }

    /// Gather per-rank local vectors back into a global vector (halo
    /// tails ignored).
    pub fn gather(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        let mut out = vec![0.0; self.n_global];
        for (r, x) in self.ranks.iter().zip(xs) {
            for (l, &g) in r.owned.iter().enumerate() {
                out[g] = x[l];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::partition::{partition, Method};

    fn dist(nx: usize, np: usize) -> (CsrMatrix, DistMatrix) {
        let a = gen::stencil_2d_5pt(nx, nx);
        let p = partition(&a, np, Method::Block);
        let d = DistMatrix::build(&a, &p);
        (a, d)
    }

    #[test]
    fn local_blocks_cover_all_nnz() {
        let (a, d) = dist(12, 3);
        let total: usize = d.ranks.iter().map(|r| r.a.nnz()).sum();
        assert_eq!(total, a.nnz());
        let rows: usize = d.ranks.iter().map(|r| r.n_local()).sum();
        assert_eq!(rows, a.n_rows());
    }

    #[test]
    fn halo_slots_sorted_and_recv_contiguous() {
        let (_, d) = dist(12, 4);
        for r in &d.ranks {
            // sorted by (owner, gid)
            for w in r.halo_globals.windows(2) {
                let (a, b) = (w[0], w[1]);
                assert!((d.owner_of[a], a) < (d.owner_of[b], b));
            }
            // recv plans tile the halo exactly
            let mut next = 0usize;
            for rp in &r.recv {
                assert_eq!(rp.slots.start, next);
                next = rp.slots.end;
                assert_ne!(rp.from, r.rank, "self-recv is forbidden");
            }
            assert_eq!(next, r.n_halo());
        }
    }

    #[test]
    fn send_mirrors_recv() {
        let (_, d) = dist(10, 3);
        for r in &d.ranks {
            for rp in &r.recv {
                let peer = &d.ranks[rp.from];
                let sp = peer.send.iter().find(|s| s.to == r.rank).unwrap();
                assert_eq!(sp.rows.len(), rp.slots.len());
                // the globals match slot-for-slot
                for (i, slot) in rp.slots.clone().enumerate() {
                    let g = r.halo_globals[slot];
                    assert_eq!(peer.owned[sp.rows[i] as usize], g);
                }
            }
        }
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let (a, d) = dist(9, 2);
        let x: Vec<f64> = (0..a.n_rows()).map(|i| i as f64).collect();
        let xs = d.scatter(&x);
        assert_eq!(d.gather(&xs), x);
    }

    #[test]
    fn boundary_rows_touch_halo() {
        let (_, d) = dist(8, 2);
        for r in &d.ranks {
            let b = r.boundary_rows();
            assert!(!b.is_empty());
            for &row in &b {
                assert!(r
                    .a
                    .row_cols(row as usize)
                    .iter()
                    .any(|&c| c as usize >= r.n_local()));
            }
        }
    }

    #[test]
    fn permute_local_preserves_spmv() {
        let (a, d) = dist(8, 2);
        let mut d2 = d.clone();
        // reverse local rows on rank 0
        let nl = d2.ranks[0].n_local();
        let perm: Vec<usize> = (0..nl).rev().collect();
        d2.ranks[0].permute_local(&perm);
        // same global SpMV result
        let x: Vec<f64> = (0..a.n_rows()).map(|i| (i as f64).sin()).collect();
        let mut want = vec![0.0; a.n_rows()];
        a.spmv(&x, &mut want);
        for d in [&d, &d2] {
            let mut xs = d.scatter(&x);
            let mut stats = crate::distsim::CommStats::default();
            crate::distsim::exchange_halo(&d.ranks, &mut xs, &mut stats);
            let ys: Vec<Vec<f64>> = d
                .ranks
                .iter()
                .zip(&xs)
                .map(|(r, x)| {
                    let mut y = r.new_vec();
                    r.a.spmv(x, &mut y);
                    y
                })
                .collect();
            let got = d.gather(&ys);
            for (u, v) in got.iter().zip(&want) {
                assert!((u - v).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn mpi_overhead_small_for_block_partition() {
        let (_, d) = dist(32, 4);
        // block partition of a 32x32 grid: halo = 2 boundary lines per cut
        let o = d.mpi_overhead();
        assert!(o > 0.0 && o < 0.25, "O_MPI = {o}");
    }
}
