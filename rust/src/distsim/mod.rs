//! Simulated-MPI distributed runtime (DESIGN.md §Substitutions).
//!
//! Ranks are explicit state machines inside one process. Everything the
//! paper *counts* — halo elements, message sizes, redundant work — is exact;
//! multi-rank wall-clock estimates combine measured per-rank compute time
//! with an α-β communication cost model ([`costmodel`]).
//!
//! The data layout mirrors a textbook distributed CRS code (paper §4,
//! Fig. 3): each rank owns a contiguous-in-partition set of rows, stores its
//! local block with *local* column indexing, and keeps remote x-elements in
//! a halo tail appended to its local vectors. Halo slots are grouped by
//! owner rank (ascending global id within an owner) so each receive is one
//! contiguous segment — the standard MPI bulk-transfer layout.
//!
//! # Executors
//!
//! This module defines the *data* side of the distributed runtime (rank
//! locals, halo plans, byte accounting); [`crate::exec`] defines the
//! *execution* side. Two executors run the MPK kernels over these plans:
//!
//! * **Sim** — the original sequential lockstep loop, now expressed as
//!   per-rank [`crate::exec::SimComm`] endpoints advanced round-by-round.
//!   All counting (`CommStats`, halo bytes, rounds) is exact and
//!   bit-identical to the original [`exchange_halo`] accounting; wall-clock
//!   is single-threaded and multi-rank timings come from the α-β model.
//! * **Threads** — one OS thread per rank with real channel messages
//!   ([`crate::exec::ThreadComm`]); wall-clock is *measured*, and DLB's
//!   remainder-round sends genuinely overlap its cache-blocked wavefront.
//!
//! [`exchange_halo`] remains as the direct all-ranks primitive for tests
//! and micro-benchmarks; [`merge_rank_stats`] combines per-rank stats
//! deterministically (asserting the ranks agree on the round count).

pub mod build;
pub mod comm;
pub mod costmodel;

pub use build::DistMatrix;
pub use comm::{exchange_halo, merge_rank_stats, CommStats};
pub use costmodel::CommCostModel;

/// Per-destination send plan: local row indices whose values this rank
/// must ship to `to` before each SpMV.
#[derive(Clone, Debug)]
pub struct SendPlan {
    pub to: usize,
    /// Local row indices (into this rank's vectors).
    pub rows: Vec<u32>,
}

/// Per-source receive plan: the contiguous halo-slot segment filled by
/// rank `from`.
#[derive(Clone, Debug)]
pub struct RecvPlan {
    pub from: usize,
    /// Halo slot range, offsets relative to `n_local`.
    pub slots: std::ops::Range<usize>,
}

/// One rank's share of the distributed matrix.
#[derive(Clone, Debug)]
pub struct RankLocal {
    pub rank: usize,
    /// Global ids of owned rows, ascending; local row `r` is `owned[r]`.
    pub owned: Vec<usize>,
    /// Local block: `n_local` rows, `n_local + n_halo` columns.
    /// Columns `< n_local` are owned rows (same order as `owned`);
    /// columns `>= n_local` are halo slots.
    pub a: crate::matrix::CsrMatrix,
    /// Global id of each halo slot (index 0 = local column `n_local`).
    pub halo_globals: Vec<usize>,
    pub send: Vec<SendPlan>,
    pub recv: Vec<RecvPlan>,
}

impl RankLocal {
    pub fn n_local(&self) -> usize {
        self.owned.len()
    }

    pub fn n_halo(&self) -> usize {
        self.halo_globals.len()
    }

    /// Vector length for this rank: owned + halo tail (paper's
    /// `N_{r,i} + N_{h,i}`).
    pub fn vec_len(&self) -> usize {
        self.n_local() + self.n_halo()
    }

    /// Allocate a zeroed local vector (with halo tail).
    pub fn new_vec(&self) -> Vec<f64> {
        vec![0.0; self.vec_len()]
    }

    /// Apply a permutation to the *local* rows (halo slots are unaffected):
    /// `perm[new] = old`. Rewrites the local block, `owned`, and send plans.
    /// Used by DLB-MPK to make distance classes contiguous (paper §5:
    /// "gathering these boundary vertices and reordering the matrix during
    /// preprocessing").
    pub fn permute_local(&mut self, perm: &[usize]) {
        let nl = self.n_local();
        assert_eq!(perm.len(), nl);
        let mut inv = vec![0usize; nl];
        for (new, &old) in perm.iter().enumerate() {
            inv[old] = new;
        }
        // rows in new order, local columns remapped, halo columns unchanged
        let mut rowptr = Vec::with_capacity(nl + 1);
        rowptr.push(0usize);
        let mut colidx = Vec::with_capacity(self.a.nnz());
        let mut values = Vec::with_capacity(self.a.nnz());
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        for new_r in 0..nl {
            let old_r = perm[new_r];
            scratch.clear();
            for k in self.a.rowptr[old_r]..self.a.rowptr[old_r + 1] {
                let c = self.a.colidx[k] as usize;
                let nc = if c < nl { inv[c] } else { c };
                scratch.push((nc as u32, self.a.values[k]));
            }
            scratch.sort_unstable_by_key(|&(c, _)| c);
            for &(c, v) in &scratch {
                colidx.push(c);
                values.push(v);
            }
            rowptr.push(colidx.len());
        }
        self.a = crate::matrix::CsrMatrix::new(nl, self.a.n_cols, rowptr, colidx, values);
        self.owned = perm.iter().map(|&old| self.owned[old]).collect();
        for sp in &mut self.send {
            for r in &mut sp.rows {
                *r = inv[*r as usize] as u32;
            }
        }
    }

    /// Local vertices adjacent to the halo — the boundary sources for the
    /// distance classification (the paper's distance-1 set w.r.t. `B`).
    pub fn boundary_rows(&self) -> Vec<u32> {
        let nl = self.n_local();
        let mut out = Vec::new();
        for r in 0..nl {
            if self.a.row_cols(r).iter().any(|&c| c as usize >= nl) {
                out.push(r as u32);
            }
        }
        out
    }
}
