//! α-β communication cost model for multi-rank wall-clock estimates.
//!
//! This testbed has one physical core, so multi-rank timings cannot be
//! measured directly; the simulation runs ranks sequentially, measures each
//! rank's compute time, and combines `max_i(T_compute,i)` with a modeled
//! communication time per bulk-synchronous round:
//!
//!   T_round = α · (messages on critical path) + (bytes on critical path)/β
//!
//! Defaults are calibrated to typical HPC interconnects (the paper's
//! Intel MPI on HDR Infiniband): α ≈ 1.5 µs intra-node / 2.5 µs inter-node,
//! β ≈ 16 GB/s intra / 12 GB/s inter per rank pair.

#[derive(Clone, Copy, Debug)]
pub struct CommCostModel {
    /// Per-message latency, seconds.
    pub alpha: f64,
    /// Bandwidth, bytes/second.
    pub beta: f64,
    /// Ranks per node: messages between ranks in the same node use
    /// `intra_alpha`/`intra_beta` instead.
    pub ranks_per_node: usize,
    pub intra_alpha: f64,
    pub intra_beta: f64,
}

impl Default for CommCostModel {
    fn default() -> Self {
        Self {
            alpha: 2.5e-6,
            beta: 12.0e9,
            ranks_per_node: 4,
            intra_alpha: 1.5e-6,
            intra_beta: 16.0e9,
        }
    }
}

impl CommCostModel {
    /// Time for one message of `bytes` between `from` and `to`.
    pub fn message_time(&self, from: usize, to: usize, bytes: usize) -> f64 {
        let same_node = from / self.ranks_per_node == to / self.ranks_per_node;
        if same_node {
            self.intra_alpha + bytes as f64 / self.intra_beta
        } else {
            self.alpha + bytes as f64 / self.beta
        }
    }

    /// Critical-path time of one bulk-synchronous exchange round: the
    /// busiest rank's serialized send+recv cost (a conservative but standard
    /// BSP estimate).
    ///
    /// `traffic[i]` = list of (peer, bytes) for rank i's receives.
    pub fn round_time(&self, traffic: &[Vec<(usize, usize)>]) -> f64 {
        let n = traffic.len();
        let mut per_rank = vec![0.0f64; n];
        for (i, recvs) in traffic.iter().enumerate() {
            for &(peer, bytes) in recvs {
                let t = self.message_time(peer, i, bytes);
                per_rank[i] += t; // recv side
                per_rank[peer] += t; // send side
            }
        }
        per_rank.into_iter().fold(0.0, f64::max)
    }
}

/// Build the per-round traffic table of a distributed matrix (what one
/// `exchange_halo` moves).
pub fn halo_traffic(ranks: &[crate::distsim::RankLocal]) -> Vec<Vec<(usize, usize)>> {
    ranks
        .iter()
        .map(|r| {
            r.recv
                .iter()
                .map(|rp| (rp.from, rp.slots.len() * std::mem::size_of::<f64>()))
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intra_node_cheaper_than_inter() {
        let m = CommCostModel::default();
        assert!(m.message_time(0, 1, 4096) < m.message_time(0, 7, 4096));
    }

    #[test]
    fn round_time_is_critical_path() {
        let m = CommCostModel::default();
        // rank 1 receives from 0 and 2; rank 3 idle
        let traffic = vec![vec![], vec![(0, 8000), (2, 8000)], vec![], vec![]];
        let t = m.round_time(&traffic);
        let expect = 2.0 * m.message_time(0, 1, 8000);
        assert!((t - expect).abs() < 1e-12);
    }

    #[test]
    fn zero_traffic_is_free() {
        let m = CommCostModel::default();
        assert_eq!(m.round_time(&[vec![], vec![]]), 0.0);
    }
}
