//! Chebyshev time propagation of quantum states (paper §7, Eq. 5–7).
//!
//! Solves `|ψ(τ+δτ)⟩ = e^{−iδτ·H}|ψ(τ)⟩` by the Chebyshev expansion
//!
//!   `e^{−iδτH} ≈ e^{−iδτ·b}·[ J_0(z)·v_0 + 2 Σ_k (−i)^k J_k(z)·v_k ]`
//!
//! with `H` rescaled to spectral radius ≤ 1 (`H_s = (H − b)/a`, `z = a·δτ`),
//! `v_{k+1} = 2 H_s v_k − v_{k−1}` (Eq. 6). The recurrence is a sequence of
//! `M` SpMVs with the *same* matrix — exactly the shape DLB-MPK accelerates:
//! the propagator blocks the recurrence in chunks of `p_m` steps and runs
//! each chunk through the cache-blocked distributed wavefront.
//!
//! The complex state is carried as two real planes (`H` is real), so one
//! recurrence step is two SpMVs — matching the fused `cheb_step` AOT
//! artifact on the XLA path.

use crate::distsim::{CommStats, DistMatrix};
use crate::matrix::CsrMatrix;
use crate::mpk::dlb::{self, DlbOptions, DlbPlan, Recurrence, Workspace};
use crate::mpk::trad::trad_recurrence;
use crate::mpk::SpmvBackend;

use super::bessel::{bessel_j_array, chebyshev_terms};

/// Which MPK engine drives the recurrence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Back-to-back SpMVs (the paper's baseline TRAD implementation).
    Trad,
    /// Cache-blocked DLB-MPK (the paper's contribution).
    Dlb,
}

#[derive(Clone, Copy, Debug)]
pub struct ChebyshevConfig {
    /// Physical time step δτ.
    pub dt: f64,
    /// Recurrence block size p_m (paper §7: p_m « M, tuned like Fig. 8).
    pub p_m: usize,
    pub engine: Engine,
    pub dlb: DlbOptions,
}

impl Default for ChebyshevConfig {
    fn default() -> Self {
        Self { dt: 0.5, p_m: 8, engine: Engine::Dlb, dlb: DlbOptions::default() }
    }
}

/// Complex state as two real planes.
#[derive(Clone, Debug)]
pub struct State {
    pub re: Vec<f64>,
    pub im: Vec<f64>,
}

impl State {
    pub fn zeros(n: usize) -> Self {
        Self { re: vec![0.0; n], im: vec![0.0; n] }
    }

    pub fn norm2(&self) -> f64 {
        self.re.iter().map(|v| v * v).sum::<f64>() + self.im.iter().map(|v| v * v).sum::<f64>()
    }

    pub fn normalize(&mut self) {
        let n = self.norm2().sqrt();
        if n > 0.0 {
            for v in self.re.iter_mut().chain(self.im.iter_mut()) {
                *v /= n;
            }
        }
    }

    /// |⟨r|ψ⟩|² density.
    pub fn density(&self) -> Vec<f64> {
        self.re
            .iter()
            .zip(&self.im)
            .map(|(r, i)| r * r + i * i)
            .collect()
    }
}

/// The propagator: holds the rescaled Hamiltonian, the DLB plan, and the
/// expansion coefficients.
pub struct ChebyshevPropagator {
    pub cfg: ChebyshevConfig,
    /// Spectral scale `a` (H_s = (H − b)/a; b = 0 for the Anderson model's
    /// symmetric spectrum).
    pub scale_a: f64,
    /// Number of expansion terms M.
    pub n_terms: usize,
    /// `J_k(a·δτ)` for k = 0..=M.
    pub coeffs: Vec<f64>,
    plan: DlbPlan,
    dist_trad: DistMatrix,
    ws: Workspace,
    pub comm: CommStats,
}

impl ChebyshevPropagator {
    /// Build from the (unscaled) Hamiltonian distributed over `dist`.
    ///
    /// `h` is consumed conceptually: the propagator re-scales a copy of the
    /// distributed blocks by `1/a` with `a = ‖H‖_∞` (Gershgorin bound).
    pub fn new(h: &CsrMatrix, dist: &DistMatrix, cfg: ChebyshevConfig) -> Self {
        let a = h.inf_norm().max(f64::MIN_POSITIVE);
        // scale local blocks
        let mut dist = dist.clone();
        for r in &mut dist.ranks {
            r.a.scale(1.0 / a);
        }
        let z = a * cfg.dt;
        let n_terms = chebyshev_terms(z).max(cfg.p_m + 1);
        let coeffs = bessel_j_array(n_terms, z);
        let plan = dlb::plan(&dist, cfg.p_m, &cfg.dlb);
        Self {
            cfg,
            scale_a: a,
            n_terms,
            coeffs,
            dist_trad: dist,
            plan,
            ws: Workspace::default(),
            comm: CommStats::default(),
        }
    }

    /// One δτ step: ψ ← e^{−iδτH_s·a} ψ (global phase e^{−iδτ·b} omitted;
    /// b = 0 here, and a global phase is unobservable anyway).
    pub fn step(&mut self, psi: &State, backend: &mut dyn SpmvBackend) -> State {
        let n = psi.re.len();
        let mut out = State::zeros(n);
        // k = 0 term: J_0 · v_0
        axpy(&mut out.re, self.coeffs[0], &psi.re);
        axpy(&mut out.im, self.coeffs[0], &psi.im);

        // v_{k-1}, v_k window, per plane
        let mut v_prev = psi.clone(); // v_0
        let mut v_cur: Option<State> = None; // v_1 after first block
        let mut k_done = 0usize; // highest k accumulated

        while k_done < self.n_terms {
            let p_m = self.cfg.p_m.min(self.n_terms - k_done);
            // run p_m recurrence steps from (v_{k_done-1}=?, v_{k_done})
            let (x0_re, x0_im, xm1_re, xm1_im): (&[f64], &[f64], Option<&[f64]>, Option<&[f64]>) =
                match &v_cur {
                    None => (&psi.re, &psi.im, None, None), // wind-up: v1 = H v0
                    Some(vc) => (&vc.re, &vc.im, Some(&v_prev.re), Some(&v_prev.im)),
                };
            let (res_re, res_im) = match self.cfg.engine {
                Engine::Dlb => {
                    // plans with p_m smaller than configured: rebuild cheaply
                    let plan: &DlbPlan = if p_m == self.cfg.p_m {
                        &self.plan
                    } else {
                        // tail block (rare): build a small temporary plan
                        &dlb::plan(&self.plan.dist, p_m, &self.cfg.dlb)
                    };
                    let rr = dlb::execute_recurrence_with(
                        plan, x0_re, xm1_re, Recurrence::Chebyshev, backend, &mut self.ws,
                    );
                    let ri = dlb::execute_recurrence_with(
                        plan, x0_im, xm1_im, Recurrence::Chebyshev, backend, &mut self.ws,
                    );
                    (rr, ri)
                }
                Engine::Trad => {
                    let rr = trad_recurrence(
                        &self.dist_trad, x0_re, xm1_re, p_m, Recurrence::Chebyshev, backend,
                    );
                    let ri = trad_recurrence(
                        &self.dist_trad, x0_im, xm1_im, p_m, Recurrence::Chebyshev, backend,
                    );
                    (rr, ri)
                }
            };
            self.comm.merge(&res_re.comm);
            self.comm.merge(&res_im.comm);

            // accumulate 2·(−i)^k·J_k·v_k for k = k_done+1 ..= k_done+p_m
            for (j, (vr, vi)) in res_re.powers.iter().zip(&res_im.powers).enumerate() {
                let k = k_done + j + 1;
                let c = 2.0 * self.coeffs[k];
                match k % 4 {
                    0 => {
                        // (−i)^k = 1
                        axpy(&mut out.re, c, vr);
                        axpy(&mut out.im, c, vi);
                    }
                    1 => {
                        // (−i)^k = −i : (−i)(r + i·m) = m − i·r
                        axpy(&mut out.re, c, vi);
                        axpy(&mut out.im, -c, vr);
                    }
                    2 => {
                        axpy(&mut out.re, -c, vr);
                        axpy(&mut out.im, -c, vi);
                    }
                    _ => {
                        axpy(&mut out.re, -c, vi);
                        axpy(&mut out.im, c, vr);
                    }
                }
            }

            // roll the window: v_prev = v_{k_done+p_m-1}, v_cur = v_{k_done+p_m}
            let m = res_re.powers.len();
            v_prev = if m >= 2 {
                State { re: res_re.powers[m - 2].clone(), im: res_im.powers[m - 2].clone() }
            } else {
                match &v_cur {
                    None => psi.clone(),
                    Some(vc) => vc.clone(),
                }
            };
            v_cur = Some(State {
                re: res_re.powers[m - 1].clone(),
                im: res_im.powers[m - 1].clone(),
            });
            k_done += m;
        }
        out
    }

    /// Propagate `steps` time steps.
    pub fn propagate(&mut self, psi: &State, steps: usize, backend: &mut dyn SpmvBackend) -> State {
        let mut cur = psi.clone();
        for _ in 0..steps {
            cur = self.step(&cur, backend);
        }
        cur
    }
}

#[inline]
fn axpy(y: &mut [f64], a: f64, x: &[f64]) {
    for (yv, xv) in y.iter_mut().zip(x) {
        *yv += a * xv;
    }
}

/// Gaussian wave packet (paper Eq. 9) on an Anderson lattice.
pub fn wave_packet(cfg: &crate::matrix::anderson::AndersonConfig, sigma: f64, k0: [f64; 3]) -> State {
    let n = cfg.n_sites();
    let (cx, cy, cz) = (cfg.lx as f64 / 2.0, cfg.ly as f64 / 2.0, cfg.lz as f64 / 2.0);
    let mut st = State::zeros(n);
    for z in 0..cfg.lz {
        for y in 0..cfg.ly {
            for x in 0..cfg.lx {
                let r = cfg.site(x, y, z);
                let (dx, dy, dz) = (x as f64 - cx, y as f64 - cy, z as f64 - cz);
                let r2 = dx * dx + dy * dy + dz * dz;
                let amp = (-r2 / (2.0 * sigma * sigma)).exp();
                let phase = k0[0] * dx + k0[1] * dy + k0[2] * dz;
                st.re[r] = amp * phase.cos();
                st.im[r] = amp * phase.sin();
            }
        }
    }
    st.normalize();
    st
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::anderson::{anderson, AndersonConfig};
    use crate::matrix::gen;
    use crate::mpk::NativeBackend;
    use crate::partition::{partition, Method};

    fn propagate(engine: Engine, np: usize, steps: usize) -> (State, State) {
        let cfg = AndersonConfig::isotropic(8, 1.0, 11);
        let h = anderson(&cfg);
        let part = partition(&h, np, Method::Block);
        let dist = DistMatrix::build(&h, &part);
        let ccfg = ChebyshevConfig {
            dt: 0.4,
            p_m: 4,
            engine,
            dlb: DlbOptions { cache_bytes: 64 << 10, s_m: 50 },
        };
        let mut prop = ChebyshevPropagator::new(&h, &dist, ccfg);
        let psi0 = wave_packet(&cfg, 2.0, [std::f64::consts::FRAC_PI_2, 0.0, 0.0]);
        let psi = prop.propagate(&psi0, steps, &mut NativeBackend);
        (psi0, psi)
    }

    #[test]
    fn unitarity_norm_conserved() {
        let (psi0, psi) = propagate(Engine::Dlb, 2, 3);
        assert!((psi0.norm2() - 1.0).abs() < 1e-12);
        assert!((psi.norm2() - 1.0).abs() < 1e-9, "norm² = {}", psi.norm2());
    }

    #[test]
    fn dlb_and_trad_engines_agree() {
        let (_, a) = propagate(Engine::Dlb, 3, 2);
        let (_, b) = propagate(Engine::Trad, 3, 2);
        for (u, v) in a.re.iter().zip(&b.re).chain(a.im.iter().zip(&b.im)) {
            assert!((u - v).abs() < 1e-10, "{u} vs {v}");
        }
    }

    #[test]
    fn free_particle_1d_exact() {
        // 1D chain without disorder: H = -t Σ|r⟩⟨r+1| + h.c. has exact
        // dispersion; check e^{-iδτH} against dense matrix exponential via
        // repeated squaring of the series... cheaper: check energy
        // conservation ⟨H⟩ and Chebyshev self-consistency over two half steps.
        let cfg = AndersonConfig { lx: 32, ly: 1, lz: 1, w: 0.0, t: 1.0, t_perp: 0.0, seed: 1 };
        let h = anderson(&cfg);
        let part = partition(&h, 1, Method::Block);
        let dist = DistMatrix::build(&h, &part);
        let psi0 = wave_packet(&cfg, 3.0, [1.0, 0.0, 0.0]);

        // one full step vs two half steps must agree (semigroup property)
        let mk = |dt: f64| ChebyshevConfig { dt, p_m: 3, engine: Engine::Dlb, dlb: DlbOptions { cache_bytes: 1 << 20, s_m: 50 } };
        let mut full = ChebyshevPropagator::new(&h, &dist, mk(0.6));
        let mut half = ChebyshevPropagator::new(&h, &dist, mk(0.3));
        let a = full.propagate(&psi0, 1, &mut NativeBackend);
        let b = half.propagate(&psi0, 2, &mut NativeBackend);
        for (u, v) in a.re.iter().zip(&b.re).chain(a.im.iter().zip(&b.im)) {
            assert!((u - v).abs() < 1e-9, "{u} vs {v}");
        }
    }

    #[test]
    fn stationary_state_only_gains_phase() {
        // single site (n=1): H = [w], e^{-i dt H} psi has |psi| unchanged
        // and the density of ANY eigenstate is stationary; use a 2-site
        // hopping dimer's symmetric state
        let mut coo = crate::matrix::CooMatrix::new(2, 2);
        coo.push(0, 1, -1.0);
        coo.push(1, 0, -1.0);
        let h = coo.to_csr();
        let part = partition(&h, 1, Method::Block);
        let dist = DistMatrix::build(&h, &part);
        let mut prop = ChebyshevPropagator::new(
            &h,
            &dist,
            ChebyshevConfig { dt: 0.7, p_m: 2, engine: Engine::Trad, dlb: DlbOptions::default() },
        );
        let s = 1.0 / 2.0f64.sqrt();
        let psi = State { re: vec![s, s], im: vec![0.0, 0.0] };
        let out = prop.step(&psi, &mut NativeBackend);
        let d = out.density();
        assert!((d[0] - 0.5).abs() < 1e-10 && (d[1] - 0.5).abs() < 1e-10);
        // eigenvalue −1: phase e^{+i·0.7}
        let want_re = s * 0.7f64.cos();
        let want_im = s * 0.7f64.sin();
        assert!((out.re[0] - want_re).abs() < 1e-10);
        assert!((out.im[0] - want_im).abs() < 1e-10);
    }

    #[test]
    fn wave_packet_is_normalized_and_centered() {
        let cfg = AndersonConfig::isotropic(16, 1.0, 2);
        let st = wave_packet(&cfg, 3.0, [0.0, 0.0, 0.0]);
        assert!((st.norm2() - 1.0).abs() < 1e-12);
        let rho = st.density();
        let c = cfg.site(8, 8, 8);
        let m = rho.iter().cloned().fold(0.0, f64::max);
        assert_eq!(rho[c], m);
        let _ = gen::tridiag(2); // keep import used
    }
}
