//! Chebyshev time propagation of quantum states (paper §7, Eq. 5–7).
//!
//! Solves `|ψ(τ+δτ)⟩ = e^{−iδτ·H}|ψ(τ)⟩` by the Chebyshev expansion
//!
//!   `e^{−iδτH} ≈ e^{−iδτ·b}·[ J_0(z)·v_0 + 2 Σ_k (−i)^k J_k(z)·v_k ]`
//!
//! with `H` rescaled to spectral radius ≤ 1 (`H_s = (H − b)/a`, `z = a·δτ`),
//! `v_{k+1} = 2 H_s v_k − v_{k−1}` (Eq. 6). The recurrence is a sequence of
//! `M` SpMVs with the *same* matrix — exactly the shape
//! [`crate::engine::MpkEngine`] amortizes: the propagator builds one engine
//! at construction (plan, workspaces, and — under the threads executor —
//! the persistent rank pool), then blocks the recurrence in chunks of `p_m`
//! steps and drives each chunk through [`MpkEngine::sweep_len`]. Tail
//! blocks (`M` not a multiple of `p_m`) hit the engine's plan cache, so
//! thousands of time steps construct exactly two plans.
//!
//! The complex state is carried as two real planes (`H` is real), so one
//! recurrence step is two sweeps — matching the fused `cheb_step` AOT
//! artifact on the XLA path.

use crate::distsim::{CommStats, DistMatrix};
use crate::engine::{EngineConfig, MpkEngine, Variant};
use crate::matrix::CsrMatrix;
use crate::mpk::dlb::Recurrence;

use super::bessel::{bessel_j_array, chebyshev_terms};

#[derive(Clone, Debug)]
pub struct ChebyshevConfig {
    /// Physical time step δτ.
    pub dt: f64,
    /// Recurrence block size p_m (paper §7: p_m « M, tuned like Fig. 8).
    pub p_m: usize,
    /// Which MPK variant/executor/backend drives the recurrence.
    pub engine: EngineConfig,
}

impl Default for ChebyshevConfig {
    fn default() -> Self {
        Self { dt: 0.5, p_m: 8, engine: EngineConfig::default() }
    }
}

/// Complex state as two real planes.
#[derive(Clone, Debug)]
pub struct State {
    pub re: Vec<f64>,
    pub im: Vec<f64>,
}

impl State {
    pub fn zeros(n: usize) -> Self {
        Self { re: vec![0.0; n], im: vec![0.0; n] }
    }

    pub fn norm2(&self) -> f64 {
        self.re.iter().map(|v| v * v).sum::<f64>() + self.im.iter().map(|v| v * v).sum::<f64>()
    }

    pub fn normalize(&mut self) {
        let n = self.norm2().sqrt();
        if n > 0.0 {
            for v in self.re.iter_mut().chain(self.im.iter_mut()) {
                *v /= n;
            }
        }
    }

    /// |⟨r|ψ⟩|² density.
    pub fn density(&self) -> Vec<f64> {
        self.re
            .iter()
            .zip(&self.im)
            .map(|(r, i)| r * r + i * i)
            .collect()
    }
}

/// The propagator: holds the prepared [`MpkEngine`] over the rescaled
/// Hamiltonian plus the expansion coefficients.
pub struct ChebyshevPropagator {
    pub cfg: ChebyshevConfig,
    /// Spectral scale `a` (H_s = (H − b)/a; b = 0 for the Anderson model's
    /// symmetric spectrum).
    pub scale_a: f64,
    /// Number of expansion terms M.
    pub n_terms: usize,
    /// `J_k(a·δτ)` for k = 0..=M.
    pub coeffs: Vec<f64>,
    engine: MpkEngine,
    pub comm: CommStats,
}

impl ChebyshevPropagator {
    /// Build from the (unscaled) Hamiltonian distributed over `dist`.
    ///
    /// `h` is consumed conceptually: the propagator re-scales a copy of the
    /// distributed blocks by `1/a` with `a = ‖H‖_∞` (Gershgorin bound) and
    /// prepares the engine (plans, workspaces, rank pool) once.
    pub fn new(h: &CsrMatrix, dist: &DistMatrix, cfg: ChebyshevConfig) -> anyhow::Result<Self> {
        anyhow::ensure!(
            !matches!(cfg.engine.variant, Variant::Ca),
            "ChebyshevPropagator runs a three-term recurrence; the CA variant \
             supports only plain powers — use Variant::Trad or Variant::Dlb"
        );
        let a = h.inf_norm().max(f64::MIN_POSITIVE);
        // scale local blocks
        let mut dist = dist.clone();
        for r in &mut dist.ranks {
            r.a.scale(1.0 / a);
        }
        let z = a * cfg.dt;
        let n_terms = chebyshev_terms(z).max(cfg.p_m + 1);
        let coeffs = bessel_j_array(n_terms, z);
        // hand our scaled clone to the engine outright (from_config would
        // deep-clone it again for the TRAD variant)
        let engine = MpkEngine::from_shared(std::sync::Arc::new(dist), cfg.p_m, &cfg.engine)?;
        Ok(Self {
            cfg,
            scale_a: a,
            n_terms,
            coeffs,
            engine,
            comm: CommStats::default(),
        })
    }

    /// The underlying prepared session (plan cache, pool counters).
    pub fn engine(&self) -> &MpkEngine {
        &self.engine
    }

    /// Mutable session access (trace export, host backend products).
    pub fn engine_mut(&mut self) -> &mut MpkEngine {
        &mut self.engine
    }

    /// One δτ step: ψ ← e^{−iδτH_s·a} ψ (global phase e^{−iδτ·b} omitted;
    /// b = 0 here, and a global phase is unobservable anyway).
    pub fn step(&mut self, psi: &State) -> State {
        let n = psi.re.len();
        let mut out = State::zeros(n);
        // k = 0 term: J_0 · v_0
        axpy(&mut out.re, self.coeffs[0], &psi.re);
        axpy(&mut out.im, self.coeffs[0], &psi.im);

        // v_{k-1}, v_k window, per plane
        let mut v_prev = psi.clone(); // v_0
        let mut v_cur: Option<State> = None; // v_1 after first block
        let mut k_done = 0usize; // highest k accumulated

        while k_done < self.n_terms {
            let p_m = self.cfg.p_m.min(self.n_terms - k_done);
            // run p_m recurrence steps from (v_{k_done-1}=?, v_{k_done})
            let (x0_re, x0_im, xm1_re, xm1_im): (&[f64], &[f64], Option<&[f64]>, Option<&[f64]>) =
                match &v_cur {
                    None => (&psi.re, &psi.im, None, None), // wind-up: v1 = H v0
                    Some(vc) => (&vc.re, &vc.im, Some(&v_prev.re), Some(&v_prev.im)),
                };
            // tail blocks (p_m < planned) reuse the engine's cached plans
            let res_re = self.engine.sweep_len(p_m, x0_re, xm1_re, Recurrence::Chebyshev);
            let res_im = self.engine.sweep_len(p_m, x0_im, xm1_im, Recurrence::Chebyshev);
            self.comm.merge(&res_re.comm);
            self.comm.merge(&res_im.comm);

            // accumulate 2·(−i)^k·J_k·v_k for k = k_done+1 ..= k_done+p_m
            for (j, (vr, vi)) in res_re.powers.iter().zip(&res_im.powers).enumerate() {
                let k = k_done + j + 1;
                let c = 2.0 * self.coeffs[k];
                match k % 4 {
                    0 => {
                        // (−i)^k = 1
                        axpy(&mut out.re, c, vr);
                        axpy(&mut out.im, c, vi);
                    }
                    1 => {
                        // (−i)^k = −i : (−i)(r + i·m) = m − i·r
                        axpy(&mut out.re, c, vi);
                        axpy(&mut out.im, -c, vr);
                    }
                    2 => {
                        axpy(&mut out.re, -c, vr);
                        axpy(&mut out.im, -c, vi);
                    }
                    _ => {
                        axpy(&mut out.re, -c, vi);
                        axpy(&mut out.im, c, vr);
                    }
                }
            }

            // roll the window: v_prev = v_{k_done+p_m-1}, v_cur = v_{k_done+p_m}
            let m = res_re.powers.len();
            v_prev = if m >= 2 {
                State { re: res_re.powers[m - 2].clone(), im: res_im.powers[m - 2].clone() }
            } else {
                match &v_cur {
                    None => psi.clone(),
                    Some(vc) => vc.clone(),
                }
            };
            v_cur = Some(State {
                re: res_re.powers[m - 1].clone(),
                im: res_im.powers[m - 1].clone(),
            });
            k_done += m;
        }
        out
    }

    /// Propagate `steps` time steps.
    pub fn propagate(&mut self, psi: &State, steps: usize) -> State {
        let mut cur = psi.clone();
        for _ in 0..steps {
            cur = self.step(&cur);
        }
        cur
    }
}

#[inline]
fn axpy(y: &mut [f64], a: f64, x: &[f64]) {
    for (yv, xv) in y.iter_mut().zip(x) {
        *yv += a * xv;
    }
}

/// Gaussian wave packet (paper Eq. 9) on an Anderson lattice.
pub fn wave_packet(cfg: &crate::matrix::anderson::AndersonConfig, sigma: f64, k0: [f64; 3]) -> State {
    let n = cfg.n_sites();
    let (cx, cy, cz) = (cfg.lx as f64 / 2.0, cfg.ly as f64 / 2.0, cfg.lz as f64 / 2.0);
    let mut st = State::zeros(n);
    for z in 0..cfg.lz {
        for y in 0..cfg.ly {
            for x in 0..cfg.lx {
                let r = cfg.site(x, y, z);
                let (dx, dy, dz) = (x as f64 - cx, y as f64 - cy, z as f64 - cz);
                let r2 = dx * dx + dy * dy + dz * dz;
                let amp = (-r2 / (2.0 * sigma * sigma)).exp();
                let phase = k0[0] * dx + k0[1] * dy + k0[2] * dz;
                st.re[r] = amp * phase.cos();
                st.im[r] = amp * phase.sin();
            }
        }
    }
    st.normalize();
    st
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::anderson::{anderson, AndersonConfig};
    use crate::matrix::gen;
    use crate::mpk::dlb::DlbOptions;
    use crate::partition::{partition, Method};

    fn engine_cfg(variant: Variant) -> EngineConfig {
        EngineConfig { variant, ..EngineConfig::default() }
    }

    fn propagate(variant: Variant, np: usize, steps: usize) -> (State, State) {
        let cfg = AndersonConfig::isotropic(8, 1.0, 11);
        let h = anderson(&cfg);
        let part = partition(&h, np, Method::Block);
        let dist = DistMatrix::build(&h, &part);
        let ccfg = ChebyshevConfig { dt: 0.4, p_m: 4, engine: engine_cfg(variant) };
        let mut prop = ChebyshevPropagator::new(&h, &dist, ccfg).unwrap();
        let psi0 = wave_packet(&cfg, 2.0, [std::f64::consts::FRAC_PI_2, 0.0, 0.0]);
        let psi = prop.propagate(&psi0, steps);
        (psi0, psi)
    }

    fn dlb_small() -> Variant {
        Variant::Dlb(DlbOptions { cache_bytes: 64 << 10, s_m: 50, async_remainder: false })
    }

    #[test]
    fn unitarity_norm_conserved() {
        let (psi0, psi) = propagate(dlb_small(), 2, 3);
        assert!((psi0.norm2() - 1.0).abs() < 1e-12);
        assert!((psi.norm2() - 1.0).abs() < 1e-9, "norm² = {}", psi.norm2());
    }

    #[test]
    fn ca_variant_rejected_at_build() {
        let cfg = AndersonConfig::isotropic(4, 1.0, 1);
        let h = anderson(&cfg);
        let part = partition(&h, 1, Method::Block);
        let dist = DistMatrix::build(&h, &part);
        let ccfg = ChebyshevConfig { dt: 0.4, p_m: 2, engine: engine_cfg(Variant::Ca) };
        assert!(
            ChebyshevPropagator::new(&h, &dist, ccfg).is_err(),
            "CA cannot drive the Chebyshev recurrence and must fail at build"
        );
    }

    #[test]
    fn dlb_and_trad_variants_agree() {
        let (_, a) = propagate(dlb_small(), 3, 2);
        let (_, b) = propagate(Variant::Trad, 3, 2);
        for (u, v) in a.re.iter().zip(&b.re).chain(a.im.iter().zip(&b.im)) {
            assert!((u - v).abs() < 1e-10, "{u} vs {v}");
        }
    }

    #[test]
    fn tail_plans_cached_across_steps() {
        // n_terms not a multiple of p_m: every step runs full blocks plus
        // one tail block. The engine must build exactly two plans (primary
        // + tail) no matter how many steps run.
        let cfg = AndersonConfig::isotropic(6, 1.0, 3);
        let h = anderson(&cfg);
        let part = partition(&h, 2, Method::Block);
        let dist = DistMatrix::build(&h, &part);
        let ccfg = ChebyshevConfig {
            dt: 0.4,
            p_m: 4,
            engine: engine_cfg(Variant::Dlb(DlbOptions { cache_bytes: 32 << 10, s_m: 50, async_remainder: false })),
        };
        let mut prop = ChebyshevPropagator::new(&h, &dist, ccfg).unwrap();
        let tail = prop.n_terms % prop.cfg.p_m;
        let psi0 = wave_packet(&cfg, 2.0, [0.3, 0.0, 0.0]);
        let _ = prop.propagate(&psi0, 3);
        let want_plans = if tail == 0 { 1 } else { 2 };
        assert_eq!(
            prop.engine().plans_built(),
            want_plans,
            "tail plans must be cached, not rebuilt per step (n_terms = {}, p_m = {})",
            prop.n_terms,
            prop.cfg.p_m
        );
        // every block of every plane of every step went through the engine
        let blocks_per_plane = prop.n_terms.div_ceil(prop.cfg.p_m);
        assert_eq!(prop.engine().sweeps_run(), 3 * 2 * blocks_per_plane);
    }

    #[test]
    fn free_particle_1d_exact() {
        // 1D chain without disorder: H = -t Σ|r⟩⟨r+1| + h.c. has exact
        // dispersion; check e^{-iδτH} against dense matrix exponential via
        // repeated squaring of the series... cheaper: check energy
        // conservation ⟨H⟩ and Chebyshev self-consistency over two half steps.
        let cfg = AndersonConfig { lx: 32, ly: 1, lz: 1, w: 0.0, t: 1.0, t_perp: 0.0, seed: 1 };
        let h = anderson(&cfg);
        let part = partition(&h, 1, Method::Block);
        let dist = DistMatrix::build(&h, &part);
        let psi0 = wave_packet(&cfg, 3.0, [1.0, 0.0, 0.0]);

        // one full step vs two half steps must agree (semigroup property)
        let mk = |dt: f64| ChebyshevConfig {
            dt,
            p_m: 3,
            engine: engine_cfg(Variant::Dlb(DlbOptions { cache_bytes: 1 << 20, s_m: 50, async_remainder: false })),
        };
        let mut full = ChebyshevPropagator::new(&h, &dist, mk(0.6)).unwrap();
        let mut half = ChebyshevPropagator::new(&h, &dist, mk(0.3)).unwrap();
        let a = full.propagate(&psi0, 1);
        let b = half.propagate(&psi0, 2);
        for (u, v) in a.re.iter().zip(&b.re).chain(a.im.iter().zip(&b.im)) {
            assert!((u - v).abs() < 1e-9, "{u} vs {v}");
        }
    }

    #[test]
    fn stationary_state_only_gains_phase() {
        // single site (n=1): H = [w], e^{-i dt H} psi has |psi| unchanged
        // and the density of ANY eigenstate is stationary; use a 2-site
        // hopping dimer's symmetric state
        let mut coo = crate::matrix::CooMatrix::new(2, 2);
        coo.push(0, 1, -1.0);
        coo.push(1, 0, -1.0);
        let h = coo.to_csr();
        let part = partition(&h, 1, Method::Block);
        let dist = DistMatrix::build(&h, &part);
        let mut prop = ChebyshevPropagator::new(
            &h,
            &dist,
            ChebyshevConfig { dt: 0.7, p_m: 2, engine: engine_cfg(Variant::Trad) },
        )
        .unwrap();
        let s = 1.0 / 2.0f64.sqrt();
        let psi = State { re: vec![s, s], im: vec![0.0, 0.0] };
        let out = prop.step(&psi);
        let d = out.density();
        assert!((d[0] - 0.5).abs() < 1e-10 && (d[1] - 0.5).abs() < 1e-10);
        // eigenvalue −1: phase e^{+i·0.7}
        let want_re = s * 0.7f64.cos();
        let want_im = s * 0.7f64.sin();
        assert!((out.re[0] - want_re).abs() < 1e-10);
        assert!((out.im[0] - want_im).abs() < 1e-10);
    }

    #[test]
    fn wave_packet_is_normalized_and_centered() {
        let cfg = AndersonConfig::isotropic(16, 1.0, 2);
        let st = wave_packet(&cfg, 3.0, [0.0, 0.0, 0.0]);
        assert!((st.norm2() - 1.0).abs() < 1e-12);
        let rho = st.density();
        let c = cfg.site(8, 8, 8);
        let m = rho.iter().cloned().fold(0.0, f64::max);
        assert_eq!(rho[c], m);
        let _ = gen::tridiag(2); // keep import used
    }
}
