//! Applications built on the DLB-MPK library.

pub mod bessel;
pub mod chebyshev;
pub mod poly_cg;
pub mod observables;
