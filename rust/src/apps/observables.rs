//! Observables for the Anderson localization study (paper §7, Fig. 11).

use crate::matrix::anderson::AndersonConfig;

/// Center of mass ⟨x⟩, ⟨y⟩, ⟨z⟩ of a density, relative to the box center.
pub fn center_of_mass(cfg: &AndersonConfig, rho: &[f64]) -> [f64; 3] {
    let (cx, cy, cz) = (cfg.lx as f64 / 2.0, cfg.ly as f64 / 2.0, cfg.lz as f64 / 2.0);
    let mut m = 0.0;
    let mut s = [0.0f64; 3];
    for z in 0..cfg.lz {
        for y in 0..cfg.ly {
            for x in 0..cfg.lx {
                let w = rho[cfg.site(x, y, z)];
                m += w;
                s[0] += w * (x as f64 - cx);
                s[1] += w * (y as f64 - cy);
                s[2] += w * (z as f64 - cz);
            }
        }
    }
    if m > 0.0 {
        for v in &mut s {
            *v /= m;
        }
    }
    s
}

/// Marginal density along x: ρ(x) = Σ_{y,z} ρ(r) (Fig. 11a's heat-map rows).
pub fn density_profile_x(cfg: &AndersonConfig, rho: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; cfg.lx];
    for z in 0..cfg.lz {
        for y in 0..cfg.ly {
            for x in 0..cfg.lx {
                out[x] += rho[cfg.site(x, y, z)];
            }
        }
    }
    out
}

/// Participation ratio 1/Σρ² — localization measure (≈ number of occupied
/// sites; small when localized).
pub fn participation_ratio(rho: &[f64]) -> f64 {
    let s2: f64 = rho.iter().map(|v| v * v).sum();
    if s2 > 0.0 {
        1.0 / s2
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn com_of_point_mass() {
        let cfg = AndersonConfig::isotropic(4, 0.0, 0);
        let mut rho = vec![0.0; 64];
        rho[cfg.site(3, 1, 0)] = 1.0;
        let c = center_of_mass(&cfg, &rho);
        assert_eq!(c, [1.0, -1.0, -2.0]);
    }

    #[test]
    fn profile_sums_to_norm() {
        let cfg = AndersonConfig::isotropic(4, 0.0, 0);
        let rho = vec![1.0 / 64.0; 64];
        let p = density_profile_x(&cfg, &rho);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p.iter().all(|&v| (v - 0.25 / 4.0 * 4.0 * 0.25).abs() < 1.0));
    }

    #[test]
    fn participation_ratio_extremes() {
        let uniform = vec![0.01; 100];
        assert!((participation_ratio(&uniform) - 100.0).abs() < 1e-9);
        let mut point = vec![0.0; 100];
        point[3] = 1.0;
        assert!((participation_ratio(&point) - 1.0).abs() < 1e-12);
    }
}
