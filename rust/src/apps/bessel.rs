//! Bessel functions of the first kind `J_k(x)` — the Chebyshev expansion
//! coefficients (paper Eq. 5).
//!
//! Miller's downward recurrence with the standard normalization
//! `J_0 + 2·Σ_{k even} J_k = 1`; accurate to ~1e-14 for the argument range
//! the propagator uses (`x = a·δτ`, typically ≤ 50).

/// `J_k(x)` for `k = 0..=k_max`.
pub fn bessel_j_array(k_max: usize, x: f64) -> Vec<f64> {
    let mut out = vec![0.0; k_max + 1];
    if x == 0.0 {
        out[0] = 1.0;
        return out;
    }
    let ax = x.abs();
    // start far above k_max and above the turning point |x|
    let start = k_max + 16 + (ax as usize) + ((40.0 * (k_max as f64 + ax)).sqrt() as usize);

    let mut jp = 0.0f64; // J_{k+1}
    let mut jc = 1e-30f64; // J_k, initially k = start
    let mut norm = 0.0f64; // J_0 + 2 Σ_{even k > 0} J_k

    let record = |k: usize, val: f64, out: &mut [f64], norm: &mut f64| {
        if k <= k_max {
            out[k] = val;
        }
        if k == 0 {
            *norm += val;
        } else if k % 2 == 0 {
            *norm += 2.0 * val;
        }
    };
    record(start, jc, &mut out, &mut norm);

    for k in (1..=start).rev() {
        // J_{k-1} = (2k/x) J_k − J_{k+1}
        let jm = (2.0 * k as f64 / ax) * jc - jp;
        jp = jc;
        jc = jm;
        record(k - 1, jc, &mut out, &mut norm);
        if jc.abs() > 1e250 {
            jp *= 1e-250;
            jc *= 1e-250;
            norm *= 1e-250;
            for v in out.iter_mut() {
                *v *= 1e-250;
            }
        }
    }
    for v in out.iter_mut() {
        *v /= norm;
    }
    if x < 0.0 {
        for (k, v) in out.iter_mut().enumerate() {
            if k % 2 == 1 {
                *v = -*v;
            }
        }
    }
    out
}

/// Number of Chebyshev terms for argument `z` to reach ~1e-15 truncation:
/// `J_k(z)` decays super-exponentially past `k ≈ z`.
pub fn chebyshev_terms(z: f64) -> usize {
    let z = z.abs();
    (z + 20.0 + 10.0 * z.cbrt()).ceil() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Series definition for small arguments (reference).
    fn j_series(k: usize, x: f64) -> f64 {
        let mut term = (x / 2.0f64).powi(k as i32)
            / (1..=k).map(|i| i as f64).product::<f64>().max(1.0);
        let mut sum = term;
        for m in 1..60 {
            term *= -(x * x / 4.0) / (m as f64 * (m as f64 + k as f64));
            sum += term;
        }
        sum
    }

    #[test]
    fn matches_series_small_x() {
        let js = bessel_j_array(10, 1.5);
        for k in 0..=10 {
            let want = j_series(k, 1.5);
            assert!(
                (js[k] - want).abs() < 1e-12,
                "J_{k}(1.5): {} vs {want}",
                js[k]
            );
        }
    }

    #[test]
    fn known_values() {
        // Abramowitz & Stegun: J_0(1) = 0.7651976866, J_1(1) = 0.4400505857
        let js = bessel_j_array(4, 1.0);
        assert!((js[0] - 0.7651976865579666).abs() < 1e-12);
        assert!((js[1] - 0.4400505857449335).abs() < 1e-12);
        // J_0(5) = -0.1775967713
        let j5 = bessel_j_array(2, 5.0);
        assert!((j5[0] + 0.17759677131433830).abs() < 1e-11);
    }

    #[test]
    fn negative_argument_parity() {
        let jp = bessel_j_array(5, 2.0);
        let jn = bessel_j_array(5, -2.0);
        for k in 0..=5 {
            let want = if k % 2 == 1 { -jp[k] } else { jp[k] };
            assert!((jn[k] - want).abs() < 1e-13);
        }
    }

    #[test]
    fn zero_argument() {
        let js = bessel_j_array(3, 0.0);
        assert_eq!(js, vec![1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn truncation_estimate_covers_decay() {
        for &z in &[0.5, 2.0, 10.0, 40.0] {
            let m = chebyshev_terms(z);
            let js = bessel_j_array(m, z);
            assert!(js[m].abs() < 1e-13, "J_{m}({z}) = {}", js[m]);
        }
    }
}
