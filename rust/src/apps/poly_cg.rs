//! Conjugate Gradient with a matrix-polynomial (Chebyshev) preconditioner —
//! the solver class the paper's introduction motivates (Demmel et al. 2008
//! CA-Krylov; Loe et al. 2020 polynomial-preconditioned GMRES in Trilinos).
//!
//! The preconditioner application `z = q(A) r` is a fixed sequence of
//! back-to-back SpMVs with the same matrix — exactly an MPK — so the
//! preconditioner owns a prepared [`crate::engine::MpkEngine`] and runs
//! one sweep `y_p = T_p(Â) r` per apply, where `T_p` are Chebyshev
//! polynomials matched to the spectral interval `[λ_min, λ_max]` (the
//! classical Chebyshev preconditioner, e.g. Saad, *Iterative Methods*,
//! §12.3). Every knob — DLB vs TRAD variant, sim vs threads executor,
//! SpMV backend — comes from the engine config, and the CG loop's own
//! `A·p` product runs through the same engine backend so the *whole*
//! solver honors one configuration.

use crate::distsim::DistMatrix;
use crate::engine::{EngineConfig, MpkEngine};
use crate::mpk::dlb::Recurrence;
use crate::mpk::SpmvBackend;

/// Chebyshev polynomial preconditioner of degree `degree` on `[lmin, lmax]`.
pub struct ChebyshevPreconditioner {
    /// Coefficients of the residual-polynomial expansion in Chebyshev basis
    /// of the *scaled* operator (see [`Self::new`]).
    theta: f64,
    delta: f64,
    pub degree: usize,
    engine: MpkEngine,
}

impl ChebyshevPreconditioner {
    /// `dist` must hold the SPD matrix `A`; `[lmin, lmax]` bracket its
    /// spectrum (Gershgorin bounds work: `lmax = ‖A‖_∞`, `lmin` small > 0).
    pub fn new(
        dist: &DistMatrix,
        lmin: f64,
        lmax: f64,
        degree: usize,
        engine: &EngineConfig,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(degree >= 1 && lmax > lmin && lmin > 0.0, "need 0 < lmin < lmax, degree >= 1");
        let engine = MpkEngine::from_config(dist, degree, engine)?;
        Ok(Self {
            theta: 0.5 * (lmax + lmin),
            delta: 0.5 * (lmax - lmin),
            degree,
            engine,
        })
    }

    /// The underlying prepared session (plan cache, pool counters).
    pub fn engine(&self) -> &MpkEngine {
        &self.engine
    }

    /// The engine's host backend — used by [`pcg`] for the CG loop's own
    /// `A·p` product so the full solver honors the configured backend.
    pub fn backend(&mut self) -> &mut dyn SpmvBackend {
        self.engine.backend()
    }

    /// Apply `z ≈ A⁻¹ r` via the degree-`m` Chebyshev iteration, implemented
    /// as one MPK-style engine sweep (all SpMVs cache-blocked under the DLB
    /// variant).
    ///
    /// Uses the standard Chebyshev semi-iteration: `z_m` is the m-th
    /// Chebyshev-accelerated Richardson iterate for `A z = r`, `z_0 = 0`.
    pub fn apply(&mut self, r: &[f64]) -> Vec<f64> {
        // Chebyshev semi-iteration needs A·z_k each step. z_k evolves, so we
        // express it through the shifted recurrence on the residual basis:
        // run the MPK recurrence y_p = A y_{p-1} on r (one engine sweep),
        // then combine the Krylov vectors with the Chebyshev-iteration
        // weights — mathematically identical to the textbook loop, but all
        // matrix touches happen inside one prepared sweep.
        let powers = self.engine.sweep(r, None, Recurrence::Power).powers;

        // Build q(A) r from the monomial Krylov basis {r, Ar, ..., A^m r}.
        // The textbook Chebyshev iteration (Saad, Alg. 12.1; z_0 = 0):
        //   σ1 = θ/δ, ρ_0 = 1/σ1, d_0 = r/θ, z_1 = d_0
        //   ρ_k = 1/(2σ1 − ρ_{k−1})
        //   d_k = ρ_k ρ_{k−1} d_{k−1} + (2ρ_k/δ)(r − A z_k)
        //   z_{k+1} = z_k + d_k
        // run here on *polynomial coefficients* in λ (length m+1): applying
        // the resulting z_m(A) to r is identical to the vector loop, but all
        // A-multiplies happened in the single engine sweep above.
        let m = self.degree;
        let sigma1 = self.theta / self.delta;
        let mut rho_prev = 1.0 / sigma1;
        let mut d = vec![0.0f64; m + 1];
        d[0] = 1.0 / self.theta;
        let mut z = d.clone();
        for _k in 1..m {
            let rho = 1.0 / (2.0 * sigma1 - rho_prev);
            // res(λ) = 1 − λ·z(λ)
            let mut res = vec![0.0f64; m + 1];
            res[0] = 1.0;
            for j in 0..m {
                res[j + 1] -= z[j];
            }
            for j in 0..=m {
                d[j] = rho * rho_prev * d[j] + (2.0 * rho / self.delta) * res[j];
            }
            for j in 0..=m {
                z[j] += d[j];
            }
            rho_prev = rho;
        }

        // z(λ) = Σ_j z[j] λ^j ; powers[j-1] = A^j r, A^0 r = r
        let n = r.len();
        let mut out = vec![0.0; n];
        for i in 0..n {
            out[i] = z[0] * r[i];
        }
        for (j, pw) in powers.iter().enumerate() {
            let c = z[j + 1];
            if c != 0.0 {
                for i in 0..n {
                    out[i] += c * pw[i];
                }
            }
        }
        out
    }
}

/// Preconditioned CG. Returns (solution, iterations, final residual norm).
///
/// The matrix-vector product `A·p` of the CG loop itself runs through the
/// preconditioner engine's backend, so the entire solver — sweeps and
/// ancillary SpMVs alike — honors the configured `BackendSpec`.
pub fn pcg(
    a_global: &crate::matrix::CsrMatrix,
    b: &[f64],
    precond: &mut ChebyshevPreconditioner,
    tol: f64,
    max_iter: usize,
) -> (Vec<f64>, usize, f64) {
    let n = b.len();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut z = precond.apply(&r);
    let mut p = z.clone();
    let mut rz = dot(&r, &z);
    let b_norm = dot(b, b).sqrt().max(f64::MIN_POSITIVE);
    let mut ap = vec![0.0; n];
    for it in 0..max_iter {
        precond.backend().spmv_range(a_global, 0, n, &p, &mut ap);
        let alpha = rz / dot(&p, &ap);
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rn = dot(&r, &r).sqrt();
        if rn / b_norm < tol {
            return (x, it + 1, rn);
        }
        z = precond.apply(&r);
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
    }
    let rn = dot(&r, &r).sqrt();
    (x, max_iter, rn)
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Variant;
    use crate::matrix::gen;
    use crate::mpk::dlb::DlbOptions;
    use crate::partition::{partition, Method};

    fn setup(n: usize) -> (crate::matrix::CsrMatrix, DistMatrix, f64) {
        let a = gen::stencil_2d_5pt(n, n); // SPD
        let part = partition(&a, 2, Method::Block);
        let d = DistMatrix::build(&a, &part);
        // exact λ_min of the 2D 5-pt Laplacian (must bracket the spectrum)
        let lmin = 8.0 * (std::f64::consts::PI / (2.0 * (n as f64 + 1.0))).sin().powi(2);
        (a, d, lmin)
    }

    fn dlb_cfg(cache_bytes: usize) -> EngineConfig {
        EngineConfig {
            variant: Variant::Dlb(DlbOptions { cache_bytes, s_m: 50, async_remainder: false }),
            ..EngineConfig::default()
        }
    }

    #[test]
    fn pcg_converges_on_laplacian() {
        let (a, d, lmin) = setup(24);
        let b = vec![1.0; a.n_rows()];
        let lmax = a.inf_norm();
        let mut pre =
            ChebyshevPreconditioner::new(&d, lmin, lmax, 6, &dlb_cfg(1 << 20)).unwrap();
        let (x, iters, rn) = pcg(&a, &b, &mut pre, 1e-10, 300);
        assert!(rn / (b.len() as f64).sqrt() < 1e-9, "residual {rn}");
        // verify the solution directly
        let mut ax = vec![0.0; b.len()];
        a.spmv(&x, &mut ax);
        for (u, v) in ax.iter().zip(&b) {
            assert!((u - v).abs() < 1e-7, "{u} vs {v}");
        }
        assert!(iters < 300);
    }

    #[test]
    fn preconditioner_reduces_iterations() {
        let (a, d, lmin) = setup(24);
        let b: Vec<f64> = (0..a.n_rows()).map(|i| ((i % 11) as f64) - 5.0).collect();
        let lmax = a.inf_norm();
        let mut weak = ChebyshevPreconditioner::new(&d, lmin, lmax, 1, &dlb_cfg(1 << 20)).unwrap();
        let mut strong =
            ChebyshevPreconditioner::new(&d, lmin, lmax, 8, &dlb_cfg(1 << 20)).unwrap();
        let (_, it_weak, _) = pcg(&a, &b, &mut weak, 1e-8, 500);
        let (_, it_strong, _) = pcg(&a, &b, &mut strong, 1e-8, 500);
        assert!(
            it_strong < it_weak,
            "degree-8 {it_strong} should beat degree-1 {it_weak}"
        );
    }

    #[test]
    fn dlb_and_trad_preconditioners_agree() {
        let (a, d, lmin) = setup(16);
        let r: Vec<f64> = (0..256).map(|i| (i as f64 * 0.3).sin()).collect();
        let lmax = a.inf_norm();
        let trad_cfg = EngineConfig { variant: Variant::Trad, ..EngineConfig::default() };
        let mut pd = ChebyshevPreconditioner::new(&d, lmin, lmax, 5, &dlb_cfg(8 << 10)).unwrap();
        let mut pt = ChebyshevPreconditioner::new(&d, lmin, lmax, 5, &trad_cfg).unwrap();
        let zd = pd.apply(&r);
        let zt = pt.apply(&r);
        for (u, v) in zd.iter().zip(&zt) {
            assert!((u - v).abs() < 1e-10 * (1.0 + v.abs()));
        }
    }
}
