//! The halo-exchange contract ([`Communicator`]) and its in-process
//! transports: [`SimComm`] (sequential lockstep mailboxes — today's
//! counting simulator) and [`ThreadComm`] (real `std::sync::mpsc`
//! channels, one OS thread per rank). The multi-process socket transport
//! lives in [`super::sock`]; the full transport contract a new
//! implementation must satisfy is written down in `docs/COMMUNICATOR.md`.
//!
//! The trait mirrors the nonblocking MPI set the paper's kernels are
//! written against: `MPI_Isend` ([`Communicator::send`]), a matching
//! tagged receive ([`Communicator::recv`], buffering out-of-order
//! arrivals like an eager-protocol unexpected-message queue), nonblocking
//! completion (`MPI_Test` → [`Communicator::try_recv`], `MPI_Waitany` →
//! [`Communicator::recv_any`]), and a round close
//! ([`Communicator::end_round`], `MPI_Waitall` + barrier;
//! [`Communicator::advance_round`] is the barrier-free variant the async
//! remainder uses on intermediate rounds). On top of the primitives sit
//! provided halo helpers that follow each rank's
//! [`crate::distsim::SendPlan`]/[`crate::distsim::RecvPlan`]:
//! [`Communicator::post_halo_sends`] and [`Communicator::wait_halo`].
//! Kernels that overlap communication with computation (DLB phase 3) call
//! the post/wait halves separately — or, with
//! `DlbOptions::async_remainder`, complete individual peer segments in
//! arrival order via [`Communicator::recv_any`]; bulk-synchronous kernels
//! use [`Communicator::exchange`].
//!
//! ## Accounting
//!
//! Statistics are **per rank** and receiver-side: every received message
//! bumps `messages` once and `bytes` by the payload size, in recv-plan
//! order; every `end_round` bumps `rounds`. Merging rank stats in
//! ascending-rank order ([`crate::distsim::merge_rank_stats`]) therefore
//! reproduces bit-identically the totals of the legacy sequential
//! [`crate::distsim::exchange_halo`] loop, for both transports.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::distsim::{CommStats, RankLocal};
use crate::trace::{RankRecorder, Span};

/// Point-to-point halo communication endpoint of one rank.
///
/// This trait **is** the transport contract: a new implementation that
/// honors the rules below runs every kernel in this crate (TRAD/CA/DLB,
/// inner threads, async remainder) unmodified — see `docs/COMMUNICATOR.md`
/// for the prose version with the MPI correspondences spelled out.
///
/// ## Contract
///
/// 1. **Tag discipline.** Kernels address messages by `(from, tag)` where
///    `tag` is a small per-sweep round number. Within one sweep a given
///    `(from, to, tag)` triple is sent **at most once**, and the sweep's
///    final [`Communicator::end_round`] completes only after every posted
///    message was received — so tags may be reused by the next sweep
///    without ambiguity (transports may assert the no-duplicate rule).
/// 2. **Exactly-once delivery.** Every send is matched by exactly one
///    completed receive of the same `(from, tag)`; arrivals the receiver
///    has not asked for yet are buffered (an eager-protocol
///    unexpected-message queue), never dropped or reordered into a
///    different key.
/// 3. **Nonblocking sends.** [`Communicator::send`] copies the payload out
///    and returns immediately (buffered `MPI_Isend`); it must never wait
///    for the matching receive (kernels post all sends of a round before
///    receiving).
/// 4. **Receiver-side accounting.** Exactly the successful completion of a
///    data receive bumps `messages`/`bytes`/`max_message_bytes` (use
///    `account_recv`); [`Communicator::try_recv`] misses and any
///    transport-internal traffic (barriers, harvests) account nothing.
///    Every round close appends one entry to `wait_ns` and bumps `rounds`.
///    This is what keeps per-rank stats bit-identical across transports.
/// 5. **Deterministic tie-break.** [`Communicator::recv_any`] completes
///    the lowest request index among the already-available messages, so
///    deterministic transports replay identically.
/// 6. **Failure beats deadlock.** If a peer dies mid-run, blocked
///    operations must fail loudly (panic/poison/EOF error) rather than
///    hang — every transport here cascades the failure to all peers.
///
/// ## Minimal transport sketch
///
/// A toy two-rank mailbox transport showing the minimum a conforming
/// implementation provides (`try_recv`/`recv_any`/`advance_round` have
/// safe blocking defaults):
///
/// ```
/// use std::collections::HashMap;
/// use std::sync::{Arc, Mutex};
/// use dlb_mpk::distsim::CommStats;
/// use dlb_mpk::exec::Communicator;
/// use dlb_mpk::trace::RankRecorder;
///
/// /// Mailbox shared by both endpoints, keyed `(from, to, tag)`.
/// type Mailbox = Arc<Mutex<HashMap<(usize, usize, u64), Vec<f64>>>>;
///
/// struct ToyComm {
///     rank: usize,
///     n: usize,
///     mail: Mailbox,
///     stats: CommStats,
///     tracer: RankRecorder,
/// }
///
/// impl Communicator for ToyComm {
///     fn rank(&self) -> usize { self.rank }
///     fn n_ranks(&self) -> usize { self.n }
///     fn tracer(&mut self) -> &mut RankRecorder { &mut self.tracer }
///
///     fn send(&mut self, to: usize, tag: u64, payload: Vec<f64>) {
///         let prev = self.mail.lock().unwrap().insert((self.rank, to, tag), payload);
///         assert!(prev.is_none(), "tag discipline: one send per (from, to, tag)");
///     }
///
///     fn recv(&mut self, from: usize, tag: u64) -> Vec<f64> {
///         // A real transport blocks here; the toy requires the send to
///         // be posted already (like SimComm under a lockstep executor).
///         let p = self.mail.lock().unwrap().remove(&(from, self.rank, tag))
///             .expect("message posted");
///         self.stats.messages += 1; // receiver-side accounting
///         self.stats.bytes += p.len() * 8;
///         self.stats.max_message_bytes = self.stats.max_message_bytes.max(p.len() * 8);
///         p
///     }
///
///     fn end_round(&mut self) {
///         self.stats.rounds += 1;     // a real transport synchronizes ranks here
///         self.stats.wait_ns.push(0); // keep the per-round wait series aligned
///     }
///
///     fn stats(&self) -> &CommStats { &self.stats }
/// }
///
/// let mail = Mailbox::default();
/// let mk = |rank| ToyComm {
///     rank,
///     n: 2,
///     mail: mail.clone(),
///     stats: CommStats::default(),
///     tracer: RankRecorder::disabled(),
/// };
/// let (mut a, mut b) = (mk(0), mk(1));
/// a.send(1, 0, vec![2.5]);
/// assert_eq!(b.recv(0, 0), vec![2.5]);
/// a.end_round();
/// b.end_round();
/// assert_eq!(b.stats().messages, 1);
/// assert_eq!(b.stats().rounds, 1);
/// ```
pub trait Communicator: Send {
    fn rank(&self) -> usize;
    fn n_ranks(&self) -> usize;

    /// This rank's trace recorder — a disabled no-op unless a
    /// [`crate::trace::TraceSession`] attached an enabled one. Transports
    /// record their own `comm.*` spans through it internally; kernels
    /// record their compute spans through the same buffer, so each rank
    /// has exactly one interleaved timeline.
    fn tracer(&mut self) -> &mut RankRecorder;

    /// Nonblocking tagged send (the payload is copied out immediately,
    /// like a buffered `MPI_Isend`).
    fn send(&mut self, to: usize, tag: u64, payload: Vec<f64>);

    /// Blocking tagged receive; arrivals with other tags are buffered.
    fn recv(&mut self, from: usize, tag: u64) -> Vec<f64>;

    /// Nonblocking tagged receive (`MPI_Test` on a posted `Irecv`):
    /// complete `(from, tag)` if it has already arrived, else return
    /// `None` immediately. A miss records a `comm.probe` span; a hit
    /// accounts exactly like [`Communicator::recv`].
    ///
    /// Default: no nonblocking support — always a miss. Callers must
    /// therefore fall back to [`Communicator::recv_any`]/`recv`, which
    /// stay correct (just fully blocking) on such transports.
    fn try_recv(&mut self, _from: usize, _tag: u64) -> Option<Vec<f64>> {
        None
    }

    /// Block until any one of the posted receives `reqs` = `[(from, tag)]`
    /// completes (`MPI_Waitany`); returns `(index into reqs, payload)`.
    /// Ties are broken by lowest request index so deterministic transports
    /// complete in a reproducible order.
    ///
    /// Default: degrade to a blocking receive of `reqs[0]` — correct but
    /// without out-of-order completion.
    fn recv_any(&mut self, reqs: &[(usize, u64)]) -> (usize, Vec<f64>) {
        assert!(!reqs.is_empty(), "recv_any on an empty request set");
        let (from, tag) = reqs[0];
        (0, self.recv(from, tag))
    }

    /// Close one bulk-synchronous exchange round: bumps `rounds` and, on
    /// threaded transports, synchronizes ranks and asserts the round
    /// counters agree.
    fn end_round(&mut self);

    /// Count a round **without** a rendezvous: bumps `rounds` and appends a
    /// zero to the wait series so per-round stats stay aligned with the
    /// sync path, but no rank blocks. The async remainder uses this on
    /// intermediate rounds — every message was already matched exactly
    /// once by `(from, tag)`, so the barrier only costs wait time there;
    /// the sweep's **final** round must still call
    /// [`Communicator::end_round`] to preserve the cross-sweep tag-reuse
    /// invariant (see `engine::pool`).
    ///
    /// Default: a full [`Communicator::end_round`] (safe, just slower).
    fn advance_round(&mut self) {
        self.end_round();
    }

    /// Per-rank accumulated statistics.
    fn stats(&self) -> &CommStats;

    /// Post this rank's halo sends of `x` for round `tag` (one message per
    /// non-empty [`crate::distsim::SendPlan`]).
    fn post_halo_sends(&mut self, r: &RankLocal, tag: u64, x: &[f64]) {
        for sp in &r.send {
            let payload: Vec<f64> = sp.rows.iter().map(|&row| x[row as usize]).collect();
            self.send(sp.to, tag, payload);
        }
    }

    /// Receive every [`crate::distsim::RecvPlan`] of round `tag` into the
    /// halo tail of `x`, then close the round.
    fn wait_halo(&mut self, r: &RankLocal, tag: u64, x: &mut [f64]) {
        let nl = r.n_local();
        for rp in &r.recv {
            let payload = self.recv(rp.from, tag);
            debug_assert_eq!(payload.len(), rp.slots.len(), "halo payload length");
            x[nl + rp.slots.start..nl + rp.slots.end].copy_from_slice(&payload);
        }
        self.end_round();
    }

    /// Blocking bulk-synchronous halo exchange: post + wait.
    fn exchange(&mut self, r: &RankLocal, tag: u64, x: &mut [f64]) {
        self.post_halo_sends(r, tag, x);
        self.wait_halo(r, tag, x);
    }
}

/// Receiver-side accounting shared by every transport: one message, its
/// payload bytes, and the running max (see the module-level *Accounting*
/// rules — calling this anywhere but on a successful receive breaks the
/// cross-transport stat equality the tests assert).
pub(crate) fn account_recv(stats: &mut CommStats, len: usize) {
    stats.messages += 1;
    let bytes = len * std::mem::size_of::<f64>();
    stats.bytes += bytes;
    stats.max_message_bytes = stats.max_message_bytes.max(bytes);
}

/// Payload bytes as the `u32` a [`Span`] carries (halo messages are far
/// below 4 GiB; saturate rather than wrap if one ever is not).
pub(crate) fn span_bytes(len: usize) -> u32 {
    (len * std::mem::size_of::<f64>()).min(u32::MAX as usize) as u32
}

// ---------------------------------------------------------------------------
// SimComm — sequential lockstep transport
// ---------------------------------------------------------------------------

type SimMailbox = HashMap<(usize, usize, u64), Vec<f64>>;

/// Sequential transport: a shared mailbox keyed by `(from, to, tag)`.
///
/// `recv` never blocks — the lockstep executor posts every rank's sends for
/// a round before any rank waits (see [`lockstep_halo_exchange`]), exactly
/// like the legacy all-ranks `exchange_halo` loop. A missing message is a
/// scheduling bug and panics.
pub struct SimComm {
    rank: usize,
    n: usize,
    mailbox: Arc<Mutex<SimMailbox>>,
    stats: CommStats,
    tracer: RankRecorder,
}

/// Build connected [`SimComm`] endpoints for `n` ranks.
pub fn sim_comms(n: usize) -> Vec<SimComm> {
    let mailbox = Arc::new(Mutex::new(SimMailbox::new()));
    (0..n)
        .map(|rank| SimComm {
            rank,
            n,
            mailbox: mailbox.clone(),
            stats: CommStats::default(),
            tracer: RankRecorder::disabled(),
        })
        .collect()
}

impl SimComm {
    /// Attach a recorder (normally [`crate::trace::TraceSession::recorder`]).
    pub fn set_tracer(&mut self, tracer: RankRecorder) {
        self.tracer = tracer;
    }

    /// Drain recorded events (for absorbing into the owning session).
    pub fn take_trace_events(&mut self) -> Vec<crate::trace::Event> {
        self.tracer.take_events()
    }
}

impl Communicator for SimComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn n_ranks(&self) -> usize {
        self.n
    }

    fn tracer(&mut self) -> &mut RankRecorder {
        &mut self.tracer
    }

    fn send(&mut self, to: usize, tag: u64, payload: Vec<f64>) {
        assert!(to < self.n && to != self.rank, "bad destination {to}");
        let t0 = self.tracer.now();
        let bytes = span_bytes(payload.len());
        let prev = self.mailbox.lock().unwrap().insert((self.rank, to, tag), payload);
        assert!(prev.is_none(), "duplicate send {} -> {to} tag {tag}", self.rank);
        self.tracer.closed_span(Span::CommSend { to: to as u32, bytes }, t0);
    }

    fn recv(&mut self, from: usize, tag: u64) -> Vec<f64> {
        let t0 = self.tracer.now();
        let payload = self
            .mailbox
            .lock()
            .unwrap()
            .remove(&(from, self.rank, tag))
            .unwrap_or_else(|| {
                panic!(
                    "SimComm: message {from} -> {} tag {tag} not posted; \
                     the sequential executor must post all sends of a round first",
                    self.rank
                )
            });
        account_recv(&mut self.stats, payload.len());
        self.tracer
            .closed_span(Span::CommRecv { from: from as u32, bytes: span_bytes(payload.len()) }, t0);
        payload
    }

    fn try_recv(&mut self, from: usize, tag: u64) -> Option<Vec<f64>> {
        let t0 = self.tracer.now();
        match self.mailbox.lock().unwrap().remove(&(from, self.rank, tag)) {
            Some(payload) => {
                account_recv(&mut self.stats, payload.len());
                self.tracer.closed_span(
                    Span::CommRecv { from: from as u32, bytes: span_bytes(payload.len()) },
                    t0,
                );
                Some(payload)
            }
            None => {
                self.tracer.closed_span(Span::CommProbe { from: from as u32 }, t0);
                None
            }
        }
    }

    fn recv_any(&mut self, reqs: &[(usize, u64)]) -> (usize, Vec<f64>) {
        assert!(!reqs.is_empty(), "recv_any on an empty request set");
        let t0 = self.tracer.now();
        let mut mb = self.mailbox.lock().unwrap();
        for (i, &(from, tag)) in reqs.iter().enumerate() {
            if let Some(payload) = mb.remove(&(from, self.rank, tag)) {
                drop(mb);
                account_recv(&mut self.stats, payload.len());
                self.tracer.closed_span(
                    Span::CommRecv { from: from as u32, bytes: span_bytes(payload.len()) },
                    t0,
                );
                return (i, payload);
            }
        }
        panic!(
            "SimComm: none of {} posted receives available on rank {}; \
             the sequential executor must post all sends of a round first",
            reqs.len(),
            self.rank
        );
    }

    fn end_round(&mut self) {
        let t0 = self.tracer.now();
        self.stats.rounds += 1;
        self.stats.wait_ns.push(0); // sequential lockstep: nobody waits
        self.tracer.closed_span(Span::CommWait { round: (self.stats.rounds - 1) as u32 }, t0);
    }

    // `advance_round` keeps the trait default (= `end_round`): the
    // sequential transport never blocks in a round close anyway.

    fn stats(&self) -> &CommStats {
        &self.stats
    }
}

/// One lockstep bulk-synchronous halo exchange over all ranks: post every
/// rank's sends, then complete every rank's receives — the sequential
/// executor's replacement for the legacy global `exchange_halo`.
pub fn lockstep_halo_exchange<C: Communicator>(
    comms: &mut [C],
    ranks: &[RankLocal],
    tag: u64,
    xs: &mut [Vec<f64>],
) {
    assert_eq!(comms.len(), ranks.len());
    assert_eq!(comms.len(), xs.len());
    for ((c, r), x) in comms.iter_mut().zip(ranks).zip(xs.iter()) {
        c.post_halo_sends(r, tag, x);
    }
    for ((c, r), x) in comms.iter_mut().zip(ranks).zip(xs.iter_mut()) {
        c.wait_halo(r, tag, x);
    }
}

// ---------------------------------------------------------------------------
// ThreadComm — channel transport, one OS thread per rank
// ---------------------------------------------------------------------------

/// `(from, tag, payload)`.
type Msg = (usize, u64, Vec<f64>);

/// A dying rank broadcasts this tag so peers blocked in `recv` fail fast
/// instead of hanging (kernel tags are small round numbers, never this).
const POISON_TAG: u64 = u64::MAX;

/// Rendezvous barrier with two extras over `std::sync::Barrier`: every
/// waiter passes its round counter and the barrier asserts all ranks
/// agree (one lock, no second pass), and a panicking rank can mark itself
/// dead to wake the waiters — std's barrier has no poisoning, so a
/// per-rank panic would otherwise turn into a silent hang.
struct RoundBarrier {
    state: Mutex<BarrierState>,
    cv: Condvar,
    n: usize,
}

#[derive(Default)]
struct BarrierState {
    arrived: usize,
    generation: u64,
    /// Round counter of the first arriver this cycle; later arrivers must
    /// match it.
    round: usize,
    dead: usize,
}

impl RoundBarrier {
    fn new(n: usize) -> Self {
        Self { state: Mutex::new(BarrierState::default()), cv: Condvar::new(), n }
    }

    /// Meet all ranks, asserting everyone arrives with the same `rounds`.
    fn wait(&self, rounds: usize) {
        let mut st = self.state.lock().unwrap();
        assert_eq!(st.dead, 0, "a rank thread died; aborting round barrier");
        if st.arrived == 0 {
            st.round = rounds;
        } else {
            assert_eq!(
                rounds, st.round,
                "round diverged: this rank at {rounds}, first arriver at {}",
                st.round
            );
        }
        st.arrived += 1;
        if st.arrived == self.n {
            st.arrived = 0;
            st.generation = st.generation.wrapping_add(1);
            self.cv.notify_all();
            return;
        }
        let gen = st.generation;
        while st.generation == gen {
            st = self.cv.wait(st).unwrap();
            assert_eq!(st.dead, 0, "a rank thread died while waiting at the round barrier");
        }
    }

    fn mark_dead(&self) {
        // Runs from a Drop during panic: must not panic again even if the
        // mutex was poisoned by the rank that died holding it.
        let mut st = match self.state.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        st.dead += 1;
        self.cv.notify_all();
    }
}

/// Threaded transport: every rank owns one unbounded mpsc receiver; every
/// peer holds a sender clone to it. Receives match on `(from, tag)` and
/// buffer everything else, so a fast neighbor may run several rounds ahead
/// without corrupting this rank's halo. `end_round` is a full barrier that
/// also asserts the per-rank round counters agree. If a rank thread
/// panics, its endpoint poisons the barrier and all peers on drop so the
/// whole run fails loudly instead of deadlocking.
pub struct ThreadComm {
    rank: usize,
    n: usize,
    /// `txs[peer]`; `None` at `self.rank`.
    txs: Vec<Option<Sender<Msg>>>,
    rx: Receiver<Msg>,
    /// Unexpected-message queue, keyed by `(from, tag)`.
    pending: HashMap<(usize, u64), Vec<f64>>,
    stats: CommStats,
    barrier: Arc<RoundBarrier>,
    tracer: RankRecorder,
}

impl ThreadComm {
    /// Attach a recorder (normally [`crate::trace::TraceSession::recorder`]).
    pub fn set_tracer(&mut self, tracer: RankRecorder) {
        self.tracer = tracer;
    }

    /// Drain recorded events (for absorbing into the owning session).
    pub fn take_trace_events(&mut self) -> Vec<crate::trace::Event> {
        self.tracer.take_events()
    }
}

/// Build connected [`ThreadComm`] endpoints for `n` ranks (move each into
/// its rank's thread).
pub fn thread_comms(n: usize) -> Vec<ThreadComm> {
    let mut txs = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel::<Msg>();
        txs.push(tx);
        rxs.push(rx);
    }
    let barrier = Arc::new(RoundBarrier::new(n));
    rxs.into_iter()
        .enumerate()
        .map(|(rank, rx)| ThreadComm {
            rank,
            n,
            txs: txs
                .iter()
                .enumerate()
                .map(|(p, tx)| (p != rank).then(|| tx.clone()))
                .collect(),
            rx,
            pending: HashMap::new(),
            stats: CommStats::default(),
            barrier: barrier.clone(),
            tracer: RankRecorder::disabled(),
        })
        .collect()
}

impl Drop for ThreadComm {
    fn drop(&mut self) {
        // A panicking rank must not strand its peers at the barrier or in
        // a blocking recv — poison both so the failure cascades and the
        // executor's joins report it.
        if std::thread::panicking() {
            self.barrier.mark_dead();
            for tx in self.txs.iter().flatten() {
                let _ = tx.send((self.rank, POISON_TAG, Vec::new()));
            }
        }
    }
}

impl Communicator for ThreadComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn n_ranks(&self) -> usize {
        self.n
    }

    fn tracer(&mut self) -> &mut RankRecorder {
        &mut self.tracer
    }

    fn send(&mut self, to: usize, tag: u64, payload: Vec<f64>) {
        let t0 = self.tracer.now();
        let bytes = span_bytes(payload.len());
        self.txs[to]
            .as_ref()
            .unwrap_or_else(|| panic!("rank {} sending to itself", self.rank))
            .send((self.rank, tag, payload))
            .expect("peer rank hung up");
        self.tracer.closed_span(Span::CommSend { to: to as u32, bytes }, t0);
    }

    fn recv(&mut self, from: usize, tag: u64) -> Vec<f64> {
        let t0 = self.tracer.now();
        let key = (from, tag);
        let payload = loop {
            if let Some(p) = self.pending.remove(&key) {
                break p;
            }
            let (f, t, p) = self.rx.recv().expect("all peer ranks hung up");
            assert_ne!(t, POISON_TAG, "peer rank {f} died mid-run");
            let prev = self.pending.insert((f, t), p);
            assert!(prev.is_none(), "duplicate message {f} -> {} tag {t}", self.rank);
        };
        account_recv(&mut self.stats, payload.len());
        self.tracer
            .closed_span(Span::CommRecv { from: from as u32, bytes: span_bytes(payload.len()) }, t0);
        payload
    }

    fn try_recv(&mut self, from: usize, tag: u64) -> Option<Vec<f64>> {
        let t0 = self.tracer.now();
        // Drain everything already delivered into the unexpected queue,
        // then complete from it — never blocks.
        while let Ok((f, t, p)) = self.rx.try_recv() {
            assert_ne!(t, POISON_TAG, "peer rank {f} died mid-run");
            let prev = self.pending.insert((f, t), p);
            assert!(prev.is_none(), "duplicate message {f} -> {} tag {t}", self.rank);
        }
        match self.pending.remove(&(from, tag)) {
            Some(payload) => {
                account_recv(&mut self.stats, payload.len());
                self.tracer.closed_span(
                    Span::CommRecv { from: from as u32, bytes: span_bytes(payload.len()) },
                    t0,
                );
                Some(payload)
            }
            None => {
                self.tracer.closed_span(Span::CommProbe { from: from as u32 }, t0);
                None
            }
        }
    }

    fn recv_any(&mut self, reqs: &[(usize, u64)]) -> (usize, Vec<f64>) {
        assert!(!reqs.is_empty(), "recv_any on an empty request set");
        let t0 = self.tracer.now();
        let (idx, payload) = loop {
            // Unexpected queue first, lowest request index winning ties —
            // the same deterministic tiebreak SimComm uses.
            if let Some(i) = reqs.iter().position(|key| self.pending.contains_key(key)) {
                break (i, self.pending.remove(&reqs[i]).unwrap());
            }
            let (f, t, p) = self.rx.recv().expect("all peer ranks hung up");
            assert_ne!(t, POISON_TAG, "peer rank {f} died mid-run");
            let prev = self.pending.insert((f, t), p);
            assert!(prev.is_none(), "duplicate message {f} -> {} tag {t}", self.rank);
        };
        account_recv(&mut self.stats, payload.len());
        self.tracer.closed_span(
            Span::CommRecv { from: reqs[idx].0 as u32, bytes: span_bytes(payload.len()) },
            t0,
        );
        (idx, payload)
    }

    fn end_round(&mut self) {
        // Barrier wait is measured unconditionally (CommStats carries it
        // even with tracing off) — one extra Instant read per round is
        // noise next to the rendezvous itself.
        let wall0 = Instant::now();
        let t0 = self.tracer.now();
        self.stats.rounds += 1;
        self.barrier.wait(self.stats.rounds);
        self.stats.wait_ns.push(wall0.elapsed().as_nanos() as u64);
        self.tracer.closed_span(Span::CommWait { round: (self.stats.rounds - 1) as u32 }, t0);
    }

    fn advance_round(&mut self) {
        // Barrier-free round close for the async remainder: every message
        // of the round was matched exactly once by `(from, tag)` before
        // this call, so the rendezvous would only add wait time. The round
        // counter still advances in lockstep logically — all ranks execute
        // the same sequence — which keeps the final `end_round` barrier's
        // counter assertion valid.
        let t0 = self.tracer.now();
        self.stats.rounds += 1;
        self.stats.wait_ns.push(0);
        self.tracer.closed_span(Span::CommWait { round: (self.stats.rounds - 1) as u32 }, t0);
    }

    fn stats(&self) -> &CommStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distsim::{exchange_halo, merge_rank_stats, DistMatrix};
    use crate::matrix::gen;
    use crate::partition::{partition, Method};

    fn setup(np: usize) -> (DistMatrix, Vec<Vec<f64>>, Vec<f64>) {
        let a = gen::stencil_2d_5pt(8, 7);
        let p = partition(&a, np, Method::Block);
        let d = DistMatrix::build(&a, &p);
        let x: Vec<f64> = (0..a.n_rows()).map(|i| 3.0 + i as f64).collect();
        let xs = d.scatter(&x);
        (d, xs, x)
    }

    #[test]
    fn sim_lockstep_matches_legacy_exchange_bit_for_bit() {
        let (d, xs0, _) = setup(3);

        let mut xs_old = xs0.clone();
        let mut st_old = CommStats::default();
        exchange_halo(&d.ranks, &mut xs_old, &mut st_old);
        exchange_halo(&d.ranks, &mut xs_old, &mut st_old);

        let mut xs_new = xs0;
        let mut comms = sim_comms(d.n_ranks());
        lockstep_halo_exchange(&mut comms, &d.ranks, 0, &mut xs_new);
        lockstep_halo_exchange(&mut comms, &d.ranks, 1, &mut xs_new);

        assert_eq!(xs_old, xs_new);
        let per_rank: Vec<CommStats> = comms.iter().map(|c| c.stats().clone()).collect();
        assert_eq!(merge_rank_stats(&per_rank), st_old);
    }

    #[test]
    fn threaded_exchange_fills_halo_with_owner_values() {
        let (d, xs, x) = setup(4);
        let comms = thread_comms(d.n_ranks());
        let filled: Vec<(Vec<f64>, CommStats)> = std::thread::scope(|s| {
            let joins: Vec<_> = comms
                .into_iter()
                .zip(&d.ranks)
                .zip(xs)
                .map(|((mut c, r), mut xv)| {
                    s.spawn(move || {
                        c.exchange(r, 0, &mut xv);
                        let st = c.stats().clone();
                        (xv, st)
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().expect("rank thread panicked")).collect()
        });
        for (r, (xv, _)) in d.ranks.iter().zip(&filled) {
            for (slot, &g) in r.halo_globals.iter().enumerate() {
                assert_eq!(xv[r.n_local() + slot], x[g], "rank {} slot {slot}", r.rank);
            }
        }
        let per_rank: Vec<CommStats> = filled.iter().map(|(_, s)| s.clone()).collect();
        let merged = merge_rank_stats(&per_rank);
        assert_eq!(merged.rounds, 1);
        assert_eq!(merged.bytes, d.total_halo() * 8);
    }

    #[test]
    fn threaded_recv_buffers_rounds_ahead() {
        // rank 0 sends two rounds before rank 1 receives either.
        let mut comms = thread_comms(2);
        let mut c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        let t = std::thread::spawn(move || {
            c0.send(1, 0, vec![1.0]);
            c0.send(1, 1, vec![2.0]);
            c0.end_round();
            c0.end_round();
        });
        // receive out of posting order: tag 1 first
        assert_eq!(c1.recv(0, 1), vec![2.0]);
        assert_eq!(c1.recv(0, 0), vec![1.0]);
        c1.end_round();
        c1.end_round();
        t.join().unwrap();
        assert_eq!(c1.stats().messages, 2);
        assert_eq!(c1.stats().bytes, 16);
        assert_eq!(c1.stats().rounds, 2);
    }

    #[test]
    fn sim_try_recv_and_recv_any_are_deterministic() {
        let mut comms = sim_comms(3);
        assert!(comms[0].try_recv(1, 4).is_none(), "nothing posted yet");
        assert_eq!(comms[0].stats().messages, 0, "a miss must not account");
        comms[1].send(0, 4, vec![1.5]);
        comms[2].send(0, 4, vec![2.5]);
        // Both available -> lowest request index completes first.
        let (i, p) = comms[0].recv_any(&[(1, 4), (2, 4)]);
        assert_eq!((i, p), (0, vec![1.5]));
        let (i, p) = comms[0].recv_any(&[(1, 4), (2, 4)]);
        assert_eq!((i, p), (1, vec![2.5]));
        assert_eq!(comms[0].stats().messages, 2);
        assert_eq!(comms[0].stats().bytes, 16);
    }

    #[test]
    fn thread_try_recv_and_recv_any_complete_out_of_order() {
        let mut comms = thread_comms(2);
        let mut c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        assert!(c1.try_recv(0, 7).is_none(), "nothing posted yet");
        assert_eq!(c1.stats().messages, 0, "a miss must not account");
        c0.send(1, 7, vec![7.0]);
        c0.send(1, 3, vec![3.0]);
        // Complete against posting order: tag 3 first.
        assert_eq!(c1.try_recv(0, 3), Some(vec![3.0]));
        // recv_any skips the never-posted request and completes the
        // buffered one without blocking.
        let (i, p) = c1.recv_any(&[(0, 9), (0, 7)]);
        assert_eq!((i, p), (1, vec![7.0]));
        assert_eq!(c1.stats().messages, 2);
        assert_eq!(c1.stats().bytes, 16);
    }

    #[test]
    fn advance_round_counts_without_rendezvous() {
        // One endpoint of a 2-rank set advancing alone: a barrier would
        // deadlock here, advance_round must not.
        let mut comms = thread_comms(2);
        let mut c0 = comms.remove(0);
        c0.advance_round();
        assert_eq!(c0.stats().rounds, 1);
        assert_eq!(c0.stats().wait_ns, vec![0]);
    }

    #[test]
    fn panicking_rank_fails_peers_instead_of_hanging() {
        let mut comms = thread_comms(2);
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        let t0 = std::thread::spawn(move || {
            let _guard = c0; // dropped while panicking -> poisons barrier + peers
            panic!("rank 0 exploded");
        });
        let t1 = std::thread::spawn(move || {
            let mut c1 = c1;
            // must abort via the poisoned barrier, not deadlock
            c1.end_round();
        });
        assert!(t0.join().is_err());
        assert!(t1.join().is_err(), "peer must fail fast when a rank dies");
    }
}
