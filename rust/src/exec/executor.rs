//! The threaded rank executor: one OS thread per rank, each running the
//! single-rank kernel of its MPK variant against a [`ThreadComm`] endpoint,
//! plus the `sim | threads(n)` dispatch knob ([`ExecutorKind`]).
//!
//! Results are assembled deterministically: per-rank stats merge in
//! ascending rank order ([`merge_rank_stats`] asserts the round counters
//! agree), flops sum in rank order, and powers gather by ownership — so a
//! threaded run is bitwise-comparable to the sequential simulator no matter
//! how the OS interleaved the rank threads.

use crate::distsim::{merge_rank_stats, CommStats, DistMatrix};
use crate::inner::InnerExec;
use crate::mpk::dlb::{DlbPlan, Recurrence};
use crate::mpk::{ca, dlb, trad, MpkResult, MpkVariant, NativeBackend};

use super::comm::{thread_comms, ThreadComm};
use super::RankRun;

/// Which executor runs the distributed kernels (`sim | threads(n)`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecutorKind {
    /// Sequential lockstep simulator (exact counters, no parallelism).
    Sim,
    /// One OS thread per rank. `n == 0` means "one per configured rank";
    /// a nonzero `n` *sets* the rank count (`threads(8)` = run 8 ranks on
    /// 8 threads, overriding `--ranks`) — see [`ExecutorKind::ranks`].
    Threads { n: usize },
    /// One OS *process* per rank over Unix-domain sockets
    /// ([`super::SockComm`]). The engine builds the endpoint for **this**
    /// process's rank from the `DLB_MPK_RANK`/`DLB_MPK_WORLD` env protocol
    /// (set by `dlb-mpk launch --np N`); `n` follows the same zero-is-auto
    /// rule as [`ExecutorKind::Threads`], validated against the launched
    /// world size.
    Processes { n: usize },
}

impl ExecutorKind {
    /// Parse `"sim"`, `"threads"`/`"threads(N)"`, or
    /// `"processes"`/`"processes(N)"`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "sim" => Some(Self::Sim),
            "threads" => Some(Self::Threads { n: 0 }),
            "processes" => Some(Self::Processes { n: 0 }),
            _ => {
                if let Some(inner) = s.strip_prefix("threads(").and_then(|r| r.strip_suffix(')')) {
                    return Some(Self::Threads { n: inner.parse().ok()? });
                }
                let inner = s.strip_prefix("processes(")?.strip_suffix(')')?;
                Some(Self::Processes { n: inner.parse().ok()? })
            }
        }
    }

    /// Short label for reports (`sim` / `thr` / `proc`).
    pub fn label(&self) -> &'static str {
        match self {
            Self::Sim => "sim",
            Self::Threads { .. } => "thr",
            Self::Processes { .. } => "proc",
        }
    }

    /// Effective rank count: `threads(n)`/`processes(n)` with nonzero `n`
    /// overrides the configured default (one thread/process per rank
    /// either way).
    pub fn ranks(&self, default: usize) -> usize {
        match self {
            Self::Threads { n } | Self::Processes { n } if *n > 0 => *n,
            _ => default,
        }
    }

    /// Check the knob against an already-built distributed matrix (for
    /// callers that cannot re-partition, like [`run`]).
    ///
    /// `Threads { n: 0 }` is deliberately *accepted* against any rank
    /// count: zero is not a thread count but the parse of plain
    /// `"threads"` — "one thread per already-configured rank" — so it
    /// matches every matrix by construction (see [`ExecutorKind::ranks`],
    /// which resolves 0 to the configured default and can never yield a
    /// zero-rank run). Only an explicit `threads(n)`, which *sets* the
    /// rank count, can disagree with a prebuilt matrix.
    pub fn validate(&self, n_ranks: usize) -> anyhow::Result<()> {
        match self {
            Self::Threads { n } => anyhow::ensure!(
                *n == 0 || *n == n_ranks,
                "executor threads({n}) does not match the matrix's {n_ranks} ranks"
            ),
            Self::Processes { n } => anyhow::ensure!(
                *n == 0 || *n == n_ranks,
                "executor processes({n}) does not match the matrix's {n_ranks} ranks"
            ),
            Self::Sim => {}
        }
        Ok(())
    }
}

impl std::fmt::Display for ExecutorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Sim => write!(f, "sim"),
            Self::Threads { n: 0 } => write!(f, "threads"),
            Self::Threads { n } => write!(f, "threads({n})"),
            Self::Processes { n: 0 } => write!(f, "processes"),
            Self::Processes { n } => write!(f, "processes({n})"),
        }
    }
}

/// Spawn one thread per rank, run `body(rank, comm)` on each, and join in
/// rank order.
fn run_ranks<F>(n: usize, body: F) -> Vec<(RankRun, CommStats)>
where
    F: Fn(usize, ThreadComm) -> (RankRun, CommStats) + Sync,
{
    let comms = thread_comms(n);
    std::thread::scope(|s| {
        let joins: Vec<_> = comms
            .into_iter()
            .enumerate()
            .map(|(i, c)| {
                let body = &body;
                s.spawn(move || body(i, c))
            })
            .collect();
        joins
            .into_iter()
            .map(|j| j.join().expect("rank thread panicked"))
            .collect()
    })
}

/// Gather per-rank outputs into a global [`MpkResult`] (deterministic
/// rank-ascending merge). Shared with the persistent-pool executor in
/// [`crate::engine`], so both threaded paths merge identically.
pub(crate) fn assemble(dist: &DistMatrix, p_m: usize, outs: Vec<(RankRun, CommStats)>) -> MpkResult {
    let per_rank: Vec<CommStats> = outs.iter().map(|(_, s)| s.clone()).collect();
    let comm = merge_rank_stats(&per_rank);
    let flop_nnz = outs.iter().map(|(run, _)| run.flop_nnz).sum();
    let mut powers = vec![vec![0.0; dist.n_global]; p_m];
    for (r, (run, _)) in dist.ranks.iter().zip(&outs) {
        for (pw, ys) in powers.iter_mut().zip(run.ys.iter().skip(1)) {
            for (l, &g) in r.owned.iter().enumerate() {
                pw[g] = ys[l];
            }
        }
    }
    MpkResult { powers, comm, flop_nnz }
}

/// TRAD-MPK under the threaded executor (measured parallel wall-clock).
pub fn trad_threaded(
    dist: &DistMatrix,
    x: &[f64],
    x_m1: Option<&[f64]>,
    p_m: usize,
    rec: Recurrence,
) -> MpkResult {
    let xs = dist.scatter(x);
    let xm1s = x_m1.map(|v| dist.scatter(v));
    let outs = run_ranks(dist.n_ranks(), |i, mut comm| {
        let r = &dist.ranks[i];
        let xm1 = xm1s.as_ref().map(|v| v[i].as_slice());
        let mut backend = NativeBackend;
        let mut inner = InnerExec::serial();
        let run = trad::trad_rank(r, &xs[i], xm1, p_m, rec, &mut comm, &mut backend, &mut inner);
        let stats = comm.stats().clone();
        (run, stats)
    });
    assemble(dist, p_m, outs)
}

/// DLB-MPK under the threaded executor, with the remainder-round sends
/// overlapped with the wavefront (paper §5). Reuses a prebuilt [`DlbPlan`]
/// so setup cost amortizes exactly like the sequential path.
pub fn dlb_threaded(
    plan: &DlbPlan,
    x: &[f64],
    x_m1: Option<&[f64]>,
    rec: Recurrence,
) -> MpkResult {
    let dist = &plan.dist;
    let xs = dist.scatter(x);
    let xm1s = x_m1.map(|v| dist.scatter(v));
    let outs = run_ranks(dist.n_ranks(), |i, mut comm| {
        let r = &dist.ranks[i];
        let xm1 = xm1s.as_ref().map(|v| v[i].as_slice());
        let mut backend = NativeBackend;
        let mut inner = InnerExec::serial();
        let run = dlb::dlb_rank(
            r,
            &plan.ranks[i],
            plan.p_m,
            &xs[i],
            xm1,
            rec,
            &mut comm,
            &mut backend,
            &mut inner,
        );
        let stats = comm.stats().clone();
        (run, stats)
    });
    assemble(dist, plan.p_m, outs)
}

/// CA-MPK under the threaded executor: one extended exchange of the input,
/// then embarrassingly parallel redundant computation per rank.
pub fn ca_threaded(
    a: &crate::matrix::CsrMatrix,
    dist: &DistMatrix,
    x: &[f64],
    p_m: usize,
) -> MpkResult {
    let plan = ca::ca_exec_plan(a, dist, p_m);
    let xs = dist.scatter(x);
    let outs = run_ranks(dist.n_ranks(), |i, mut comm| {
        let r = &dist.ranks[i];
        let mut inner = InnerExec::serial();
        let run = ca::ca_rank(
            a,
            r,
            &plan.sends[i],
            &plan.recvs[i],
            &plan.ext[i],
            &xs[i],
            p_m,
            &mut comm,
            &mut inner,
        );
        let stats = comm.stats().clone();
        (run, stats)
    });
    assemble(dist, p_m, outs)
}

/// Variant dispatcher over both executors, mirroring [`crate::mpk::run`]
/// (like it, the DLB branch plans with default options apart from the
/// cache budget; use [`dlb_threaded`] with an explicit plan for tuned
/// `s_m` or amortized setup).
///
/// # Panics
///
/// If `kind` is `threads(n)` with a nonzero `n` that does not match the
/// prebuilt matrix's rank count (the matrix cannot be re-partitioned
/// here — apply [`ExecutorKind::ranks`] before building it, as the
/// coordinator does).
pub fn run(
    dist: &DistMatrix,
    x: &[f64],
    p_m: usize,
    variant: MpkVariant,
    kind: ExecutorKind,
) -> MpkResult {
    kind.validate(dist.n_ranks()).expect("executor/rank mismatch");
    match kind {
        ExecutorKind::Sim => crate::mpk::run(dist, x, p_m, variant),
        ExecutorKind::Threads { .. } => match variant {
            MpkVariant::Trad => trad_threaded(dist, x, None, p_m, Recurrence::Power),
            MpkVariant::Ca => {
                let a = ca::reassemble_global(dist);
                ca_threaded(&a, dist, x, p_m)
            }
            MpkVariant::Dlb { cache_bytes } => {
                let opts = dlb::DlbOptions { cache_bytes, ..dlb::DlbOptions::default() };
                let plan = dlb::plan(dist, p_m, &opts);
                dlb_threaded(&plan, x, None, Recurrence::Power)
            }
        },
        ExecutorKind::Processes { .. } => panic!(
            "the processes executor is SPMD — construct an MpkEngine inside a \
             `dlb-mpk launch`-spawned rank process instead of calling exec::run"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::mpk::trad_mpk;
    use crate::partition::{partition, Method};

    #[test]
    fn executor_kind_parses() {
        assert_eq!(ExecutorKind::parse("sim"), Some(ExecutorKind::Sim));
        assert_eq!(ExecutorKind::parse("threads"), Some(ExecutorKind::Threads { n: 0 }));
        assert_eq!(ExecutorKind::parse("threads(4)"), Some(ExecutorKind::Threads { n: 4 }));
        assert_eq!(ExecutorKind::parse("processes"), Some(ExecutorKind::Processes { n: 0 }));
        assert_eq!(ExecutorKind::parse("processes(2)"), Some(ExecutorKind::Processes { n: 2 }));
        assert_eq!(ExecutorKind::parse("mpi"), None);
        assert_eq!(ExecutorKind::parse("threads(x)"), None);
        assert_eq!(ExecutorKind::parse("processes(x)"), None);
        assert_eq!(format!("{}", ExecutorKind::Threads { n: 4 }), "threads(4)");
        assert_eq!(format!("{}", ExecutorKind::Processes { n: 0 }), "processes");
        assert_eq!(format!("{}", ExecutorKind::Processes { n: 2 }), "processes(2)");
        assert_eq!(ExecutorKind::Processes { n: 2 }.label(), "proc");
        assert!(ExecutorKind::Threads { n: 3 }.validate(4).is_err());
        assert!(ExecutorKind::Threads { n: 0 }.validate(4).is_ok());
        assert!(ExecutorKind::Processes { n: 3 }.validate(4).is_err());
        assert!(ExecutorKind::Processes { n: 0 }.validate(4).is_ok());
        // nonzero n overrides the configured rank count
        assert_eq!(ExecutorKind::Threads { n: 3 }.ranks(8), 3);
        assert_eq!(ExecutorKind::Threads { n: 0 }.ranks(8), 8);
        assert_eq!(ExecutorKind::Processes { n: 3 }.ranks(8), 3);
        assert_eq!(ExecutorKind::Sim.ranks(8), 8);
    }

    #[test]
    fn validate_treats_zero_threads_as_auto() {
        // `threads` (n = 0) is the auto form: one thread per configured
        // rank, valid against any prebuilt matrix — including one rank.
        for n_ranks in [1, 2, 8] {
            assert!(ExecutorKind::Threads { n: 0 }.validate(n_ranks).is_ok());
            assert!(ExecutorKind::Sim.validate(n_ranks).is_ok());
        }
        // An explicit count must match the matrix exactly.
        assert!(ExecutorKind::Threads { n: 4 }.validate(4).is_ok());
        let err = ExecutorKind::Threads { n: 4 }.validate(2).unwrap_err();
        assert!(err.to_string().contains("threads(4)"), "{err}");
        // And `ranks` can never resolve the auto form to zero ranks.
        assert_eq!(ExecutorKind::Threads { n: 0 }.ranks(1), 1);
    }

    #[test]
    fn threaded_trad_matches_sim_bitwise() {
        let a = gen::stencil_2d_5pt(10, 9);
        let x: Vec<f64> = (0..a.n_rows()).map(|i| ((i % 11) as f64 - 5.0) / 3.0).collect();
        for np in [1, 3, 4] {
            let part = partition(&a, np, Method::Block);
            let d = DistMatrix::build(&a, &part);
            let sim = trad_mpk(&d, &x, 3, &mut NativeBackend);
            let thr = trad_threaded(&d, &x, None, 3, Recurrence::Power);
            assert_eq!(sim.powers, thr.powers, "np={np}");
            assert_eq!(sim.comm, thr.comm, "np={np}");
            assert_eq!(sim.flop_nnz, thr.flop_nnz, "np={np}");
        }
    }
}
