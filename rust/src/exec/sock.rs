//! [`SockComm`] — the multi-**process** transport: every rank is a separate
//! OS process and halo messages travel over Unix-domain sockets. This is
//! the third [`Communicator`] implementation (after the sequential
//! [`super::SimComm`] and the threaded [`super::ThreadComm`]) and the
//! stand-in for — and template of — a real MPI FFI shim: the full
//! nonblocking set maps onto nonblocking socket reads plus the same
//! `(from, tag)`-keyed unexpected-message queue the threaded transport
//! uses, so TRAD/CA/DLB, inner threads, and the async remainder all run
//! unmodified across process boundaries (see `docs/COMMUNICATOR.md` for
//! the transport contract this file conforms to).
//!
//! ## Execution model (SPMD)
//!
//! Like `mpirun`, every rank process runs the *same program* with the same
//! configuration and deterministically rebuilds the identical matrix,
//! partition, and plans; only halo payloads and small control frames cross
//! the sockets. A process learns its identity from the environment
//! ([`RankEnv`]): `DLB_MPK_RANK`, `DLB_MPK_WORLD`, `DLB_MPK_SOCK_DIR`, and
//! optionally `DLB_MPK_TIMEOUT_MS`. The `dlb-mpk launch --np N -- <cmd>`
//! subcommand forks N copies of the current binary with those variables
//! set; any other launcher (a shell loop, a batch scheduler) works the
//! same way.
//!
//! ## Rendezvous
//!
//! Rank `r` binds a listener at `<dir>/rank-<r>-<epoch>.sock`, actively
//! connects to every rank `< r` (retrying with backoff until the peer's
//! listener appears), and accepts one connection from every rank `> r`.
//! Each connector introduces itself with a 16-byte hello frame
//! `[magic, version, from, world]` that the acceptor validates, so a
//! mis-wired or stale process fails the rendezvous loudly instead of
//! corrupting a run. The `epoch` suffix is a process-local counter
//! ([`next_epoch`]): SPMD determinism means every process agrees on the
//! epoch of each engine construction, successive engines in one program
//! never collide on socket paths, and a finished endpoint's cleanup can
//! never unlink a successor's socket. Full-mesh rendezvous is itself a
//! barrier — rank `r` only completes once every pair involving `r`
//! exists — so sequential constructions cannot cross-connect.
//!
//! ## Wire format
//!
//! One frame per message: a 16-byte header `[magic u32][len u32][tag u64]`
//! (little-endian, `len` counts `f64` elements) followed by `len * 8`
//! payload bytes. Receivers validate the magic and bound `len` before
//! trusting either, and buffer partial frames per peer until complete.
//!
//! ## Robustness
//!
//! After rendezvous every stream is nonblocking; all blocking operations
//! are poll loops with a deadline ([`RankEnv::timeout`], default 30 s). A
//! clean peer EOF while a receive is outstanding panics with a "rank X
//! exited" message instead of hanging, and a write that would block first
//! drains this rank's incoming frames (two ranks pushing large payloads at
//! each other would otherwise deadlock on full kernel buffers). Rust
//! ignores `SIGPIPE`, so writes to a dead peer surface as a clean
//! `BrokenPipe` panic. At process level any such panic exits the rank
//! nonzero, which the launcher reports.
//!
//! ## Control plane
//!
//! Round barriers, the engine's post-sweep stats/result allgather, and
//! trace harvesting ride the same framed streams under tags with the top
//! bit set (the crate-internal `CTRL` namespace); kernel sends assert that
//! bit clear. Control frames
//! bypass [`crate::distsim::CommStats`] accounting and trace spans, so the
//! merged per-rank stats stay bit-identical to the single-process
//! transports.

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::distsim::CommStats;
use crate::trace::{RankRecorder, Span};

use super::comm::{account_recv, span_bytes, Communicator};

/// Frame header magic ("DLBM").
const FRAME_MAGIC: u32 = 0x444C_424D;
/// Rendezvous hello magic ("DLBH").
const HELLO_MAGIC: u32 = 0x444C_4248;
/// Bumped on any incompatible frame/hello layout change.
const WIRE_VERSION: u32 = 1;
/// `[magic u32][len u32][tag u64]`, little-endian.
const HEADER_BYTES: usize = 16;
/// Sanity bound on one payload (2 GiB of `f64`s) — a corrupt length field
/// must not turn into a giant allocation.
const MAX_PAYLOAD_ELEMS: usize = 1 << 28;

/// Top tag bit marking control-plane frames (barrier/gather/trace). Kernel
/// tags are small round numbers and must keep this bit clear.
pub(crate) const CTRL: u64 = 1 << 63;
const CTRL_KIND_SHIFT: u32 = 56;
/// Generation bits below the kind field keep every control exchange's
/// `(from, tag)` key unique across a run.
const CTRL_GEN_MASK: u64 = (1 << CTRL_KIND_SHIFT) - 1;
/// Round-barrier arrive/release frames (see [`SockComm::end_round`]).
pub(crate) const CTRL_BARRIER: u64 = CTRL | (1 << CTRL_KIND_SHIFT);
/// Post-sweep stats + owned-rows allgather (engine `sweep_proc`).
pub(crate) const CTRL_GATHER: u64 = CTRL | (2 << CTRL_KIND_SHIFT);
/// Trace-event harvest to rank 0 at sweep end.
pub(crate) const CTRL_TRACE: u64 = CTRL | (3 << CTRL_KIND_SHIFT);

/// Sleep between polls while a blocking operation waits.
const POLL_SLEEP: Duration = Duration::from_micros(50);

/// Compose a control tag from a kind constant and a generation counter.
pub(crate) fn ctrl_tag(kind: u64, generation: u64) -> u64 {
    kind | (generation & CTRL_GEN_MASK)
}

/// This rank's identity under the `DLB_MPK_*` env rendezvous protocol.
///
/// Present (all three of `DLB_MPK_RANK`, `DLB_MPK_WORLD`,
/// `DLB_MPK_SOCK_DIR` set) exactly when the process was started by
/// `dlb-mpk launch` or an equivalent external launcher.
#[derive(Debug, Clone)]
pub struct RankEnv {
    /// This process's rank in `0..world`.
    pub rank: usize,
    /// Total number of rank processes.
    pub world: usize,
    /// Directory holding the rendezvous sockets (shared by all ranks).
    pub dir: PathBuf,
    /// Deadline for rendezvous and for any single blocking operation
    /// (`DLB_MPK_TIMEOUT_MS`, default 30 s).
    pub timeout: Duration,
}

impl RankEnv {
    /// Read the rendezvous protocol from the environment. `None` when not
    /// launched as a rank process; panics on a malformed value (a broken
    /// launcher should fail loudly, not fall back to single-process).
    pub fn from_env() -> Option<RankEnv> {
        let rank = std::env::var("DLB_MPK_RANK").ok()?;
        let world = std::env::var("DLB_MPK_WORLD").ok()?;
        let dir = std::env::var("DLB_MPK_SOCK_DIR").ok()?;
        let rank: usize = rank.parse().expect("DLB_MPK_RANK must be an integer");
        let world: usize = world.parse().expect("DLB_MPK_WORLD must be an integer");
        assert!(world >= 1, "DLB_MPK_WORLD must be >= 1");
        assert!(rank < world, "DLB_MPK_RANK {rank} out of range for world {world}");
        let timeout_ms: u64 = match std::env::var("DLB_MPK_TIMEOUT_MS") {
            Ok(v) => v.parse().expect("DLB_MPK_TIMEOUT_MS must be an integer"),
            Err(_) => 30_000,
        };
        Some(RankEnv {
            rank,
            world,
            dir: PathBuf::from(dir),
            timeout: Duration::from_millis(timeout_ms),
        })
    }
}

/// Process-local rendezvous epoch. SPMD determinism makes every rank
/// process agree on the epoch of each [`SockComm::connect`] (they all
/// execute the same constructions in the same order), so successive
/// engines in one program get disjoint socket paths.
pub fn next_epoch() -> u64 {
    static EPOCH: AtomicU64 = AtomicU64::new(0);
    EPOCH.fetch_add(1, Ordering::Relaxed)
}

fn sock_path(dir: &Path, rank: usize, epoch: u64) -> PathBuf {
    dir.join(format!("rank-{rank}-{epoch}.sock"))
}

/// One connected peer stream plus its partial-frame receive buffer.
struct Peer {
    stream: UnixStream,
    /// Bytes read but not yet parsed into whole frames.
    buf: Vec<u8>,
    /// The peer closed its end (process exited). Frames parsed before the
    /// EOF are still deliverable; a receive that needs more panics.
    eof: bool,
}

impl Peer {
    fn new(stream: UnixStream) -> Self {
        Peer { stream, buf: Vec::new(), eof: false }
    }
}

/// Multi-process socket transport endpoint — see the module docs for the
/// execution model, wire format, and robustness rules. Mirrors
/// [`super::ThreadComm`]'s accounting and span semantics exactly, so
/// merged per-rank [`CommStats`] and kernel results are bit-identical to
/// the single-process transports.
pub struct SockComm {
    rank: usize,
    n: usize,
    /// `peers[p]`; `None` at `self.rank`.
    peers: Vec<Option<Peer>>,
    /// Unexpected-message queue, keyed by `(from, tag)` — control frames
    /// share it (their tags are namespaced by [`CTRL`]).
    pending: HashMap<(usize, u64), Vec<f64>>,
    stats: CommStats,
    tracer: RankRecorder,
    timeout: Duration,
    /// Barrier generation counter (advances in lockstep on every rank).
    barrier_gen: u64,
    /// This rank's listener socket path, unlinked on drop.
    own_sock: PathBuf,
}

impl SockComm {
    /// Rendezvous with all peer ranks of one `epoch` (see module docs) and
    /// return the connected endpoint. Fails — rather than hangs — if any
    /// peer does not appear within `timeout`.
    pub fn connect(
        rank: usize,
        world: usize,
        dir: &Path,
        epoch: u64,
        timeout: Duration,
    ) -> Result<SockComm> {
        ensure!(world >= 1, "world must be >= 1");
        ensure!(rank < world, "rank {rank} out of range for world {world}");
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating socket dir {}", dir.display()))?;
        let own_sock = sock_path(dir, rank, epoch);
        // A stale file from a crashed earlier run would fail the bind.
        let _ = std::fs::remove_file(&own_sock);
        let listener = UnixListener::bind(&own_sock)
            .with_context(|| format!("rank {rank}: binding {}", own_sock.display()))?;
        listener.set_nonblocking(true)?;
        let deadline = Instant::now() + timeout;

        let mut peers: Vec<Option<Peer>> = (0..world).map(|_| None).collect();

        // Phase 1: actively connect to every lower rank. The connect
        // succeeds as soon as the peer's listener is bound (the kernel
        // queues it), so no ordering deadlock with phase 2 is possible.
        for p in 0..rank {
            let path = sock_path(dir, p, epoch);
            let mut backoff = Duration::from_micros(200);
            let stream = loop {
                match UnixStream::connect(&path) {
                    Ok(s) => break s,
                    Err(e) => {
                        if Instant::now() >= deadline {
                            bail!(
                                "rank {rank}: cannot reach rank {p} at {} after {:?}: {e}",
                                path.display(),
                                timeout
                            );
                        }
                        std::thread::sleep(backoff);
                        backoff = (backoff * 2).min(Duration::from_millis(10));
                    }
                }
            };
            // Introduce ourselves (blocking write; 16 bytes always fit the
            // fresh socket buffer, but set a timeout for form's sake).
            stream.set_write_timeout(Some(remaining(deadline)?))?;
            let mut hello = Vec::with_capacity(16);
            hello.extend_from_slice(&HELLO_MAGIC.to_le_bytes());
            hello.extend_from_slice(&WIRE_VERSION.to_le_bytes());
            hello.extend_from_slice(&(rank as u32).to_le_bytes());
            hello.extend_from_slice(&(world as u32).to_le_bytes());
            (&stream)
                .write_all(&hello)
                .with_context(|| format!("rank {rank}: hello to rank {p}"))?;
            stream.set_nonblocking(true)?;
            peers[p] = Some(Peer::new(stream));
        }

        // Phase 2: accept one connection from every higher rank and match
        // it to its slot via the hello frame.
        let mut missing = world - rank - 1;
        while missing > 0 {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    stream.set_read_timeout(Some(remaining(deadline)?))?;
                    let mut hello = [0u8; 16];
                    (&stream)
                        .read_exact(&mut hello)
                        .with_context(|| format!("rank {rank}: reading peer hello"))?;
                    let magic = u32::from_le_bytes(hello[0..4].try_into().unwrap());
                    let version = u32::from_le_bytes(hello[4..8].try_into().unwrap());
                    let from = u32::from_le_bytes(hello[8..12].try_into().unwrap()) as usize;
                    let peer_world = u32::from_le_bytes(hello[12..16].try_into().unwrap()) as usize;
                    ensure!(magic == HELLO_MAGIC, "rank {rank}: bad hello magic {magic:#x}");
                    ensure!(
                        version == WIRE_VERSION,
                        "rank {rank}: peer wire version {version}, ours {WIRE_VERSION}"
                    );
                    ensure!(
                        peer_world == world,
                        "rank {rank}: peer believes world={peer_world}, ours {world}"
                    );
                    ensure!(
                        from > rank && from < world,
                        "rank {rank}: unexpected hello from rank {from}"
                    );
                    ensure!(
                        peers[from].is_none(),
                        "rank {rank}: duplicate connection from rank {from}"
                    );
                    stream.set_nonblocking(true)?;
                    peers[from] = Some(Peer::new(stream));
                    missing -= 1;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!(
                            "rank {rank}: rendezvous timed out after {:?} with {missing} \
                             higher-rank peer(s) missing",
                            timeout
                        );
                    }
                    std::thread::sleep(Duration::from_micros(200));
                }
                Err(e) => return Err(e).context(format!("rank {rank}: accept failed")),
            }
        }

        Ok(SockComm {
            rank,
            n: world,
            peers,
            pending: HashMap::new(),
            stats: CommStats::default(),
            tracer: RankRecorder::disabled(),
            timeout,
            barrier_gen: 0,
            own_sock,
        })
    }

    /// Rendezvous per [`RankEnv`] (the launched-process path).
    pub fn from_env_for(env: &RankEnv, epoch: u64) -> Result<SockComm> {
        SockComm::connect(env.rank, env.world, &env.dir, epoch, env.timeout)
    }

    /// Attach a recorder (normally [`crate::trace::TraceSession::recorder`]).
    pub fn set_tracer(&mut self, tracer: RankRecorder) {
        self.tracer = tracer;
    }

    /// Drain recorded events (for absorbing into the owning session).
    pub fn take_trace_events(&mut self) -> Vec<crate::trace::Event> {
        self.tracer.take_events()
    }

    /// Drain whatever `from` has written, parsing complete frames into the
    /// unexpected queue. Never blocks.
    fn poll_peer(&mut self, from: usize) {
        let frames = {
            let peer = self.peers[from].as_mut().expect("polling self");
            if peer.eof {
                return;
            }
            let mut tmp = [0u8; 64 * 1024];
            loop {
                match peer.stream.read(&mut tmp) {
                    Ok(0) => {
                        peer.eof = true;
                        break;
                    }
                    Ok(n) => peer.buf.extend_from_slice(&tmp[..n]),
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(e) => {
                        peer.eof = true;
                        // Treat a torn connection like an EOF: frames already
                        // buffered stay deliverable, the next needed receive
                        // reports the dead peer.
                        let _ = e;
                        break;
                    }
                }
            }
            parse_frames(&mut peer.buf)
        };
        for (tag, payload) in frames {
            let prev = self.pending.insert((from, tag), payload);
            assert!(prev.is_none(), "duplicate message {from} -> {} tag {tag:#x}", self.rank);
        }
    }

    fn poll_all(&mut self) {
        for from in 0..self.n {
            if from != self.rank {
                self.poll_peer(from);
            }
        }
    }

    /// Write a whole frame to `to`, polling our own incoming frames while
    /// the socket buffer is full (prevents mutual-send deadlock).
    fn write_frame(&mut self, to: usize, tag: u64, payload: &[f64]) {
        assert!(to < self.n && to != self.rank, "bad destination {to}");
        assert!(payload.len() <= MAX_PAYLOAD_ELEMS, "payload too large");
        let bytes = encode_frame(tag, payload);
        let deadline = Instant::now() + self.timeout;
        let mut off = 0;
        while off < bytes.len() {
            let res = {
                let peer = self.peers[to].as_mut().expect("sending to self");
                peer.stream.write(&bytes[off..])
            };
            match res {
                Ok(0) => panic!("rank {to} closed its socket mid-write"),
                Ok(n) => off += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    self.poll_all();
                    if Instant::now() >= deadline {
                        panic!(
                            "rank {}: send to rank {to} tag {tag:#x} stalled for {:?} \
                             ({off}/{} bytes written)",
                            self.rank,
                            self.timeout,
                            bytes.len()
                        );
                    }
                    std::thread::sleep(POLL_SLEEP);
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => panic!(
                    "rank {}: send to rank {to} failed: {e} — peer process likely exited",
                    self.rank
                ),
            }
        }
    }

    /// Block until `(from, tag)` is deliverable; a peer EOF or the deadline
    /// turns into a clean panic instead of a hang.
    fn await_key(&mut self, from: usize, tag: u64, what: &str) -> Vec<f64> {
        let deadline = Instant::now() + self.timeout;
        loop {
            if let Some(p) = self.pending.remove(&(from, tag)) {
                return p;
            }
            self.poll_all();
            if let Some(p) = self.pending.remove(&(from, tag)) {
                return p;
            }
            if self.peers[from].as_ref().expect("receiving from self").eof {
                panic!(
                    "rank {from} exited (EOF) while rank {} awaited {what} tag {tag:#x}",
                    self.rank
                );
            }
            if Instant::now() >= deadline {
                panic!(
                    "rank {}: timed out after {:?} awaiting {what} tag {tag:#x} from rank {from}",
                    self.rank, self.timeout
                );
            }
            std::thread::sleep(POLL_SLEEP);
        }
    }

    /// Control-plane send: same framing, no stats, no trace span.
    pub(crate) fn send_ctrl(&mut self, to: usize, tag: u64, payload: Vec<f64>) {
        assert!(tag & CTRL != 0, "control send with a kernel tag {tag:#x}");
        self.write_frame(to, tag, &payload);
    }

    /// Control-plane receive: same matching, no stats, no trace span.
    pub(crate) fn recv_ctrl(&mut self, from: usize, tag: u64) -> Vec<f64> {
        assert!(tag & CTRL != 0, "control recv with a kernel tag {tag:#x}");
        self.await_key(from, tag, "control frame")
    }

    /// Rank-0-coordinated barrier carrying the round counter: every rank
    /// `p > 0` sends its `rounds` to rank 0, which asserts they all agree
    /// and broadcasts the release. A fresh generation per barrier keeps the
    /// `(from, tag)` keys unique for the whole run.
    fn barrier(&mut self) {
        self.barrier_gen += 1;
        let tag = ctrl_tag(CTRL_BARRIER, self.barrier_gen);
        let here = self.stats.rounds as f64;
        if self.rank == 0 {
            for p in 1..self.n {
                let arrive = self.recv_ctrl(p, tag);
                assert_eq!(arrive.len(), 1, "malformed barrier frame from rank {p}");
                assert_eq!(
                    arrive[0], here,
                    "round diverged: rank {p} at {}, rank 0 at {here}",
                    arrive[0]
                );
            }
            for p in 1..self.n {
                self.send_ctrl(p, tag, vec![here]);
            }
        } else {
            self.send_ctrl(0, tag, vec![here]);
            let release = self.recv_ctrl(0, tag);
            assert_eq!(release.len(), 1, "malformed barrier release");
            assert_eq!(
                release[0], here,
                "round diverged: rank 0 released at {}, rank {} at {here}",
                release[0], self.rank
            );
        }
    }
}

impl Drop for SockComm {
    fn drop(&mut self) {
        // Closing the streams (implicit) delivers EOF to every peer, so a
        // panicking rank process fails its peers fast — the socket-level
        // equivalent of ThreadComm's poison cascade. Only the listener
        // path needs explicit cleanup; the epoch suffix guarantees it is
        // ours alone.
        let _ = std::fs::remove_file(&self.own_sock);
    }
}

impl Communicator for SockComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn n_ranks(&self) -> usize {
        self.n
    }

    fn tracer(&mut self) -> &mut RankRecorder {
        &mut self.tracer
    }

    fn send(&mut self, to: usize, tag: u64, payload: Vec<f64>) {
        assert!(tag & CTRL == 0, "kernel send with a control tag {tag:#x}");
        let t0 = self.tracer.now();
        let bytes = span_bytes(payload.len());
        self.write_frame(to, tag, &payload);
        self.tracer.closed_span(Span::CommSend { to: to as u32, bytes }, t0);
    }

    fn recv(&mut self, from: usize, tag: u64) -> Vec<f64> {
        let t0 = self.tracer.now();
        let payload = self.await_key(from, tag, "message");
        account_recv(&mut self.stats, payload.len());
        self.tracer
            .closed_span(Span::CommRecv { from: from as u32, bytes: span_bytes(payload.len()) }, t0);
        payload
    }

    fn try_recv(&mut self, from: usize, tag: u64) -> Option<Vec<f64>> {
        let t0 = self.tracer.now();
        // One nonblocking drain, then complete from the unexpected queue.
        self.poll_peer(from);
        match self.pending.remove(&(from, tag)) {
            Some(payload) => {
                account_recv(&mut self.stats, payload.len());
                self.tracer.closed_span(
                    Span::CommRecv { from: from as u32, bytes: span_bytes(payload.len()) },
                    t0,
                );
                Some(payload)
            }
            None => {
                self.tracer.closed_span(Span::CommProbe { from: from as u32 }, t0);
                None
            }
        }
    }

    fn recv_any(&mut self, reqs: &[(usize, u64)]) -> (usize, Vec<f64>) {
        assert!(!reqs.is_empty(), "recv_any on an empty request set");
        let t0 = self.tracer.now();
        let deadline = Instant::now() + self.timeout;
        let (idx, payload) = loop {
            // Unexpected queue first, lowest request index winning ties —
            // the same deterministic tiebreak SimComm uses.
            if let Some(i) = reqs.iter().position(|key| self.pending.contains_key(key)) {
                break (i, self.pending.remove(&reqs[i]).unwrap());
            }
            self.poll_all();
            if let Some(i) = reqs.iter().position(|key| self.pending.contains_key(key)) {
                break (i, self.pending.remove(&reqs[i]).unwrap());
            }
            for &(from, tag) in reqs {
                if self.peers[from].as_ref().expect("receiving from self").eof {
                    panic!(
                        "rank {from} exited (EOF) while rank {} awaited tag {tag:#x} \
                         in recv_any",
                        self.rank
                    );
                }
            }
            if Instant::now() >= deadline {
                panic!(
                    "rank {}: timed out after {:?} in recv_any over {} request(s)",
                    self.rank,
                    self.timeout,
                    reqs.len()
                );
            }
            std::thread::sleep(POLL_SLEEP);
        };
        account_recv(&mut self.stats, payload.len());
        self.tracer.closed_span(
            Span::CommRecv { from: reqs[idx].0 as u32, bytes: span_bytes(payload.len()) },
            t0,
        );
        (idx, payload)
    }

    fn end_round(&mut self) {
        let wall0 = Instant::now();
        let t0 = self.tracer.now();
        self.stats.rounds += 1;
        self.barrier();
        self.stats.wait_ns.push(wall0.elapsed().as_nanos() as u64);
        self.tracer.closed_span(Span::CommWait { round: (self.stats.rounds - 1) as u32 }, t0);
    }

    fn advance_round(&mut self) {
        // Barrier-free round close for the async remainder — identical
        // semantics to ThreadComm::advance_round (see that comment for the
        // tag-safety argument).
        let t0 = self.tracer.now();
        self.stats.rounds += 1;
        self.stats.wait_ns.push(0);
        self.tracer.closed_span(Span::CommWait { round: (self.stats.rounds - 1) as u32 }, t0);
    }

    fn stats(&self) -> &CommStats {
        &self.stats
    }
}

/// Remaining time before `deadline`, as an error if already past (socket
/// timeouts reject a zero duration).
fn remaining(deadline: Instant) -> Result<Duration> {
    let now = Instant::now();
    ensure!(now < deadline, "rendezvous deadline exceeded");
    Ok(deadline - now)
}

fn encode_frame(tag: u64, payload: &[f64]) -> Vec<u8> {
    let mut b = Vec::with_capacity(HEADER_BYTES + payload.len() * 8);
    b.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
    b.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    b.extend_from_slice(&tag.to_le_bytes());
    for v in payload {
        b.extend_from_slice(&v.to_le_bytes());
    }
    b
}

/// Parse every complete frame out of `buf`, leaving a trailing partial
/// frame (if any) in place. Validates magic and payload length before
/// trusting either.
fn parse_frames(buf: &mut Vec<u8>) -> Vec<(u64, Vec<f64>)> {
    let mut out = Vec::new();
    let mut start = 0;
    while buf.len() - start >= HEADER_BYTES {
        let magic = u32::from_le_bytes(buf[start..start + 4].try_into().unwrap());
        assert_eq!(magic, FRAME_MAGIC, "corrupt frame: bad magic {magic:#x}");
        let len = u32::from_le_bytes(buf[start + 4..start + 8].try_into().unwrap()) as usize;
        assert!(len <= MAX_PAYLOAD_ELEMS, "corrupt frame: payload length {len}");
        let tag = u64::from_le_bytes(buf[start + 8..start + 16].try_into().unwrap());
        let total = HEADER_BYTES + len * 8;
        if buf.len() - start < total {
            break;
        }
        let body = &buf[start + HEADER_BYTES..start + total];
        let payload: Vec<f64> = body
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        out.push((tag, payload));
        start += total;
    }
    buf.drain(..start);
    out
}

/// Build connected [`SockComm`] endpoints for `n` ranks **in one process**
/// (each endpoint rendezvouses on its own thread — the full mesh cannot
/// complete sequentially). For tests and single-process experiments; real
/// multi-process runs construct one endpoint per process via
/// [`SockComm::from_env_for`].
pub fn sock_comms(dir: &Path, n: usize, timeout: Duration) -> Result<Vec<SockComm>> {
    let epoch = next_epoch();
    std::thread::scope(|s| {
        let joins: Vec<_> = (0..n)
            .map(|rank| s.spawn(move || SockComm::connect(rank, n, dir, epoch, timeout)))
            .collect();
        joins
            .into_iter()
            .map(|j| j.join().expect("rendezvous thread panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distsim::{merge_rank_stats, DistMatrix};
    use crate::matrix::gen;
    use crate::partition::{partition, Method};

    fn test_dir(tag: &str) -> PathBuf {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        std::env::temp_dir().join(format!(
            "dlb-mpk-sock-{}-{tag}-{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ))
    }

    fn timeout() -> Duration {
        Duration::from_secs(10)
    }

    #[test]
    fn frame_codec_roundtrips_and_handles_partial_delivery() {
        let frame_a = encode_frame(7, &[1.5, -2.25]);
        let frame_b = encode_frame(u64::MAX, &[]);
        let mut buf = Vec::new();
        // deliver frame A in two pieces
        buf.extend_from_slice(&frame_a[..HEADER_BYTES + 3]);
        assert!(parse_frames(&mut buf).is_empty(), "partial frame must wait");
        assert_eq!(buf.len(), HEADER_BYTES + 3, "partial bytes stay buffered");
        buf.extend_from_slice(&frame_a[HEADER_BYTES + 3..]);
        buf.extend_from_slice(&frame_b);
        let got = parse_frames(&mut buf);
        assert_eq!(got, vec![(7, vec![1.5, -2.25]), (u64::MAX, vec![])]);
        assert!(buf.is_empty());
    }

    #[test]
    fn rendezvous_and_halo_exchange_matches_sim() {
        let dir = test_dir("halo");
        let a = gen::stencil_2d_5pt(8, 7);
        let p = partition(&a, 3, Method::Block);
        let d = DistMatrix::build(&a, &p);
        let x: Vec<f64> = (0..a.n_rows()).map(|i| 3.0 + i as f64).collect();
        let xs = d.scatter(&x);

        // reference: sequential lockstep
        let mut xs_sim = xs.clone();
        let mut sims = super::super::sim_comms(d.n_ranks());
        super::super::lockstep_halo_exchange(&mut sims, &d.ranks, 0, &mut xs_sim);

        let comms = sock_comms(&dir, d.n_ranks(), timeout()).unwrap();
        let filled: Vec<(Vec<f64>, CommStats)> = std::thread::scope(|s| {
            let joins: Vec<_> = comms
                .into_iter()
                .zip(&d.ranks)
                .zip(xs)
                .map(|((mut c, r), mut xv)| {
                    s.spawn(move || {
                        c.exchange(r, 0, &mut xv);
                        let st = c.stats().clone();
                        (xv, st)
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().expect("rank thread panicked")).collect()
        });
        for ((xv, _), xsim) in filled.iter().zip(&xs_sim) {
            assert_eq!(
                xv.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                xsim.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            );
        }
        let per_rank: Vec<CommStats> = filled.iter().map(|(_, s)| s.clone()).collect();
        let sim_stats: Vec<CommStats> = sims.iter().map(|c| c.stats().clone()).collect();
        assert_eq!(merge_rank_stats(&per_rank), merge_rank_stats(&sim_stats));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn out_of_order_tags_buffer_exactly_once() {
        let dir = test_dir("ooo");
        let mut comms = sock_comms(&dir, 2, timeout()).unwrap();
        let mut c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        c0.send(1, 0, vec![1.0]);
        c0.send(1, 1, vec![2.0]);
        // receive out of posting order: tag 1 first
        assert_eq!(c1.recv(0, 1), vec![2.0]);
        assert_eq!(c1.recv(0, 0), vec![1.0]);
        assert_eq!(c1.stats().messages, 2);
        assert_eq!(c1.stats().bytes, 16);
        drop(c0);
        drop(c1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn try_recv_and_recv_any_complete_out_of_order() {
        let dir = test_dir("nb");
        let mut comms = sock_comms(&dir, 2, timeout()).unwrap();
        let mut c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        assert!(c1.try_recv(0, 7).is_none(), "nothing posted yet");
        assert_eq!(c1.stats().messages, 0, "a miss must not account");
        c0.send(1, 7, vec![7.0]);
        c0.send(1, 3, vec![3.0]);
        // Local unix writes are immediately readable: complete against
        // posting order, tag 3 first.
        assert_eq!(c1.try_recv(0, 3), Some(vec![3.0]));
        // recv_any skips the never-posted request and completes the
        // buffered one without blocking.
        let (i, p) = c1.recv_any(&[(0, 9), (0, 7)]);
        assert_eq!((i, p), (1, vec![7.0]));
        assert_eq!(c1.stats().messages, 2);
        assert_eq!(c1.stats().bytes, 16);
        drop(c0);
        drop(c1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn advance_round_counts_without_rendezvous() {
        let dir = test_dir("adv");
        let mut comms = sock_comms(&dir, 2, timeout()).unwrap();
        let mut c0 = comms.remove(0);
        c0.advance_round();
        assert_eq!(c0.stats().rounds, 1);
        assert_eq!(c0.stats().wait_ns, vec![0]);
        drop(c0);
        drop(comms);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn end_round_synchronizes_and_counts() {
        let dir = test_dir("barrier");
        let comms = sock_comms(&dir, 3, timeout()).unwrap();
        let stats: Vec<CommStats> = std::thread::scope(|s| {
            let joins: Vec<_> = comms
                .into_iter()
                .map(|mut c| {
                    s.spawn(move || {
                        c.end_round();
                        c.end_round();
                        c.stats().clone()
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().expect("rank panicked")).collect()
        });
        for st in &stats {
            assert_eq!(st.rounds, 2);
            assert_eq!(st.wait_ns.len(), 2);
            assert_eq!(st.messages, 0, "barrier frames must not account");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dead_rank_fails_peer_with_clean_error_not_hang() {
        let dir = test_dir("death");
        let mut comms = sock_comms(&dir, 2, Duration::from_secs(5)).unwrap();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        drop(c0); // rank 0 "process" exits before sending anything
        let t = std::thread::spawn(move || {
            let mut c1 = c1;
            let _ = c1.recv(0, 0); // must panic on EOF, not hang
        });
        assert!(t.join().is_err(), "peer must fail fast when a rank dies");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn barrier_detects_round_divergence() {
        let dir = test_dir("diverge");
        let mut comms = sock_comms(&dir, 2, Duration::from_secs(5)).unwrap();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        let t0 = std::thread::spawn(move || {
            let mut c0 = c0;
            c0.end_round(); // rank 0 arrives with rounds=1
        });
        let t1 = std::thread::spawn(move || {
            let mut c1 = c1;
            c1.advance_round(); // skips ahead: rounds=1 without rendezvous
            c1.end_round(); // arrives with rounds=2 -> divergence
        });
        // Rank 0 asserts the mismatch; rank 1 then sees EOF instead of the
        // release. Both fail, neither hangs.
        assert!(t0.join().is_err());
        assert!(t1.join().is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ctrl_frames_bypass_stats() {
        let dir = test_dir("ctrl");
        let mut comms = sock_comms(&dir, 2, timeout()).unwrap();
        let mut c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        let tag = ctrl_tag(CTRL_GATHER, 1);
        c0.send_ctrl(1, tag, vec![42.0, 43.0]);
        assert_eq!(c1.recv_ctrl(0, tag), vec![42.0, 43.0]);
        assert_eq!(c1.stats().messages, 0);
        assert_eq!(c1.stats().bytes, 0);
        drop(c0);
        drop(c1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rank_env_roundtrip_ignores_absent() {
        // Can't mutate the test process env safely in parallel tests;
        // just assert absence of the variables parses as None.
        if std::env::var("DLB_MPK_RANK").is_err() {
            assert!(RankEnv::from_env().is_none());
        }
    }
}
