//! Rank executors: actually *run* the distributed MPK variants.
//!
//! The [`crate::distsim`] layer defines what a rank owns and what must move
//! between ranks; the [`crate::mpk`] kernels are written as **single-rank
//! functions** against the [`Communicator`] halo-exchange contract
//! (`trad_rank`, `dlb_rank`, `ca_rank`). This module supplies three ways
//! to execute them:
//!
//! * **Sim** ([`SimComm`] + [`lockstep_halo_exchange`]) — all ranks advance
//!   round-by-round inside one thread, exactly like the original counting
//!   simulator. Byte/message/round accounting is bit-identical to the
//!   legacy `exchange_halo` loop, so every figure and counter in the repo
//!   is unchanged.
//! * **Threads** ([`ThreadComm`] + [`trad_threaded`]/[`dlb_threaded`]/
//!   [`ca_threaded`]) — one OS thread per rank, real point-to-point
//!   messages over `std::sync::mpsc` channels, a round barrier, and
//!   *measured* parallel wall-clock. DLB's remainder-round sends are posted
//!   as soon as their payload rows are final, overlapping communication
//!   with the cache-blocked wavefront (paper §5).
//! * **Processes** ([`SockComm`]) — every rank is a separate OS *process*
//!   exchanging framed messages over Unix-domain sockets; the stand-in
//!   for (and template of) a real MPI transport. Launched SPMD-style via
//!   `dlb-mpk launch --np N -- <cmd>` or any launcher that sets the
//!   `DLB_MPK_RANK`/`DLB_MPK_WORLD` env protocol ([`RankEnv`]).
//!
//! All executors produce bitwise-identical `powers` and identical merged
//! [`crate::distsim::CommStats`] (cross-validated in
//! `rust/tests/exec_equivalence.rs` and `rust/tests/sock_proc.rs`); only
//! wall-clock differs.
//!
//! The **primary public entry point** over these executors is
//! [`crate::engine::MpkEngine`] — a prepare-once/apply-many session that
//! owns the variant plans, reuses workspaces, and (for the threads
//! executor) keeps a *persistent rank pool* instead of spawning `n_ranks`
//! threads per call the way [`trad_threaded`]/[`dlb_threaded`]/
//! [`ca_threaded`] do. Those spawn-per-sweep drivers remain for one-shot
//! runs and as the baseline the pool is benchmarked against
//! (`benches/fig10_strong_scaling.rs`). [`ExecutorKind`] is the
//! `sim | threads(n)` knob wired through the engine builder,
//! [`crate::coordinator::RunConfig`], and the CLI; [`run`] is the low-level
//! one-shot variant dispatcher mirroring [`crate::mpk::run`].

pub mod comm;
pub mod executor;
pub mod sock;

pub use comm::{
    lockstep_halo_exchange, sim_comms, thread_comms, Communicator, SimComm, ThreadComm,
};
pub use executor::{ca_threaded, dlb_threaded, run, trad_threaded, ExecutorKind};
pub use sock::{next_epoch, sock_comms, RankEnv, SockComm};

/// What a single-rank kernel produces: the local power vectors plus the
/// rank's share of the flop count. `ys[p]` is the local vector of power
/// `p` (`ys[0]` = the input); only the first `n_local` entries of each are
/// meaningful to the caller (halo tails are scratch).
pub struct RankRun {
    pub ys: Vec<Vec<f64>>,
    pub flop_nnz: usize,
}
