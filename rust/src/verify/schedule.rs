//! Analyzer 1 — schedule race detector.
//!
//! Machine-checks the batching argument of [`crate::race::schedule`]: the
//! phase-2 wavefront schedule is a valid capped schedule, its
//! `parallel_batches` flatten back to the same step multiset *and* to a
//! valid order, and every pair of steps sharing a batch is independent
//! under the three hand-argued rules (same-power row-disjointness, Δp = 1
//! level-window separation, Δp = 2 `prev2` row-disjointness). Each
//! violation names the rule, the two conflicting steps, and the
//! overlapping rows.
//!
//! Level spans are reconstructed from the plan's row `ranges` via
//! [`crate::graph::Levels::level_of_row`], so a span never reports an
//! empty level it does not actually own — reconstruction can only shrink
//! a span, which weakens the dependency window in the safe direction (no
//! false alarms; a real adjacent-level conflict always involves non-empty
//! levels).

use crate::distsim::RankLocal;
use crate::graph::Levels;
use crate::mpk::dlb::DlbRankPlan;
use crate::race::schedule::Step;

use super::{Diagnostic, Rule};

/// Verify one rank's phase-2 schedule and batches (see module docs).
pub fn check_rank_schedule(rank: usize, r: &RankLocal, pl: &DlbRankPlan) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let nl = r.n_local();
    let n_groups = pl.ranges.len();
    if n_groups == 0 {
        if !pl.schedule.is_empty() || pl.batches.iter().any(|b| !b.is_empty()) {
            out.push(Diagnostic::new(
                Rule::SchedBatchMismatch,
                Some(rank),
                "steps scheduled over zero groups".into(),
            ));
        }
        return out;
    }

    // Group ranges must tile [0, nl) contiguously — every other check
    // (row disjointness across groups, span reconstruction) builds on it.
    let mut prev_hi = 0usize;
    for (g, &(lo, hi)) in pl.ranges.iter().enumerate() {
        if lo != prev_hi || hi < lo {
            out.push(Diagnostic::new(
                Rule::SchedGroupRanges,
                Some(rank),
                format!("group {g} range [{lo}, {hi}) does not continue from {prev_hi}"),
            ));
            return out;
        }
        prev_hi = hi;
    }
    if prev_hi != nl {
        out.push(Diagnostic::new(
            Rule::SchedGroupRanges,
            Some(rank),
            format!("group ranges end at {prev_hi}, expected n_local = {nl}"),
        ));
        return out;
    }

    let spans = reconstruct_spans(&pl.levels, &pl.ranges);
    let n_levels = pl.levels.n_levels();

    out.extend(check_order(rank, "schedule", &pl.schedule, &spans, n_levels, &pl.caps));

    // Batches: same multiset as the schedule, valid when concatenated,
    // pairwise independent within each batch.
    let flat: Vec<Step> = pl.batches.iter().flatten().copied().collect();
    let key = |s: &Step| (s.group, s.power);
    let mut a: Vec<Step> = pl.schedule.clone();
    let mut b = flat.clone();
    a.sort_unstable_by_key(key);
    b.sort_unstable_by_key(key);
    if a != b {
        out.push(Diagnostic::new(
            Rule::SchedBatchMismatch,
            Some(rank),
            format!(
                "batches flatten to {} steps, schedule has {} (different multiset)",
                flat.len(),
                pl.schedule.len()
            ),
        ));
    } else {
        out.extend(check_order(rank, "batch concatenation", &flat, &spans, n_levels, &pl.caps));
    }
    for (bi, batch) in pl.batches.iter().enumerate() {
        for (i, &x) in batch.iter().enumerate() {
            for &y in &batch[i + 1..] {
                if let Some(d) = dependent(rank, bi, x, y, &spans, &pl.ranges, &pl.levels) {
                    out.push(d);
                }
            }
        }
    }
    out
}

/// Per-group level spans `[lo, hi)` recovered from the row ranges.
fn reconstruct_spans(levels: &Levels, ranges: &[(usize, usize)]) -> Vec<(usize, usize)> {
    ranges
        .iter()
        .map(|&(lo, hi)| {
            if hi <= lo {
                (0, 0)
            } else {
                (levels.level_of_row(lo), levels.level_of_row(hi - 1) + 1)
            }
        })
        .collect()
}

/// The `validate_schedule` algorithm of [`crate::race::schedule`],
/// generalized to per-group caps and diagnostic output: every step
/// advances its group by exactly one power, never before every group
/// covering its levels ± 1 reached `power - 1`, and each group finishes
/// at its cap.
fn check_order(
    rank: usize,
    what: &str,
    steps: &[Step],
    spans: &[(usize, usize)],
    n_levels: usize,
    caps: &[usize],
) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let n_groups = spans.len();
    let mut gl_lo = vec![usize::MAX; n_levels];
    let mut gl_hi = vec![0usize; n_levels];
    for (g, &(lo, hi)) in spans.iter().enumerate() {
        for l in lo..hi {
            gl_lo[l] = gl_lo[l].min(g);
            gl_hi[l] = gl_hi[l].max(g);
        }
    }
    let mut pow = vec![0usize; n_groups];
    for (i, s) in steps.iter().enumerate() {
        if s.group >= n_groups {
            out.push(Diagnostic::new(
                Rule::SchedPowerJump,
                Some(rank),
                format!("{what} step {i}: group {} out of range ({n_groups} groups)", s.group),
            ));
            continue;
        }
        if s.power != pow[s.group] + 1 {
            out.push(Diagnostic::new(
                Rule::SchedPowerJump,
                Some(rank),
                format!(
                    "{what} step {i}: group {} jumps from power {} to {}",
                    s.group, pow[s.group], s.power
                ),
            ));
        }
        let (lo, hi) = spans[s.group];
        let dep_lo = lo.saturating_sub(1);
        let dep_hi = (hi + 1).min(n_levels);
        for l in dep_lo..dep_hi {
            if gl_lo[l] == usize::MAX {
                continue; // empty level: no group to depend on
            }
            for h in gl_lo[l]..=gl_hi[l] {
                if h != s.group && pow[h] + 1 < s.power {
                    out.push(Diagnostic::new(
                        Rule::SchedDepUnmet,
                        Some(rank),
                        format!(
                            "{what} step {i}: (g{}, p{}) runs while dependency group {h} \
                             (level {l}) is at power {} < {}",
                            s.group,
                            s.power,
                            pow[h],
                            s.power - 1
                        ),
                    ));
                }
            }
        }
        pow[s.group] = s.power;
    }
    for (g, (&p, &cap)) in pow.iter().zip(caps).enumerate() {
        if p != cap {
            out.push(Diagnostic::new(
                Rule::SchedIncomplete,
                Some(rank),
                format!("{what}: group {g} finishes at power {p}, cap is {cap}"),
            ));
        }
    }
    out
}

/// Pairwise independence of two same-batch steps — `None` if independent,
/// otherwise the diagnostic naming the violated rule and the overlap.
fn dependent(
    rank: usize,
    batch: usize,
    x: Step,
    y: Step,
    spans: &[(usize, usize)],
    ranges: &[(usize, usize)],
    levels: &Levels,
) -> Option<Diagnostic> {
    if x.group == y.group {
        return Some(Diagnostic::new(
            Rule::SchedBatchSameGroup,
            Some(rank),
            format!(
                "batch {batch}: (g{}, p{}) and (g{}, p{}) touch the same group",
                x.group, x.power, y.group, y.power
            ),
        ));
    }
    match x.power.abs_diff(y.power) {
        // Same write buffer (Δp = 0), or the higher step's prev-2 read is
        // the lower step's write buffer (Δp = 2): safe iff row-disjoint.
        0 | 2 => {
            let (alo, ahi) = ranges[x.group];
            let (blo, bhi) = ranges[y.group];
            let olo = alo.max(blo);
            let ohi = ahi.min(bhi);
            (olo < ohi).then(|| {
                Diagnostic::new(
                    Rule::SchedBatchRowOverlap,
                    Some(rank),
                    format!(
                        "batch {batch}: (g{}, p{}) and (g{}, p{}) share rows [{olo}, {ohi})",
                        x.group, x.power, y.group, y.power
                    ),
                )
            })
        }
        // Δp = 1: the higher-power step reads levels span ± 1 of the
        // lower-power step's freshly written buffer.
        1 => {
            let (rd, wr) = if x.power > y.power { (x, y) } else { (y, x) };
            let (rlo, rhi) = spans[rd.group];
            let (wlo, whi) = spans[wr.group];
            if whi < rlo || wlo > rhi {
                return None;
            }
            // Counterexample rows: the reader's dependency window clipped
            // to the writer's range.
            let n_levels = levels.n_levels();
            let win_lo = levels.level_ptr[rlo.saturating_sub(1).min(n_levels)];
            let win_hi = levels.level_ptr[(rhi + 1).min(n_levels)];
            let (wr_lo, wr_hi) = ranges[wr.group];
            let olo = win_lo.max(wr_lo);
            let ohi = win_hi.min(wr_hi);
            Some(Diagnostic::new(
                Rule::SchedBatchAdjLevels,
                Some(rank),
                format!(
                    "batch {batch}: reader (g{}, p{}) levels [{rlo}, {rhi}) overlaps writer \
                     (g{}, p{}) levels [{wlo}, {whi}); conflicting rows [{olo}, {ohi})",
                    rd.group, rd.power, wr.group, wr.power
                ),
            ))
        }
        // Δp ≥ 3: different buffers in the three-term window; the only
        // cross-buffer read (prev-2) is two powers down, handled above.
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distsim::DistMatrix;
    use crate::matrix::gen;
    use crate::mpk::dlb;

    fn plan(np: usize, p_m: usize) -> (DistMatrix, dlb::DlbPlan) {
        let a = gen::stencil_2d_5pt(12, 12);
        let part = crate::partition::partition(&a, np, crate::partition::Method::Block);
        let dist = DistMatrix::build(&a, &part);
        let plan = dlb::plan(&dist, p_m, &dlb::DlbOptions::default());
        ((*plan.dist).clone(), plan)
    }

    #[test]
    fn real_plans_pass() {
        for (np, p_m) in [(1, 1), (2, 2), (3, 4)] {
            let (dist, plan) = plan(np, p_m);
            for (rank, (r, pl)) in dist.ranks.iter().zip(&plan.ranks).enumerate() {
                let diags = check_rank_schedule(rank, r, pl);
                assert!(diags.is_empty(), "rank {rank}: {}", super::super::render(&diags));
            }
        }
    }

    #[test]
    fn merged_batches_are_rejected() {
        let (dist, mut plan) = plan(2, 4);
        // Merge the first two non-empty adjacent batches of some rank:
        // consecutive fronts are dependent by construction.
        let (rank, pl) = plan
            .ranks
            .iter_mut()
            .enumerate()
            .find(|(_, pl)| pl.batches.len() >= 2)
            .expect("a rank with >= 2 batches");
        let merged = pl.batches.remove(1);
        pl.batches[0].extend(merged);
        let diags = check_rank_schedule(rank, &dist.ranks[rank], pl);
        assert!(
            diags.iter().any(|d| matches!(
                d.rule,
                Rule::SchedBatchAdjLevels | Rule::SchedBatchRowOverlap | Rule::SchedBatchSameGroup
            )),
            "expected a batch-independence diagnostic, got: {}",
            super::super::render(&diags)
        );
    }

    #[test]
    fn swapped_schedule_steps_are_rejected() {
        let (dist, mut plan) = plan(2, 2);
        let (rank, pl) = plan
            .ranks
            .iter_mut()
            .enumerate()
            .find(|(_, pl)| pl.schedule.len() >= 2)
            .expect("a rank with >= 2 steps");
        let last = pl.schedule.len() - 1;
        pl.schedule.swap(0, last);
        let diags = check_rank_schedule(rank, &dist.ranks[rank], pl);
        assert!(
            diags.iter().any(|d| matches!(d.rule, Rule::SchedDepUnmet | Rule::SchedPowerJump)),
            "expected an order diagnostic, got: {}",
            super::super::render(&diags)
        );
    }
}
