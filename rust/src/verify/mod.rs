//! Static race & communication-plan verification.
//!
//! The kernels' safety rests on *structural* claims: wavefront batches are
//! pairwise independent (so [`crate::inner`]'s raw-pointer views never
//! alias a concurrent write), every send plan meets exactly one matching
//! recv plan (so the transports' `(from, tag)` matching delivers exactly
//! once, deadlock-free), and DLB's async remainder split
//! (`seg_rows`/`multi_rows`) partitions class `I_1` with each segment
//! reading only its feeding peer's halo slots. All of these are decidable
//! from the level structure and the plans alone — the same observation the
//! paper's level-based dependency analysis (RACE's reachability rule)
//! builds on — so this module checks them *before execution*, every time.
//!
//! Four analyzers, each returning [`Diagnostic`]s with stable rule IDs and
//! a concrete counterexample (the conflicting steps / rows / peers):
//!
//! 1. [`schedule`] — schedule race detector: machine-checks the
//!    hand-argued batching rules of [`crate::race::schedule`] (same-power
//!    row-disjointness, Δp = 1 level-window separation, Δp = 2 `prev2`
//!    row-disjointness) and that the batch concatenation is a valid capped
//!    schedule.
//! 2. [`alias`] — aliasing checker for inner splits: every
//!    `InnerWork::{Range,Rows}` decomposition (`split_range`,
//!    `contiguous_runs`, CA promote rounds) writes disjoint row sets per
//!    worker before any raw-pointer view exists.
//! 3. [`comm`] — communication-plan checker: exactly-once send/recv
//!    matching across ranks, payload/byte agreement, halo-slot tiling, a
//!    round-ordered progress simulation that detects deadlock, and the
//!    cross-sweep tag discipline of the barrier-free async path.
//! 4. [`partition`] — DLB partition checker: `seg_rows[j] ∪ multi_rows`
//!    exactly partitions `class_ranges[0]` and each `seg_rows[j]` row
//!    reads only halo slots owned by recv plan `j`.
//!
//! Entry points: [`Verifier::check_all`] (full DLB plan),
//! [`Verifier::check_trad`] / [`Verifier::check_ca`], all wired into
//! [`crate::engine::MpkEngine`] prepare time behind
//! `MpkEngine::builder().verify_plans(true)` (default-on in debug builds)
//! and the `dlb-mpk verify` CLI subcommand. Verification never runs on the
//! sweep hot path.
//!
//! # Rule IDs are a contract
//!
//! Every [`Diagnostic`] carries a [`Rule`] whose [`Rule::id`] string
//! (`SCHED_BATCH_ROW_OVERLAP`, `COMM_DEADLOCK`, …) is **stable**: CI greps
//! them, the negative tests assert on them, and external tooling may key
//! on them — never renumber, rename, or reuse one. The closed vocabulary
//! is [`Rule::ALL`] (33 rules), documented one-by-one with failure
//! exemplars in `docs/VERIFY.md`. `dlb-mpk verify --rule <ID>` filters a
//! report to a single rule ([`Report::retain_rule`]) and the subcommand
//! exits with a machine-readable code: `0` clean, `1` usage/build error
//! (e.g. an unknown rule ID), `2` diagnostics found (the JSON report on
//! stdout lists them).

pub mod alias;
pub mod comm;
pub mod partition;
pub mod schedule;

use crate::distsim::DistMatrix;
use crate::mpk::ca::CaExecPlan;
use crate::mpk::dlb::DlbRankPlan;

/// Stable rule identifiers — one per checked invariant. Negative tests
/// (`rust/tests/verify_negative.rs`) assert on [`Rule::id`] strings, so
/// these names are part of the crate's diagnostic contract: never renumber
/// or reuse them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rule {
    // -- schedule race detector -----------------------------------------
    /// Group ranges do not tile `[0, n_local)` contiguously.
    SchedGroupRanges,
    /// A step advances a group by more than one power.
    SchedPowerJump,
    /// A step runs before a dependency group reached `power - 1`.
    SchedDepUnmet,
    /// A group's final power differs from its cap.
    SchedIncomplete,
    /// Batches flatten to a different step multiset than the schedule.
    SchedBatchMismatch,
    /// One batch contains the same group twice.
    SchedBatchSameGroup,
    /// Same-batch steps with `Δp ∈ {0, 2}` write/read overlapping rows.
    SchedBatchRowOverlap,
    /// Same-batch steps one power apart whose level spans are adjacent
    /// (the writer intersects the reader's ±1 dependency window).
    SchedBatchAdjLevels,
    // -- inner-split aliasing checker -----------------------------------
    /// A split emits overlapping chunks (two workers would write one row).
    AliasSplitOverlap,
    /// A split loses rows (chunks do not cover the input range).
    AliasSplitGap,
    /// `contiguous_runs` does not reproduce its input row list exactly.
    AliasRunsMismatch,
    /// CA promote-round row lists (owned ∪ live external classes) overlap.
    AliasCaRowsOverlap,
    // -- communication-plan checker -------------------------------------
    /// A plan names the rank itself as peer.
    CommSelfMessage,
    /// A plan names a peer outside `[0, n_ranks)`.
    CommPeerRange,
    /// Two plans for the same (rank, peer) direction.
    CommDuplicatePlan,
    /// A send plan has no matching recv plan at the destination.
    CommSendUnmatched,
    /// A recv plan has no matching send plan at the source.
    CommRecvUnmatched,
    /// Matched send/recv plans disagree on element count.
    CommLenMismatch,
    /// Matched plans disagree on *which* global rows travel.
    CommPayloadMismatch,
    /// A send plan row index is outside the sender's local rows.
    CommSendRowRange,
    /// Two recv plans claim the same halo slot.
    CommSlotOverlap,
    /// Halo slots not covered by any recv plan.
    CommSlotGap,
    /// A recv plan's slots hold globals not owned by its source peer.
    CommSlotOwner,
    /// The round-ordered progress simulation stalls: some rank blocks
    /// forever on a receive no peer ever posts (missing send or wait
    /// cycle).
    CommDeadlock,
    /// A tag is reused within one sweep without an intervening barrier.
    CommTagReuse,
    /// The sweep's final round closes without a barrier, so the next
    /// sweep's tag reuse could match this sweep's in-flight messages.
    CommNoFinalBarrier,
    // -- CA exchange-plan checker ---------------------------------------
    /// The CA recv plans do not cover the external classes exactly once.
    CaExtCoverage,
    // -- DLB partition checker ------------------------------------------
    /// `seg_rows` has a different peer count than the recv plans.
    DlbSegCount,
    /// A segment row list is not sorted ascending.
    DlbSegUnsorted,
    /// A row appears in two segments (or a segment and `multi_rows`).
    DlbPartitionOverlap,
    /// A class-`I_1` row appears in no segment and not in `multi_rows`.
    DlbPartitionGap,
    /// A segment/multi row lies outside `class_ranges[0]`.
    DlbPartitionRange,
    /// A `seg_rows[j]` row reads a halo slot owned by a different peer.
    DlbSegForeignSlot,
}

impl Rule {
    /// Every rule, in declaration order — the closed diagnostic vocabulary
    /// (listed with prose in `docs/VERIFY.md`). `dlb-mpk verify --rule ID`
    /// validates against this table, and the unit tests assert that the
    /// [`Rule::id`]/[`Rule::parse`] pair is a bijection over it.
    pub const ALL: [Rule; 33] = [
        Self::SchedGroupRanges,
        Self::SchedPowerJump,
        Self::SchedDepUnmet,
        Self::SchedIncomplete,
        Self::SchedBatchMismatch,
        Self::SchedBatchSameGroup,
        Self::SchedBatchRowOverlap,
        Self::SchedBatchAdjLevels,
        Self::AliasSplitOverlap,
        Self::AliasSplitGap,
        Self::AliasRunsMismatch,
        Self::AliasCaRowsOverlap,
        Self::CommSelfMessage,
        Self::CommPeerRange,
        Self::CommDuplicatePlan,
        Self::CommSendUnmatched,
        Self::CommRecvUnmatched,
        Self::CommLenMismatch,
        Self::CommPayloadMismatch,
        Self::CommSendRowRange,
        Self::CommSlotOverlap,
        Self::CommSlotGap,
        Self::CommSlotOwner,
        Self::CommDeadlock,
        Self::CommTagReuse,
        Self::CommNoFinalBarrier,
        Self::CaExtCoverage,
        Self::DlbSegCount,
        Self::DlbSegUnsorted,
        Self::DlbPartitionOverlap,
        Self::DlbPartitionGap,
        Self::DlbPartitionRange,
        Self::DlbSegForeignSlot,
    ];

    /// Look up a rule by its stable ID (`"COMM_DEADLOCK"` →
    /// [`Rule::CommDeadlock`]); `None` for an unknown ID. Inverse of
    /// [`Rule::id`].
    pub fn parse(id: &str) -> Option<Rule> {
        Self::ALL.into_iter().find(|r| r.id() == id)
    }

    /// The stable diagnostic identifier (see the enum docs).
    pub const fn id(self) -> &'static str {
        match self {
            Self::SchedGroupRanges => "SCHED_GROUP_RANGES",
            Self::SchedPowerJump => "SCHED_POWER_JUMP",
            Self::SchedDepUnmet => "SCHED_DEP_UNMET",
            Self::SchedIncomplete => "SCHED_INCOMPLETE",
            Self::SchedBatchMismatch => "SCHED_BATCH_STEP_MISMATCH",
            Self::SchedBatchSameGroup => "SCHED_BATCH_SAME_GROUP",
            Self::SchedBatchRowOverlap => "SCHED_BATCH_ROW_OVERLAP",
            Self::SchedBatchAdjLevels => "SCHED_BATCH_ADJ_LEVELS",
            Self::AliasSplitOverlap => "ALIAS_SPLIT_OVERLAP",
            Self::AliasSplitGap => "ALIAS_SPLIT_GAP",
            Self::AliasRunsMismatch => "ALIAS_RUNS_MISMATCH",
            Self::AliasCaRowsOverlap => "ALIAS_CA_ROWS_OVERLAP",
            Self::CommSelfMessage => "COMM_SELF_MESSAGE",
            Self::CommPeerRange => "COMM_PEER_RANGE",
            Self::CommDuplicatePlan => "COMM_DUPLICATE_PLAN",
            Self::CommSendUnmatched => "COMM_SEND_UNMATCHED",
            Self::CommRecvUnmatched => "COMM_RECV_UNMATCHED",
            Self::CommLenMismatch => "COMM_LEN_MISMATCH",
            Self::CommPayloadMismatch => "COMM_PAYLOAD_MISMATCH",
            Self::CommSendRowRange => "COMM_SEND_ROW_RANGE",
            Self::CommSlotOverlap => "COMM_SLOT_OVERLAP",
            Self::CommSlotGap => "COMM_SLOT_GAP",
            Self::CommSlotOwner => "COMM_SLOT_OWNER",
            Self::CommDeadlock => "COMM_DEADLOCK",
            Self::CommTagReuse => "COMM_TAG_REUSE",
            Self::CommNoFinalBarrier => "COMM_NO_FINAL_BARRIER",
            Self::CaExtCoverage => "CA_EXT_COVERAGE",
            Self::DlbSegCount => "DLB_SEG_COUNT",
            Self::DlbSegUnsorted => "DLB_SEG_UNSORTED",
            Self::DlbPartitionOverlap => "DLB_PARTITION_OVERLAP",
            Self::DlbPartitionGap => "DLB_PARTITION_GAP",
            Self::DlbPartitionRange => "DLB_PARTITION_RANGE",
            Self::DlbSegForeignSlot => "DLB_SEG_FOREIGN_SLOT",
        }
    }
}

/// One verification failure: rule + offending rank + counterexample text
/// (the conflicting steps, rows, or peers).
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub rule: Rule,
    /// Which rank's plan is at fault (`None` for cross-rank properties).
    pub rank: Option<usize>,
    pub detail: String,
}

impl Diagnostic {
    pub(crate) fn new(rule: Rule, rank: Option<usize>, detail: String) -> Self {
        Self { rule, rank, detail }
    }
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.rank {
            Some(r) => write!(f, "[{}] rank {r}: {}", self.rule.id(), self.detail),
            None => write!(f, "[{}] {}", self.rule.id(), self.detail),
        }
    }
}

/// The outcome of one verification pass: how many analyzer checks ran and
/// every diagnostic they produced.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// Number of analyzer passes executed (a passing report with
    /// `checks == 0` means nothing was actually verified).
    pub checks: usize,
    pub diags: Vec<Diagnostic>,
}

impl Report {
    pub fn is_ok(&self) -> bool {
        self.diags.is_empty()
    }

    /// Whether any diagnostic carries the given stable rule ID (what the
    /// adversarial negative tests assert on).
    pub fn has_rule(&self, id: &str) -> bool {
        self.diags.iter().any(|d| d.rule.id() == id)
    }

    /// Keep only diagnostics of one rule (the `dlb-mpk verify --rule ID`
    /// filter). `checks` is left as-is: the analyzers still ran; the caller
    /// chose to look at one invariant.
    pub fn retain_rule(&mut self, rule: Rule) {
        self.diags.retain(|d| d.rule == rule);
    }

    pub(crate) fn absorb(&mut self, diags: Vec<Diagnostic>) {
        self.checks += 1;
        self.diags.extend(diags);
    }

    /// `Ok(())` or an error listing every diagnostic.
    pub fn into_result(self) -> anyhow::Result<()> {
        anyhow::ensure!(self.is_ok(), "plan verification failed:\n{self}");
        Ok(())
    }

    /// Structured JSON (`{"ok":…,"checks":…,"diagnostics":[…]}`), parseable
    /// by [`crate::util::json::Json::parse`]. Hand-built like the chrome
    /// trace export — the crate carries no serializer.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(64 + self.diags.len() * 96);
        s.push_str(&format!(
            "{{\"ok\": {}, \"checks\": {}, \"diagnostics\": [",
            self.is_ok(),
            self.checks
        ));
        for (i, d) in self.diags.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            let rank = d.rank.map_or("null".to_string(), |r| r.to_string());
            s.push_str(&format!(
                "{{\"rule\": \"{}\", \"rank\": {rank}, \"detail\": \"{}\"}}",
                d.rule.id(),
                json_escape(&d.detail)
            ));
        }
        s.push_str("]}");
        s
    }
}

impl std::fmt::Display for Report {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for d in &self.diags {
            writeln!(f, "  {d}")?;
        }
        write!(f, "  ({} diagnostics over {} checks)", self.diags.len(), self.checks)
    }
}

/// Escape a string for embedding in a JSON literal.
pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// The static analysis pass over schedules, rank plans, and inner work
/// splits. Stateless apart from the configured inner-thread count (which
/// decides the splits analyzer 2 must prove disjoint).
#[derive(Clone, Copy, Debug)]
pub struct Verifier {
    /// Inner participants per rank whose work splits are checked. The
    /// split functions are checked with at least 2 participants even when
    /// the engine runs serially, so the decomposition logic itself is
    /// always covered.
    pub inner_threads: usize,
}

impl Default for Verifier {
    fn default() -> Self {
        Self::new()
    }
}

impl Verifier {
    pub fn new() -> Self {
        Self { inner_threads: 1 }
    }

    pub fn with_inner_threads(k: usize) -> Self {
        Self { inner_threads: k.max(1) }
    }

    fn split_k(&self) -> usize {
        self.inner_threads.max(2)
    }

    /// Verify a full DLB plan: per-rank schedule races, inner-split
    /// aliasing, the `seg_rows`/`multi_rows` partition, the cross-rank
    /// communication plans, round progress, and the async tag discipline.
    /// `plans` is [`crate::mpk::dlb::DlbPlan::ranks`]; `p_m` its block
    /// size.
    pub fn check_all(&self, dist: &DistMatrix, plans: &[DlbRankPlan], p_m: usize) -> Report {
        let mut rep = Report::default();
        rep.absorb(comm::check_dist(dist));
        rep.absorb(comm::check_progress_dist(dist, p_m));
        let async_remainder = plans.first().is_some_and(|pl| pl.async_remainder);
        rep.absorb(comm::check_tag_rounds(&comm::dlb_rounds(p_m, async_remainder)));
        for (rank, (r, pl)) in dist.ranks.iter().zip(plans).enumerate() {
            rep.absorb(schedule::check_rank_schedule(rank, r, pl));
            rep.absorb(alias::check_dlb_alias(rank, r, pl, self.split_k()));
            rep.absorb(partition::check_rank_partition(rank, r, pl));
        }
        rep
    }

    /// Verify a TRAD session: cross-rank plans, `p_m` lockstep rounds of
    /// progress, the per-round tag sequence, and the full-sweep row split.
    pub fn check_trad(&self, dist: &DistMatrix, p_m: usize) -> Report {
        let mut rep = Report::default();
        rep.absorb(comm::check_dist(dist));
        rep.absorb(comm::check_progress_dist(dist, p_m));
        rep.absorb(comm::check_tag_rounds(&comm::trad_rounds(p_m)));
        for (rank, r) in dist.ranks.iter().enumerate() {
            rep.absorb(alias::check_split(rank, 0, r.n_local(), self.split_k()));
        }
        rep
    }

    /// Verify a CA session: the extended-exchange plan (exactly-once,
    /// payload-exact, covering the external classes), its single tagged
    /// round, and the promote-round row-list disjointness.
    pub fn check_ca(&self, dist: &DistMatrix, plan: &CaExecPlan) -> Report {
        let mut rep = Report::default();
        rep.absorb(comm::check_ca_plans(dist, plan));
        rep.absorb(comm::check_tag_rounds(&comm::ca_rounds()));
        for (rank, r) in dist.ranks.iter().enumerate() {
            rep.absorb(alias::check_ca_alias(
                rank,
                &r.owned,
                &plan.ext[rank],
                plan.p_m,
                self.split_k(),
            ));
        }
        rep
    }
}

/// Cheap per-rank facts for `debug_assert!` hooks inside the kernels
/// (TRAD/CA have no per-rank plan beyond the rank local): recv slots tile
/// the halo, send rows are in range. Cross-rank matching needs all ranks
/// and runs at engine prepare time instead.
pub fn debug_check_rank(r: &crate::distsim::RankLocal) -> Vec<Diagnostic> {
    comm::check_rank_local(r.rank, r)
}

/// Per-rank DLB facts for the `debug_assert!` hook in
/// [`crate::mpk::dlb::dlb_rank`]: local comm layout, schedule/batches, and
/// the async partition.
pub fn debug_check_dlb_rank(r: &crate::distsim::RankLocal, pl: &DlbRankPlan) -> Vec<Diagnostic> {
    let mut out = comm::check_rank_local(r.rank, r);
    out.extend(schedule::check_rank_schedule(r.rank, r, pl));
    out.extend(partition::check_rank_partition(r.rank, r, pl));
    out
}

/// Render diagnostics for `debug_assert!` messages.
pub fn render(diags: &[Diagnostic]) -> String {
    diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("; ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_ids_are_a_bijection_over_all() {
        let mut seen = std::collections::BTreeSet::new();
        for r in Rule::ALL {
            assert!(seen.insert(r.id()), "duplicate rule ID {}", r.id());
            assert_eq!(Rule::parse(r.id()), Some(r), "parse must invert id for {}", r.id());
        }
        assert_eq!(seen.len(), Rule::ALL.len());
        assert_eq!(Rule::parse("NOT_A_RULE"), None);
        assert_eq!(Rule::parse("comm_deadlock"), None, "IDs are case-sensitive");
    }

    #[test]
    fn retain_rule_filters_diagnostics_only() {
        let mut rep = Report::default();
        rep.absorb(vec![
            Diagnostic::new(Rule::CommDeadlock, Some(1), "stall".into()),
            Diagnostic::new(Rule::SchedPowerJump, None, "jump".into()),
            Diagnostic::new(Rule::CommDeadlock, Some(2), "stall".into()),
        ]);
        rep.retain_rule(Rule::CommDeadlock);
        assert_eq!(rep.diags.len(), 2);
        assert!(rep.diags.iter().all(|d| d.rule == Rule::CommDeadlock));
        assert_eq!(rep.checks, 1, "retain_rule must not rewrite the check count");
        rep.retain_rule(Rule::AliasSplitGap);
        assert!(rep.is_ok(), "filtering to an untriggered rule empties the report");
    }
}
