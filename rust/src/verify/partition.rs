//! Analyzer 4 — DLB async-remainder partition checker.
//!
//! The async remainder's correctness argument has two structural legs
//! (see [`crate::mpk::dlb`]): `seg_rows[j] ∪ multi_rows` must *exactly*
//! partition class `I_1` (`class_ranges[0]`) — every boundary row advanced
//! exactly once per round, in any completion order — and every
//! `seg_rows[j]` row must read halo slots of recv plan `j` *only*, so the
//! row really is final the moment peer `j`'s message lands. This analyzer
//! proves both from the plan and the local matrix: a mark sweep over
//! `class_ranges[0]` for the partition, and a halo-column scan against
//! the slot → recv-plan map for segment purity.

use crate::distsim::RankLocal;
use crate::mpk::dlb::DlbRankPlan;

use super::{Diagnostic, Rule};

/// Verify one rank's `seg_rows`/`multi_rows` split (see module docs).
pub fn check_rank_partition(rank: usize, r: &RankLocal, pl: &DlbRankPlan) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let nl = r.n_local();
    let n_halo = r.n_halo();

    if pl.seg_rows.len() != r.recv.len() {
        out.push(Diagnostic::new(
            Rule::DlbSegCount,
            Some(rank),
            format!(
                "seg_rows has {} segments, the rank has {} recv plans",
                pl.seg_rows.len(),
                r.recv.len()
            ),
        ));
        return out;
    }

    let (c_lo, c_hi) = pl.class_ranges.first().copied().unwrap_or((0, 0));
    let in_class = |row: u32| (row as usize) >= c_lo && (row as usize) < c_hi;

    for (j, rows) in pl.seg_rows.iter().enumerate() {
        for w in rows.windows(2) {
            if w[1] <= w[0] {
                out.push(Diagnostic::new(
                    Rule::DlbSegUnsorted,
                    Some(rank),
                    format!("seg_rows[{j}] not strictly ascending at {} then {}", w[0], w[1]),
                ));
                return out;
            }
        }
        if let Some(&row) = rows.iter().find(|&&row| !in_class(row)) {
            out.push(Diagnostic::new(
                Rule::DlbPartitionRange,
                Some(rank),
                format!("seg_rows[{j}] row {row} outside class I_1 = [{c_lo}, {c_hi})"),
            ));
            return out;
        }
    }
    if let Some(&row) = pl.multi_rows.iter().find(|&&row| !in_class(row)) {
        out.push(Diagnostic::new(
            Rule::DlbPartitionRange,
            Some(rank),
            format!("multi_rows row {row} outside class I_1 = [{c_lo}, {c_hi})"),
        ));
        return out;
    }

    // Exact partition of I_1: every row claimed exactly once.
    let mut claimed_by: Vec<Option<usize>> = vec![None; c_hi - c_lo];
    let lists =
        pl.seg_rows.iter().enumerate().chain(std::iter::once((usize::MAX, &pl.multi_rows)));
    for (j, rows) in lists {
        let name = |j: usize| {
            if j == usize::MAX { "multi_rows".to_string() } else { format!("seg_rows[{j}]") }
        };
        for &row in rows.iter() {
            let slot = &mut claimed_by[row as usize - c_lo];
            if let Some(prev) = *slot {
                out.push(Diagnostic::new(
                    Rule::DlbPartitionOverlap,
                    Some(rank),
                    format!("row {row} claimed by both {} and {}", name(prev), name(j)),
                ));
                return out;
            }
            *slot = Some(j);
        }
    }
    if let Some(i) = claimed_by.iter().position(|c| c.is_none()) {
        out.push(Diagnostic::new(
            Rule::DlbPartitionGap,
            Some(rank),
            format!(
                "class-I_1 row {} belongs to no segment and not to multi_rows — it would \
                 never advance",
                c_lo + i
            ),
        ));
        return out;
    }

    // Segment purity: a seg_rows[j] row may read halo slots of recv plan j
    // only (reading another peer's slot before that message lands races
    // with the transport's in-place halo write).
    let mut slot_owner = vec![usize::MAX; n_halo];
    for (j, rp) in r.recv.iter().enumerate() {
        for s in rp.slots.clone() {
            if s < n_halo {
                slot_owner[s] = j;
            }
        }
    }
    for (j, rows) in pl.seg_rows.iter().enumerate() {
        for &row in rows.iter() {
            for &c in r.a.row_cols(row as usize) {
                let c = c as usize;
                if c >= nl && slot_owner[c - nl] != j {
                    out.push(Diagnostic::new(
                        Rule::DlbSegForeignSlot,
                        Some(rank),
                        format!(
                            "seg_rows[{j}] row {row} reads halo slot {} of recv plan {} — \
                             it may only advance after that peer's message too",
                            c - nl,
                            slot_owner[c - nl]
                        ),
                    ));
                    return out;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distsim::DistMatrix;
    use crate::matrix::gen;
    use crate::mpk::dlb;
    use crate::partition::{partition, Method};

    fn plans(np: usize, p_m: usize) -> (DistMatrix, dlb::DlbPlan) {
        let a = gen::stencil_2d_5pt(12, 12);
        let part = partition(&a, np, Method::Block);
        let dist = DistMatrix::build(&a, &part);
        let plan = dlb::plan(&dist, p_m, &dlb::DlbOptions::default());
        ((*plan.dist).clone(), plan)
    }

    #[test]
    fn real_partitions_pass() {
        for (np, p_m) in [(1, 2), (2, 2), (3, 4), (2, 1)] {
            let (dist, plan) = plans(np, p_m);
            for (rank, (r, pl)) in dist.ranks.iter().zip(&plan.ranks).enumerate() {
                let diags = check_rank_partition(rank, r, pl);
                assert!(diags.is_empty(), "np={np} p_m={p_m} rank {rank}: {}",
                    super::super::render(&diags));
            }
        }
    }

    #[test]
    fn moved_row_is_rejected() {
        let (dist, mut plan) = plans(3, 3);
        // Move one row from a non-empty segment to a different peer's
        // segment: its halo reads still point at the original peer.
        let rank = plan
            .ranks
            .iter()
            .position(|pl| {
                pl.seg_rows.len() >= 2 && pl.seg_rows.iter().any(|s| !s.is_empty())
            })
            .expect("a rank with >= 2 peers and a non-empty segment");
        let pl = &mut plan.ranks[rank];
        let from = pl.seg_rows.iter().position(|s| !s.is_empty()).unwrap();
        let to = (from + 1) % pl.seg_rows.len();
        let row = pl.seg_rows[from].remove(0);
        pl.seg_rows[to].push(row);
        pl.seg_rows[to].sort_unstable();
        let diags = check_rank_partition(rank, &dist.ranks[rank], pl);
        assert!(
            diags.iter().any(|d| d.rule == Rule::DlbSegForeignSlot),
            "{}",
            super::super::render(&diags)
        );
    }

    #[test]
    fn dropped_row_is_a_gap_and_duplicate_is_an_overlap() {
        let (dist, mut plan) = plans(2, 3);
        let rank = plan
            .ranks
            .iter()
            .position(|pl| pl.seg_rows.iter().any(|s| !s.is_empty()))
            .unwrap();
        {
            let pl = &mut plan.ranks[rank];
            let seg = pl.seg_rows.iter_mut().find(|s| !s.is_empty()).unwrap();
            let row = seg.remove(0);
            let diags = check_rank_partition(rank, &dist.ranks[rank], pl);
            assert!(diags.iter().any(|d| d.rule == Rule::DlbPartitionGap));
            seg.insert(0, row);
            pl.multi_rows.push(row);
            pl.multi_rows.sort_unstable();
        }
        let diags = check_rank_partition(rank, &dist.ranks[rank], &plan.ranks[rank]);
        assert!(diags.iter().any(|d| d.rule == Rule::DlbPartitionOverlap));
    }
}
