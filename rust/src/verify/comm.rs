//! Analyzer 3 — communication-plan checker.
//!
//! Three layers, all static:
//!
//! * **Plan matching** ([`check_dist`], [`check_ca_plans`]): every send
//!   plan meets exactly one recv plan at its destination (peer + length),
//!   and they agree on *which* global rows travel — the sender's
//!   `owned[rows]` ids must equal the receiver's `halo_globals[slots]`
//!   slot-for-slot. Recv plans must tile the halo exactly and name the
//!   true owner of every slot.
//! * **Progress** ([`check_progress`]): a round-ordered fixpoint
//!   simulation of the blocking semantics — rank `i` completes round `t`
//!   only when every peer it receives from has posted its round-`t` send
//!   (i.e. has itself completed rounds `0..t`). Transports buffer sends,
//!   so posting never blocks; a rank that the fixpoint leaves short of
//!   `n_rounds` is deadlocked, and the diagnostic carries the wait-for
//!   chain. The model is conservative for DLB's early posting (phase-2
//!   `y_1` sends and async next-round sends go out *earlier* than the
//!   model assumes), so a pass here implies progress on the real paths.
//! * **Tag discipline** ([`check_tag_rounds`] over [`RoundSpec`]
//!   sequences): within one sweep a `(peer, tag)` pair must be unique
//!   between barriers, or a late message from round `t` could satisfy a
//!   receive of round `t' > t`. The barrier-free async remainder drops
//!   intermediate barriers ([`Communicator::advance_round`]) but must
//!   still barrier the sweep's final round — otherwise the *next* sweep's
//!   tag 0 could match this sweep's in-flight traffic.
//!
//! [`Communicator::advance_round`]: crate::exec::comm::Communicator::advance_round

use crate::distsim::{DistMatrix, RankLocal};
use crate::mpk::ca::CaExecPlan;

use super::{Diagnostic, Rule};

/// Per-rank facts checkable without the other ranks — the
/// `debug_assert!` subset run inside the kernels.
pub fn check_rank_local(rank: usize, r: &RankLocal) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let nl = r.n_local();

    let mut seen_to = std::collections::BTreeSet::new();
    for sp in &r.send {
        if sp.to == rank {
            out.push(Diagnostic::new(
                Rule::CommSelfMessage,
                Some(rank),
                format!("send plan targets rank {rank} itself"),
            ));
        }
        if !seen_to.insert(sp.to) {
            out.push(Diagnostic::new(
                Rule::CommDuplicatePlan,
                Some(rank),
                format!("two send plans target rank {}", sp.to),
            ));
        }
        for &row in &sp.rows {
            if row as usize >= nl {
                out.push(Diagnostic::new(
                    Rule::CommSendRowRange,
                    Some(rank),
                    format!("send to {} ships local row {row} >= n_local {nl}", sp.to),
                ));
                break;
            }
        }
    }

    let mut seen_from = std::collections::BTreeSet::new();
    let mut next = 0usize;
    for rp in &r.recv {
        if rp.from == rank {
            out.push(Diagnostic::new(
                Rule::CommSelfMessage,
                Some(rank),
                format!("recv plan names rank {rank} itself as source"),
            ));
        }
        if !seen_from.insert(rp.from) {
            out.push(Diagnostic::new(
                Rule::CommDuplicatePlan,
                Some(rank),
                format!("two recv plans name rank {} as source", rp.from),
            ));
        }
        if rp.slots.start < next {
            out.push(Diagnostic::new(
                Rule::CommSlotOverlap,
                Some(rank),
                format!(
                    "recv from {} claims slots [{}, {}) overlapping the previous plan's end {next}",
                    rp.from, rp.slots.start, rp.slots.end
                ),
            ));
        } else if rp.slots.start > next {
            out.push(Diagnostic::new(
                Rule::CommSlotGap,
                Some(rank),
                format!(
                    "halo slots [{next}, {}) filled by no recv plan (next is from {})",
                    rp.slots.start, rp.from
                ),
            ));
        }
        next = next.max(rp.slots.end);
    }
    if next != r.n_halo() {
        out.push(Diagnostic::new(
            Rule::CommSlotGap,
            Some(rank),
            format!("recv plans end at slot {next}, halo has {} slots", r.n_halo()),
        ));
    }
    out
}

/// Cross-rank matching of the halo exchange plans (see module docs).
pub fn check_dist(dist: &DistMatrix) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let nr = dist.n_ranks();
    let mut peers_ok = true;
    for r in &dist.ranks {
        out.extend(check_rank_local(r.rank, r));
        for sp in &r.send {
            if sp.to >= nr {
                peers_ok = false;
                out.push(Diagnostic::new(
                    Rule::CommPeerRange,
                    Some(r.rank),
                    format!("send plan targets rank {} of {nr}", sp.to),
                ));
            }
        }
        for rp in &r.recv {
            if rp.from >= nr {
                peers_ok = false;
                out.push(Diagnostic::new(
                    Rule::CommPeerRange,
                    Some(r.rank),
                    format!("recv plan names source rank {} of {nr}", rp.from),
                ));
            }
        }
    }
    if !peers_ok {
        return out; // matching below indexes ranks by peer id
    }

    for s in &dist.ranks {
        for sp in &s.send {
            let d = &dist.ranks[sp.to];
            let Some(rp) = d.recv.iter().find(|rp| rp.from == s.rank) else {
                out.push(Diagnostic::new(
                    Rule::CommSendUnmatched,
                    Some(s.rank),
                    format!("send to {} has no recv plan at the destination", sp.to),
                ));
                continue;
            };
            if sp.rows.len() != rp.slots.len() {
                out.push(Diagnostic::new(
                    Rule::CommLenMismatch,
                    None,
                    format!(
                        "{} -> {}: send ships {} values, recv expects {}",
                        s.rank,
                        sp.to,
                        sp.rows.len(),
                        rp.slots.len()
                    ),
                ));
                continue;
            }
            for (i, (&row, slot)) in sp.rows.iter().zip(rp.slots.clone()).enumerate() {
                // out-of-range rows/slots already carry their own diagnostic
                let (Some(&sent), Some(&want)) =
                    (s.owned.get(row as usize), d.halo_globals.get(slot))
                else {
                    break;
                };
                if sent != want {
                    out.push(Diagnostic::new(
                        Rule::CommPayloadMismatch,
                        None,
                        format!(
                            "{} -> {} element {i}: sender ships global {sent} into a slot \
                             expecting global {want}",
                            s.rank, sp.to,
                        ),
                    ));
                    break;
                }
            }
        }
        for rp in &s.recv {
            if !dist.ranks[rp.from].send.iter().any(|sp| sp.to == s.rank) {
                out.push(Diagnostic::new(
                    Rule::CommRecvUnmatched,
                    Some(s.rank),
                    format!("recv from {} has no send plan at the source", rp.from),
                ));
            }
            for slot in rp.slots.clone() {
                let Some(&g) = s.halo_globals.get(slot) else {
                    break; // slot range past the halo: already a CommSlotGap
                };
                if dist.owner_of[g] as usize != rp.from {
                    out.push(Diagnostic::new(
                        Rule::CommSlotOwner,
                        Some(s.rank),
                        format!(
                            "halo slot {slot} holds global {g} owned by rank {}, but the recv \
                             plan names {}",
                            dist.owner_of[g], rp.from
                        ),
                    ));
                    break;
                }
            }
        }
    }
    out
}

/// Round-ordered progress simulation over a per-rank peer adjacency
/// (`sends[i]` / `recvs[i]` = peers rank `i` sends to / receives from in
/// *every* round — all three kernels reuse one plan set across rounds).
/// Ranks left short of `n_rounds` at the fixpoint are deadlocked.
pub fn check_progress(
    sends: &[Vec<usize>],
    recvs: &[Vec<usize>],
    n_rounds: usize,
) -> Vec<Diagnostic> {
    let nr = sends.len();
    assert_eq!(recvs.len(), nr);
    let mut pos = vec![0usize; nr];
    // The blocking peer of rank i at its current round, or None if i can
    // advance: the first recv peer that has not posted the matching send.
    let blocker = |i: usize, pos: &[usize]| -> Option<usize> {
        recvs[i]
            .iter()
            .copied()
            .find(|&j| j >= nr || !sends[j].contains(&i) || pos[j] < pos[i])
    };
    let mut changed = true;
    while changed {
        changed = false;
        for i in 0..nr {
            while pos[i] < n_rounds && blocker(i, &pos).is_none() {
                pos[i] += 1;
                changed = true;
            }
        }
    }
    let mut out = Vec::new();
    for i in 0..nr {
        if pos[i] >= n_rounds {
            continue;
        }
        // Wait-for chain from i: follow blockers until repetition (a wait
        // cycle) or a peer that simply never sends.
        let mut chain = vec![i];
        let mut cur = i;
        loop {
            match blocker(cur, &pos) {
                Some(j) if j < nr && sends[j].contains(&cur) => {
                    if chain.contains(&j) {
                        chain.push(j);
                        break;
                    }
                    chain.push(j);
                    cur = j;
                }
                Some(j) => {
                    chain.push(j);
                    out.push(Diagnostic::new(
                        Rule::CommDeadlock,
                        Some(i),
                        format!(
                            "rank {i} blocks forever in round {} waiting on rank {j}, which \
                             has no send plan for it (chain {chain:?})",
                            pos[i]
                        ),
                    ));
                    return out;
                }
                None => break, // pos advanced meanwhile; shouldn't happen at fixpoint
            }
        }
        out.push(Diagnostic::new(
            Rule::CommDeadlock,
            Some(i),
            format!("rank {i} stuck at round {} of {n_rounds}; wait-for chain {chain:?}", pos[i]),
        ));
        return out; // one chain explains the stall; avoid n_ranks duplicates
    }
    out
}

/// [`check_progress`] with the adjacency read off a [`DistMatrix`]'s halo
/// plans (TRAD rounds, DLB phases 1 and 3).
pub fn check_progress_dist(dist: &DistMatrix, n_rounds: usize) -> Vec<Diagnostic> {
    let sends: Vec<Vec<usize>> =
        dist.ranks.iter().map(|r| r.send.iter().map(|sp| sp.to).collect()).collect();
    let recvs: Vec<Vec<usize>> =
        dist.ranks.iter().map(|r| r.recv.iter().map(|rp| rp.from).collect()).collect();
    check_progress(&sends, &recvs, n_rounds)
}

/// One communication round of a sweep, as the tag-discipline model sees
/// it: which tag its messages carry and whether the round closes with a
/// barrier (`end_round`) or barrier-free (`advance_round`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RoundSpec {
    pub tag: u64,
    pub barrier_after: bool,
}

/// TRAD's sweep: round `p ∈ 1..=p_m` exchanges tag `p − 1`; every
/// exchange ends in `wait_halo`, which barriers.
pub fn trad_rounds(p_m: usize) -> Vec<RoundSpec> {
    (1..=p_m).map(|p| RoundSpec { tag: (p - 1) as u64, barrier_after: true }).collect()
}

/// CA's sweep: one extended exchange on tag 0, explicitly `end_round`ed.
pub fn ca_rounds() -> Vec<RoundSpec> {
    vec![RoundSpec { tag: 0, barrier_after: true }]
}

/// DLB's sweep: phase 1 on tag 0 (barriered), then remainder round
/// `p ∈ 1..p_m` on tag `p`. The sync path barriers every round via
/// `wait_halo`; the async path closes intermediate rounds with
/// `advance_round` and barriers only the final round.
pub fn dlb_rounds(p_m: usize, async_remainder: bool) -> Vec<RoundSpec> {
    let mut rounds = vec![RoundSpec { tag: 0, barrier_after: true }];
    for p in 1..p_m {
        let last = p == p_m - 1;
        rounds.push(RoundSpec { tag: p as u64, barrier_after: !async_remainder || last });
    }
    rounds
}

/// Cross-sweep tag safety: no tag repeats between barriers, and no tags
/// may remain live when the sweep ends (the next sweep restarts at tag 0).
pub fn check_tag_rounds(rounds: &[RoundSpec]) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut live: Vec<u64> = Vec::new();
    for (i, r) in rounds.iter().enumerate() {
        if live.contains(&r.tag) {
            out.push(Diagnostic::new(
                Rule::CommTagReuse,
                None,
                format!("round {i} reuses tag {} with no barrier since its last use", r.tag),
            ));
        }
        live.push(r.tag);
        if r.barrier_after {
            live.clear();
        }
    }
    if !live.is_empty() {
        out.push(Diagnostic::new(
            Rule::CommNoFinalBarrier,
            None,
            format!(
                "sweep ends with tags {live:?} unfenced; the next sweep's identical tags \
                 could match this sweep's in-flight messages"
            ),
        ));
    }
    out
}

/// CA's extended-exchange plan: exactly-once peer matching, payload
/// agreement (`local_of[gid] == row`, `owner_of[gid] == sender`), external
/// classes covered by the receives, and single-round progress.
pub fn check_ca_plans(dist: &DistMatrix, plan: &CaExecPlan) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let nr = dist.n_ranks();
    if plan.sends.len() != nr || plan.recvs.len() != nr || plan.ext.len() != nr {
        out.push(Diagnostic::new(
            Rule::CommPeerRange,
            None,
            format!(
                "plan covers {}/{}/{} ranks (sends/recvs/ext), dist has {nr}",
                plan.sends.len(),
                plan.recvs.len(),
                plan.ext.len()
            ),
        ));
        return out;
    }

    for i in 0..nr {
        let mut seen = std::collections::BTreeSet::new();
        for (peer, _) in &plan.sends[i] {
            if *peer >= nr || *peer == i {
                out.push(Diagnostic::new(
                    if *peer == i { Rule::CommSelfMessage } else { Rule::CommPeerRange },
                    Some(i),
                    format!("CA send plan names peer {peer}"),
                ));
            } else if !seen.insert(*peer) {
                out.push(Diagnostic::new(
                    Rule::CommDuplicatePlan,
                    Some(i),
                    format!("two CA send plans target rank {peer}"),
                ));
            }
        }
        let mut seen = std::collections::BTreeSet::new();
        for (peer, gids) in &plan.recvs[i] {
            if *peer >= nr || *peer == i {
                out.push(Diagnostic::new(
                    if *peer == i { Rule::CommSelfMessage } else { Rule::CommPeerRange },
                    Some(i),
                    format!("CA recv plan names peer {peer}"),
                ));
                continue;
            }
            if !seen.insert(*peer) {
                out.push(Diagnostic::new(
                    Rule::CommDuplicatePlan,
                    Some(i),
                    format!("two CA recv plans name rank {peer} as source"),
                ));
            }
            for &g in gids {
                match dist.owner_of.get(g) {
                    Some(&o) if o as usize == *peer => {}
                    Some(&o) => {
                        out.push(Diagnostic::new(
                            Rule::CommSlotOwner,
                            Some(i),
                            format!("CA recv from {peer} lists global {g} owned by rank {o}"),
                        ));
                        break;
                    }
                    None => {
                        out.push(Diagnostic::new(
                            Rule::CommSlotOwner,
                            Some(i),
                            format!(
                                "CA recv from {peer} lists global {g} >= n_global {}",
                                dist.n_global
                            ),
                        ));
                        break;
                    }
                }
            }
        }
    }
    if !out.is_empty() {
        return out;
    }

    for s in 0..nr {
        for (d, rows) in &plan.sends[s] {
            let Some((_, gids)) = plan.recvs[*d].iter().find(|(p, _)| *p == s) else {
                out.push(Diagnostic::new(
                    Rule::CommSendUnmatched,
                    Some(s),
                    format!("CA send to {d} has no recv plan at the destination"),
                ));
                continue;
            };
            if rows.len() != gids.len() {
                out.push(Diagnostic::new(
                    Rule::CommLenMismatch,
                    None,
                    format!(
                        "CA {s} -> {d}: send ships {} values, recv expects {}",
                        rows.len(),
                        gids.len()
                    ),
                ));
                continue;
            }
            for (i, (&row, &g)) in rows.iter().zip(gids).enumerate() {
                if dist.local_of[g] != row {
                    out.push(Diagnostic::new(
                        Rule::CommPayloadMismatch,
                        None,
                        format!(
                            "CA {s} -> {d} element {i}: send reads local row {row}, receiver \
                             expects global {g} (local {})",
                            dist.local_of[g]
                        ),
                    ));
                    break;
                }
            }
        }
        for (peer, _) in &plan.recvs[s] {
            if !plan.sends[*peer].iter().any(|(d, _)| *d == s) {
                out.push(Diagnostic::new(
                    Rule::CommRecvUnmatched,
                    Some(s),
                    format!("CA recv from {peer} has no send plan at the source"),
                ));
            }
        }

        // coverage: the receives must deliver the external classes exactly
        let mut want: Vec<usize> = plan.ext[s].iter().flatten().copied().collect();
        want.sort_unstable();
        let mut got: Vec<usize> =
            plan.recvs[s].iter().flat_map(|(_, gids)| gids.iter().copied()).collect();
        got.sort_unstable();
        if want != got {
            out.push(Diagnostic::new(
                Rule::CaExtCoverage,
                Some(s),
                format!(
                    "external classes need {} values, recv plans deliver {} (sets differ)",
                    want.len(),
                    got.len()
                ),
            ));
        }
    }

    let sends: Vec<Vec<usize>> =
        plan.sends.iter().map(|v| v.iter().map(|&(d, _)| d).collect()).collect();
    let recvs: Vec<Vec<usize>> =
        plan.recvs.iter().map(|v| v.iter().map(|&(p, _)| p).collect()).collect();
    out.extend(check_progress(&sends, &recvs, 1));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::partition::{partition, Method};

    fn dist(np: usize) -> DistMatrix {
        let a = gen::stencil_2d_5pt(10, 10);
        let p = partition(&a, np, Method::Block);
        DistMatrix::build(&a, &p)
    }

    #[test]
    fn built_dist_passes() {
        for np in [1, 2, 4] {
            let d = dist(np);
            let diags = check_dist(&d);
            assert!(diags.is_empty(), "np={np}: {}", super::super::render(&diags));
            assert!(check_progress_dist(&d, 4).is_empty());
        }
    }

    #[test]
    fn dropped_recv_is_unmatched_with_a_slot_gap() {
        let mut d = dist(3);
        let victim = d.ranks.iter().position(|r| !r.recv.is_empty()).unwrap();
        d.ranks[victim].recv.remove(0);
        let diags = check_dist(&d);
        assert!(diags.iter().any(|x| x.rule == Rule::CommSendUnmatched));
        assert!(diags.iter().any(|x| x.rule == Rule::CommSlotGap));
    }

    #[test]
    fn dropped_send_deadlocks() {
        let mut d = dist(2);
        let victim = d.ranks.iter().position(|r| !r.send.is_empty()).unwrap();
        d.ranks[victim].send.remove(0);
        assert!(check_dist(&d).iter().any(|x| x.rule == Rule::CommRecvUnmatched));
        let diags = check_progress_dist(&d, 1);
        assert!(
            diags.iter().any(|x| x.rule == Rule::CommDeadlock),
            "{}",
            super::super::render(&diags)
        );
    }

    #[test]
    fn tag_models_are_safe() {
        for p_m in 1..=4 {
            assert!(check_tag_rounds(&trad_rounds(p_m)).is_empty());
            assert!(check_tag_rounds(&dlb_rounds(p_m, false)).is_empty());
            assert!(check_tag_rounds(&dlb_rounds(p_m, true)).is_empty());
        }
        assert!(check_tag_rounds(&ca_rounds()).is_empty());
    }

    #[test]
    fn tag_mutations_are_rejected() {
        // reuse a tag across two barrier-free rounds
        let mut rounds = dlb_rounds(4, true);
        rounds[2].tag = rounds[1].tag;
        let diags = check_tag_rounds(&rounds);
        assert!(diags.iter().any(|x| x.rule == Rule::CommTagReuse));

        // drop the sweep-final barrier
        let mut rounds = dlb_rounds(3, true);
        rounds.last_mut().unwrap().barrier_after = false;
        let diags = check_tag_rounds(&rounds);
        assert!(diags.iter().any(|x| x.rule == Rule::CommNoFinalBarrier));
    }

    #[test]
    fn ca_plans_pass_and_reject_mutations() {
        let a = gen::stencil_2d_5pt(10, 10);
        let p = partition(&a, 3, Method::Block);
        let d = DistMatrix::build(&a, &p);
        let plan = crate::mpk::ca::ca_exec_plan(&a, &d, 3);
        assert!(check_ca_plans(&d, &plan).is_empty());

        let mut bad = crate::mpk::ca::ca_exec_plan(&a, &d, 3);
        let victim = bad.recvs.iter().position(|v| !v.is_empty()).unwrap();
        bad.recvs[victim].remove(0);
        let diags = check_ca_plans(&d, &bad);
        assert!(diags.iter().any(|x| x.rule == Rule::CommSendUnmatched));
        assert!(diags.iter().any(|x| x.rule == Rule::CaExtCoverage));
    }
}
