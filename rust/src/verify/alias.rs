//! Analyzer 2 — aliasing checker for inner work splits.
//!
//! [`crate::inner`] hands raw-pointer buffer views
//! (`SharedBuf`/`SharedBufMut`) to concurrent workers; the soundness
//! argument is that every decomposition a kernel feeds to `run_batch`
//! writes pairwise-disjoint row sets. This analyzer re-executes each
//! decomposition the kernels actually use — [`split_range`] chunks over
//! group/class/full-sweep ranges, [`contiguous_runs`] +
//! per-run splitting over the async remainder's segment row lists, and
//! the CA promote round's owned ∪ external row lists — and proves
//! disjointness and coverage *statically*, before any pointer view is
//! constructed.
//!
//! [`split_range`]: crate::inner::split_range
//! [`contiguous_runs`]: crate::mpk::dlb::contiguous_runs

use crate::distsim::RankLocal;
use crate::inner::split_range;
use crate::mpk::dlb::{contiguous_runs, DlbRankPlan};

use super::{Diagnostic, Rule};

/// Verify `split_range(lo, hi, k)`: non-empty chunks that tile `[lo, hi)`
/// contiguously — each row written by exactly one worker.
pub fn check_split(rank: usize, lo: usize, hi: usize, k: usize) -> Vec<Diagnostic> {
    let what = format!("split_range([{lo}, {hi}), k={k})");
    check_chunks(rank, &what, &split_range(lo, hi, k), lo, hi)
}

/// Verify an explicit chunk list against the range it must tile.
fn check_chunks(
    rank: usize,
    what: &str,
    chunks: &[(usize, usize)],
    lo: usize,
    hi: usize,
) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut next = lo;
    for &(clo, chi) in chunks {
        if clo < next {
            out.push(Diagnostic::new(
                Rule::AliasSplitOverlap,
                Some(rank),
                format!("{what}: chunk [{clo}, {chi}) overlaps rows below {next}"),
            ));
            return out;
        }
        if clo > next {
            out.push(Diagnostic::new(
                Rule::AliasSplitGap,
                Some(rank),
                format!("{what}: rows [{next}, {clo}) belong to no chunk"),
            ));
            return out;
        }
        if chi <= clo {
            out.push(Diagnostic::new(
                Rule::AliasSplitGap,
                Some(rank),
                format!("{what}: empty chunk at {clo}"),
            ));
            return out;
        }
        next = chi;
    }
    if next != hi {
        out.push(Diagnostic::new(
            Rule::AliasSplitGap,
            Some(rank),
            format!("{what}: chunks end at {next}, range ends at {hi}"),
        ));
    }
    out
}

/// Verify the async remainder's run decomposition of a sorted row list:
/// `contiguous_runs` must reproduce exactly the input rows, the runs must
/// be disjoint and ascending (two runs sharing a row = two concurrent
/// writers), and each run must split cleanly for `k` participants.
pub fn check_runs(rank: usize, what: &str, rows: &[u32], k: usize) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    // `contiguous_runs` assumes a sorted, duplicate-free list; a duplicate
    // row yields two overlapping runs (two concurrent writers), and an
    // out-of-order list breaks the run reconstruction entirely.
    for w in rows.windows(2) {
        if w[1] == w[0] {
            out.push(Diagnostic::new(
                Rule::AliasSplitOverlap,
                Some(rank),
                format!("{what}: row {} listed twice — two workers would write it", w[0]),
            ));
            return out;
        }
        if w[1] < w[0] {
            out.push(Diagnostic::new(
                Rule::AliasRunsMismatch,
                Some(rank),
                format!(
                    "{what}: rows {} then {} out of order — contiguous_runs assumes ascending",
                    w[0], w[1]
                ),
            ));
            return out;
        }
    }
    let runs = contiguous_runs(rows);
    let flat: Vec<u32> = runs.iter().flat_map(|&(lo, hi)| (lo as u32..hi as u32)).collect();
    if flat != rows {
        out.push(Diagnostic::new(
            Rule::AliasRunsMismatch,
            Some(rank),
            format!(
                "{what}: contiguous_runs covers {} rows, input lists {} (content differs)",
                flat.len(),
                rows.len()
            ),
        ));
        return out;
    }
    for &(lo, hi) in &runs {
        out.extend(check_split(rank, lo, hi, k));
    }
    out
}

/// Verify every decomposition the DLB kernel feeds its inner pool: the
/// phase-2 group ranges and phase-3 class ranges (range splits), and the
/// async remainder's per-segment and multi-peer row lists (run splits).
pub fn check_dlb_alias(
    rank: usize,
    _r: &RankLocal,
    pl: &DlbRankPlan,
    k: usize,
) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for &(lo, hi) in &pl.ranges {
        out.extend(check_split(rank, lo, hi, k));
    }
    for &(lo, hi) in &pl.class_ranges {
        out.extend(check_split(rank, lo, hi, k));
    }
    for (j, rows) in pl.seg_rows.iter().enumerate() {
        out.extend(check_runs(rank, &format!("seg_rows[{j}]"), rows, k));
    }
    out.extend(check_runs(rank, "multi_rows", &pl.multi_rows, k));
    out
}

/// Verify the CA promote round's row lists: `run_ca_round` splits the
/// owned list plus every still-live external class into concurrent tasks,
/// so a row appearing in two of those lists would be written by two
/// workers in the same batch.
pub fn check_ca_alias(
    rank: usize,
    owned: &[usize],
    ext: &[Vec<usize>],
    p_m: usize,
    k: usize,
) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    // Lists live in at least one round p >= 1: owned always; class `E_kx`
    // while p <= p_m - 1 - kx, i.e. iff its target is >= 1.
    let mut lists: Vec<(String, &[usize])> = vec![("owned".into(), owned)];
    for (kx, cls) in ext.iter().enumerate() {
        if p_m.saturating_sub(1).saturating_sub(kx) >= 1 {
            lists.push((format!("ext[{kx}]"), cls));
        }
    }
    let mut seen: std::collections::BTreeMap<usize, usize> = std::collections::BTreeMap::new();
    for (li, (name, rows)) in lists.iter().enumerate() {
        for &g in rows.iter() {
            if let Some(&prev) = seen.get(&g) {
                out.push(Diagnostic::new(
                    Rule::AliasCaRowsOverlap,
                    Some(rank),
                    format!(
                        "row {g} appears in both {} and {name}: two same-round tasks would \
                         write it",
                        lists[prev].0
                    ),
                ));
                return out;
            }
            seen.insert(g, li);
        }
        out.extend(check_split(rank, 0, rows.len(), k));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_range_decompositions_pass() {
        for (lo, hi) in [(0usize, 0usize), (0, 1), (3, 17), (0, 1000)] {
            for k in 1..=6 {
                let diags = check_split(7, lo, hi, k);
                assert!(diags.is_empty(), "[{lo},{hi}) k={k}: {}", super::super::render(&diags));
            }
        }
    }

    #[test]
    fn bad_chunk_lists_are_rejected() {
        assert!(check_chunks(0, "t", &[(0, 5), (4, 8)], 0, 8)
            .iter()
            .any(|d| d.rule == Rule::AliasSplitOverlap));
        assert!(check_chunks(0, "t", &[(0, 3), (5, 8)], 0, 8)
            .iter()
            .any(|d| d.rule == Rule::AliasSplitGap));
        assert!(check_chunks(0, "t", &[(0, 3)], 0, 8)
            .iter()
            .any(|d| d.rule == Rule::AliasSplitGap));
    }

    #[test]
    fn run_decompositions_pass_and_reject_duplicates() {
        assert!(check_runs(0, "t", &[3, 4, 5, 9, 20, 21], 3).is_empty());
        assert!(check_runs(0, "t", &[], 2).is_empty());
        // a duplicated row produces two overlapping runs
        let diags = check_runs(0, "t", &[3, 4, 4], 2);
        assert!(
            diags
                .iter()
                .any(|d| matches!(d.rule, Rule::AliasSplitOverlap | Rule::AliasRunsMismatch)),
            "{}",
            super::super::render(&diags)
        );
        // an unsorted list cannot round-trip through contiguous_runs
        let diags = check_runs(0, "t", &[9, 3], 2);
        assert!(diags.iter().any(|d| d.rule == Rule::AliasRunsMismatch));
    }

    #[test]
    fn ca_overlapping_lists_are_rejected() {
        let owned = vec![0usize, 1, 2];
        let ext = vec![vec![3usize, 4], vec![5, 6]];
        assert!(check_ca_alias(0, &owned, &ext, 3, 2).is_empty());
        let bad = vec![vec![2usize, 4], vec![5, 6]]; // row 2 also owned
        let diags = check_ca_alias(0, &owned, &bad, 3, 2);
        assert!(diags.iter().any(|d| d.rule == Rule::AliasCaRowsOverlap));
        // a class past its target is never computed, so overlap there is fine
        let dead = vec![vec![3usize, 4], vec![5, 6], vec![2]]; // ext[2] target 0
        assert!(check_ca_alias(0, &owned, &dead, 3, 2).is_empty());
    }
}
