//! Shared-memory parallel wavefront execution *inside* one rank — the
//! second level of the ranks × inner-threads hierarchy (the paper's
//! MPI+OpenMP composition, RACE's level-based shared-memory scheduling).
//!
//! # What runs in parallel, and why it is safe
//!
//! A rank's compute is a sequence of *steps* `(group, power)` — promote the
//! rows of one level group from `A^{p-1}x` to `A^p x`. Two steps may run
//! concurrently only if neither reads what the other writes:
//!
//! * **Same power, different groups** — both write power `p` at disjoint
//!   row ranges and read only power `p − 1`, which is finished. Safe.
//! * **Powers apart by one** — the step at power `p + 1` reads power `p`
//!   on its level span ± 1 (the SpMV dependency window). Safe only when
//!   the writer's span is ≥ 2 levels away — RACE's rule that levels at
//!   distance ≥ 2 never share matrix rows.
//! * **Powers apart by two or more** — different write buffers; the only
//!   lower-power read is the three-term recurrence's `prev2`, which is the
//!   step's *own* rows at `p − 2`, finished long before. Safe.
//!
//! [`crate::race::parallel_batches`] turns a wavefront schedule into
//! batches of steps that satisfy exactly these conditions (skewed fronts
//! `node + 2·power`; see its docs for the full argument), so an
//! [`InnerExec`] may run all tasks of one batch concurrently and only
//! barrier between batches.
//!
//! # Bitwise identity with the serial path
//!
//! Every task computes each of its rows with the same primitive the serial
//! code uses ([`crate::mpk::kernel_step`] / CA's `row_dot`), on the same
//! backend kind, over the same fully-finished inputs. Each row is written
//! exactly once per power, so neither the batch order nor which thread
//! runs a task can change a single bit of the output — `inner_threads(k)`
//! is bitwise identical to serial for every `k` (asserted across variants
//! and executors in `rust/tests/inner_exec.rs`).
//!
//! # Shape of the pool
//!
//! An [`InnerExec`] with `k` participants owns `k − 1` parked worker
//! threads (`mpk-rank-{r}-inner-{w}`); the calling rank thread is
//! participant 0 and executes its own share of every batch, so `k = 1`
//! degenerates to today's serial code with zero overhead. Workers own
//! their own [`SpmvBackend`] instance and, when tracing, a lane
//! [`RankRecorder`] whose `inner.task(g,p)` spans export as separate
//! chrome-trace tids (`rank * LANE_STRIDE + lane`).
//!
//! # Why the `unsafe impl Send` is sound
//!
//! This module contains the crate's only `unsafe` code: `SharedBuf` /
//! `SharedBufMut` are `(ptr, len)` views of the power buffers, declared
//! `Send` so batch tasks can carry them to worker threads. The borrow
//! checker cannot verify them, so the argument is spelled out here and
//! relied on everywhere:
//!
//! 1. **Lifetime** — views are built inside `run_batch`/`run_split_*`
//!    from live `&[f64]`/`&mut [f64]` borrows, and those calls **block**
//!    until every worker acks its last task. No view survives the call
//!    that created it, so no pointer outlives the buffer it points into.
//! 2. **Aliasing across threads** — two tasks of one batch never
//!    write the same element and never read what a same-batch task
//!    writes. That is exactly the [`crate::race::parallel_batches`]
//!    independence rule (proved in its docs) for wavefront batches, and
//!    row-range/run disjointness for the flat splits.
//! 3. **Not just hand-waving** — rule 2 is machine-checked *before
//!    execution* by [`crate::verify`]: analyzer 1 re-derives batch
//!    independence from the level structure (`SCHED_BATCH_*` rules) and
//!    analyzer 2 proves every split decomposition disjoint and complete
//!    (`ALIAS_*` rules). Engines verify by default in debug builds
//!    ([`crate::engine::EngineConfig::verify_plans`]).
//! 4. **Publication** — workers park on `mpsc` channels; the channel
//!    send/recv pair is the happens-before edge that publishes buffer
//!    writes to the next batch's readers, and the final acks publish
//!    everything back to the rank thread before `run_batch` returns.
//!
//! Each `unsafe impl`/`unsafe fn` below carries the item-local version of
//! this argument; `#![warn(clippy::undocumented_unsafe_blocks)]` keeps it
//! that way.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::engine::BackendSpec;
use crate::matrix::CsrMatrix;
use crate::mpk::dlb::Recurrence;
use crate::mpk::{kernel_step, SpmvBackend};
use crate::trace::{Event, RankRecorder, Span, TraceSession};

/// Read-only view of a power buffer, sendable to inner workers.
///
/// Raw pointers instead of borrows because one batch may read and write
/// *disjoint row ranges of the same buffer* from different tasks — a
/// sharing pattern Rust references cannot express. Soundness rests on the
/// [`crate::race::parallel_batches`] invariant (no same-batch read/write
/// overlap) plus [`InnerExec::run_batch`] blocking until every task has
/// acked, so no pointer outlives the buffers it was built from.
#[derive(Clone, Copy, Debug)]
pub(crate) struct SharedBuf {
    ptr: *const f64,
    len: usize,
}

// SAFETY: a plain (ptr, len) pair with no thread affinity. Workers only
// dereference it via `slice`, whose contract (batch buffers outlive the
// blocked `run_batch` call; no same-batch write overlaps the read) is what
// actually keeps cross-thread access sound.
unsafe impl Send for SharedBuf {}

impl SharedBuf {
    pub(crate) fn of(v: &[f64]) -> Self {
        Self { ptr: v.as_ptr(), len: v.len() }
    }

    /// # Safety
    /// Only within a task of a batch whose buffers are still borrowed by
    /// the blocked `run_batch` caller, and never overlapping a same-batch
    /// write (the `parallel_batches` invariant).
    unsafe fn slice<'a>(self) -> &'a [f64] {
        std::slice::from_raw_parts(self.ptr, self.len)
    }
}

/// Write view of a power buffer — same rules as [`SharedBuf`], plus:
/// same-batch tasks write disjoint row ranges of it.
#[derive(Clone, Copy, Debug)]
pub(crate) struct SharedBufMut {
    ptr: *mut f64,
    len: usize,
}

// SAFETY: as for [`SharedBuf`], plus writes: each task writes only the row
// range/run it owns, and batch tasks own pairwise-disjoint rows (the
// `parallel_batches` invariant, machine-checked by `crate::verify::alias`),
// so no two threads ever write the same element.
unsafe impl Send for SharedBufMut {}

impl SharedBufMut {
    pub(crate) fn of(v: &mut [f64]) -> Self {
        Self { ptr: v.as_mut_ptr(), len: v.len() }
    }

    pub(crate) fn read(self) -> SharedBuf {
        SharedBuf { ptr: self.ptr, len: self.len }
    }

    /// # Safety
    /// See [`SharedBuf::slice`]; additionally the caller must only write
    /// rows its own task owns.
    unsafe fn slice_mut<'a>(self) -> &'a mut [f64] {
        std::slice::from_raw_parts_mut(self.ptr, self.len)
    }
}

/// Shared rank-local matrix pointer (the matrix is immutable for the whole
/// sweep; workers only read it).
#[derive(Clone, Copy, Debug)]
pub(crate) struct MatPtr(pub(crate) *const CsrMatrix);

// SAFETY: the matrix is borrowed by the `run_batch` caller for the whole
// blocking call and never mutated during a sweep; workers perform
// read-only accesses, which may alias freely.
unsafe impl Send for MatPtr {}

impl MatPtr {
    pub(crate) fn of(a: &CsrMatrix) -> Self {
        Self(a)
    }
}

/// Borrowed row-index list (CA promotion rounds walk explicit row lists).
#[derive(Clone, Copy, Debug)]
pub(crate) struct RowsPtr {
    ptr: *const usize,
    len: usize,
}

// SAFETY: read-only (ptr, len) view of a row list that the blocked
// `run_batch` caller keeps borrowed until every task acks; shared
// immutable reads from worker threads are sound.
unsafe impl Send for RowsPtr {}

impl RowsPtr {
    pub(crate) fn of(rows: &[usize]) -> Self {
        Self { ptr: rows.as_ptr(), len: rows.len() }
    }
}

/// One dependency-free task of a batch.
pub(crate) enum InnerWork {
    /// A contiguous row range of one three-term recurrence step
    /// (TRAD sweeps, DLB wavefront + remainder) via `kernel_step`.
    Range {
        a: MatPtr,
        rec: Recurrence,
        prev2: Option<SharedBuf>,
        prev: SharedBuf,
        cur: SharedBufMut,
        lo: usize,
        hi: usize,
        span: Span,
    },
    /// An explicit row list of one CA promotion round (global indexing,
    /// plain row dot products — CA never goes through a backend).
    Rows { a: MatPtr, rows: RowsPtr, prev: SharedBuf, cur: SharedBufMut, span: Span },
}

/// Execute one task; returns the nonzeros touched (the `flop_nnz` share).
fn exec_work(w: &InnerWork, backend: &mut dyn SpmvBackend, tracer: &mut RankRecorder) -> usize {
    match *w {
        InnerWork::Range { a, rec, prev2, prev, cur, lo, hi, span } => {
            let t0 = tracer.now();
            // SAFETY: `run_batch` blocks its caller (who holds the real
            // borrows) until this task acks, and the batch invariant says
            // no same-batch task writes what we read or touches rows we
            // write — see the SharedBuf docs.
            let nnz = unsafe {
                let prev2 = prev2.map(|b| b.slice());
                kernel_step(&*a.0, rec, prev2, prev.slice(), cur.slice_mut(), lo, hi, backend)
            };
            tracer.closed_span(span, t0);
            nnz
        }
        InnerWork::Rows { a, rows, prev, cur, span } => {
            let t0 = tracer.now();
            // SAFETY: as above; row lists of one batch are disjoint.
            let nnz = unsafe {
                let a = &*a.0;
                let rows = std::slice::from_raw_parts(rows.ptr, rows.len);
                let (prev, cur) = (prev.slice(), cur.slice_mut());
                let mut nnz = 0usize;
                for &g in rows {
                    cur[g] = crate::mpk::ca::row_dot(a, g, prev);
                    nnz += a.row_cols(g).len();
                }
                nnz
            };
            tracer.closed_span(span, t0);
            nnz
        }
    }
}

enum ToWorker {
    /// Run a bundle of tasks, then ack the summed nnz on the done channel.
    Run(Vec<InnerWork>),
    /// Drain the lane recorder's buffered events.
    Harvest(Sender<Vec<Event>>),
}

struct Pool {
    workers: Vec<Sender<ToWorker>>,
    done_rx: Receiver<usize>,
    handles: Vec<JoinHandle<()>>,
}

/// A rank's inner thread pool: participant 0 is the calling rank thread,
/// participants `1..k` are parked worker threads. `k <= 1` is the serial
/// executor — no threads, no channels, and the kernels bypass it entirely.
pub struct InnerExec {
    pool: Option<Pool>,
}

impl InnerExec {
    /// The serial executor (`inner_threads(1)`, the default).
    pub fn serial() -> Self {
        Self { pool: None }
    }

    /// An executor with `k` total participants for `rank`. Workers own a
    /// fresh backend from `backend` and, when `trace` is given, a lane
    /// recorder on the session's epoch.
    pub fn new(k: usize, rank: usize, backend: &BackendSpec, trace: Option<&TraceSession>) -> Self {
        if k <= 1 {
            return Self::serial();
        }
        let (done_tx, done_rx) = channel();
        let mut workers = Vec::with_capacity(k - 1);
        let mut handles = Vec::with_capacity(k - 1);
        for w in 1..k {
            let (tx, rx) = channel::<ToWorker>();
            let be = backend.make();
            let tracer = match trace {
                Some(ts) => ts.recorder(rank),
                None => RankRecorder::disabled(),
            };
            let done = done_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("mpk-rank-{rank}-inner-{w}"))
                .spawn(move || worker_loop(rx, done, be, tracer))
                .expect("spawn inner worker thread");
            workers.push(tx);
            handles.push(handle);
        }
        Self { pool: Some(Pool { workers, done_rx, handles }) }
    }

    /// Whether batches actually fan out (`k >= 2`). Kernels keep their
    /// exact serial code path (same spans, no task boxing) when false.
    pub fn is_parallel(&self) -> bool {
        self.pool.is_some()
    }

    /// Total participants (caller + workers).
    pub fn participants(&self) -> usize {
        self.pool.as_ref().map_or(1, |p| p.workers.len() + 1)
    }

    /// Run one dependency-free batch: task `i` goes to participant
    /// `i % k` (deterministic, so traces are stable), the caller executes
    /// its own bundle on `backend`/`tracer`, and the call returns the
    /// summed nnz only after every dispatched bundle has acked — the
    /// barrier that makes the raw-pointer views sound.
    pub(crate) fn run_batch(
        &mut self,
        work: Vec<InnerWork>,
        backend: &mut dyn SpmvBackend,
        tracer: &mut RankRecorder,
    ) -> usize {
        let Some(pool) = self.pool.as_ref() else {
            let mut nnz = 0usize;
            for w in &work {
                nnz += exec_work(w, backend, tracer);
            }
            return nnz;
        };
        let k = pool.workers.len() + 1;
        let mut bundles: Vec<Vec<InnerWork>> = (0..k).map(|_| Vec::new()).collect();
        for (i, w) in work.into_iter().enumerate() {
            bundles[i % k].push(w);
        }
        let mut bundles = bundles.into_iter();
        let mine = bundles.next().expect("k >= 1");
        let mut dispatched = 0usize;
        for (tx, bundle) in pool.workers.iter().zip(bundles) {
            if !bundle.is_empty() {
                tx.send(ToWorker::Run(bundle)).expect("inner worker died");
                dispatched += 1;
            }
        }
        let mut nnz = 0usize;
        for w in &mine {
            nnz += exec_work(w, backend, tracer);
        }
        for _ in 0..dispatched {
            nnz += pool.done_rx.recv().expect("inner worker died mid-batch");
        }
        nnz
    }

    /// Drain every worker's lane recorder; returns `(lane, events)` pairs
    /// with lanes numbered from 1 (lane 0 is the rank's main thread).
    pub fn harvest(&mut self) -> Vec<(usize, Vec<Event>)> {
        let Some(pool) = self.pool.as_ref() else {
            return Vec::new();
        };
        let mut out = Vec::with_capacity(pool.workers.len());
        for (w, tx) in pool.workers.iter().enumerate() {
            let (ev_tx, ev_rx) = channel();
            tx.send(ToWorker::Harvest(ev_tx)).expect("inner worker died");
            out.push((w + 1, ev_rx.recv().expect("inner worker died during harvest")));
        }
        out
    }
}

impl Drop for InnerExec {
    fn drop(&mut self) {
        if let Some(mut pool) = self.pool.take() {
            pool.workers.clear(); // closes the job channels
            for h in pool.handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

fn worker_loop(
    jobs: Receiver<ToWorker>,
    done: Sender<usize>,
    mut backend: Box<dyn SpmvBackend + Send>,
    mut tracer: RankRecorder,
) {
    while let Ok(msg) = jobs.recv() {
        match msg {
            ToWorker::Run(bundle) => {
                let mut nnz = 0usize;
                for w in &bundle {
                    nnz += exec_work(w, backend.as_mut(), &mut tracer);
                }
                if done.send(nnz).is_err() {
                    break;
                }
            }
            ToWorker::Harvest(tx) => {
                let _ = tx.send(tracer.take_events());
            }
        }
    }
}

/// Deterministic near-equal split of `[lo, hi)` into at most `k` non-empty
/// contiguous chunks.
pub(crate) fn split_range(lo: usize, hi: usize, k: usize) -> Vec<(usize, usize)> {
    let n = hi.saturating_sub(lo);
    if n == 0 {
        return Vec::new();
    }
    let k = k.clamp(1, n);
    (0..k).map(|i| (lo + n * i / k, lo + n * (i + 1) / k)).collect()
}

/// Split one recurrence step `[lo, hi)` into per-participant [`InnerWork`]
/// chunks and run them as a single batch. All chunks share `power`, so
/// they are mutually independent — used by the TRAD full sweeps and the
/// DLB phase-3 class advances.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_split_range(
    inner: &mut InnerExec,
    a: &CsrMatrix,
    rec: Recurrence,
    prev2: Option<&[f64]>,
    prev: &[f64],
    cur: &mut [f64],
    lo: usize,
    hi: usize,
    power: usize,
    backend: &mut dyn SpmvBackend,
    tracer: &mut RankRecorder,
) -> usize {
    let prev2 = prev2.map(SharedBuf::of);
    let prevv = SharedBuf::of(prev);
    let curv = SharedBufMut::of(cur);
    let work: Vec<InnerWork> = split_range(lo, hi, inner.participants())
        .into_iter()
        .enumerate()
        .map(|(i, (clo, chi))| InnerWork::Range {
            a: MatPtr::of(a),
            rec,
            prev2,
            prev: prevv,
            cur: curv,
            lo: clo,
            hi: chi,
            span: Span::InnerTask { group: i as u32, power: power as u32 },
        })
        .collect();
    inner.run_batch(work, backend, tracer)
}

/// Split a list of contiguous runs — one landed halo segment's class rows,
/// see [`crate::mpk::dlb`]'s async remainder — into per-participant chunks
/// and run them as a single batch: the "batch per landed segment" seam.
/// All chunks share `power` and write disjoint rows, so the batch is
/// dependency-free; a single run produces exactly the tasks
/// [`run_split_range`] would.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_split_runs(
    inner: &mut InnerExec,
    a: &CsrMatrix,
    rec: Recurrence,
    prev2: Option<&[f64]>,
    prev: &[f64],
    cur: &mut [f64],
    runs: &[(usize, usize)],
    power: usize,
    backend: &mut dyn SpmvBackend,
    tracer: &mut RankRecorder,
) -> usize {
    let prev2 = prev2.map(SharedBuf::of);
    let prevv = SharedBuf::of(prev);
    let curv = SharedBufMut::of(cur);
    let k = inner.participants();
    let mut group = 0u32;
    let mut work: Vec<InnerWork> = Vec::new();
    for &(lo, hi) in runs {
        for (clo, chi) in split_range(lo, hi, k) {
            work.push(InnerWork::Range {
                a: MatPtr::of(a),
                rec,
                prev2,
                prev: prevv,
                cur: curv,
                lo: clo,
                hi: chi,
                span: Span::InnerTask { group, power: power as u32 },
            });
            group += 1;
        }
    }
    inner.run_batch(work, backend, tracer)
}

/// One CA promotion round as a single batch: the owned row list plus every
/// still-live external class, each split into per-participant chunks. All
/// tasks write power `p` at disjoint rows and read only power `p − 1`, so
/// the whole round is dependency-free.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_ca_round(
    inner: &mut InnerExec,
    a: &CsrMatrix,
    owned: &[usize],
    ext: &[Vec<usize>],
    p_m: usize,
    p: usize,
    prev: &[f64],
    cur: &mut [f64],
    tracer: &mut RankRecorder,
) -> usize {
    let k = inner.participants();
    let prevv = SharedBuf::of(prev);
    let curv = SharedBufMut::of(cur);
    let mut work: Vec<InnerWork> = Vec::new();
    let mut group = 0u32;
    let mut push_list = |rows: &[usize], work: &mut Vec<InnerWork>, group: &mut u32| {
        for (clo, chi) in split_range(0, rows.len(), k) {
            work.push(InnerWork::Rows {
                a: MatPtr::of(a),
                rows: RowsPtr::of(&rows[clo..chi]),
                prev: prevv,
                cur: curv,
                span: Span::InnerTask { group: *group, power: p as u32 },
            });
            *group += 1;
        }
    };
    push_list(owned, &mut work, &mut group);
    for (kx, cls) in ext.iter().enumerate() {
        let target = p_m.saturating_sub(1).saturating_sub(kx);
        if p <= target {
            push_list(cls, &mut work, &mut group);
        }
    }
    // Rows tasks never touch the backend seam (CA's fixed row loop), but
    // the caller participant still needs one to satisfy `run_batch`.
    let mut host = crate::mpk::NativeBackend;
    inner.run_batch(work, &mut host, tracer)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::mpk::NativeBackend;

    #[test]
    fn split_range_is_deterministic_and_covers() {
        assert_eq!(split_range(0, 10, 3), vec![(0, 3), (3, 6), (6, 10)]);
        assert_eq!(split_range(5, 5, 4), vec![]);
        assert_eq!(split_range(2, 4, 8), vec![(2, 3), (3, 4)], "never emits empty chunks");
        for (k, n) in [(1, 17), (3, 17), (5, 100)] {
            let chunks = split_range(0, n, k);
            assert_eq!(chunks.len(), k);
            assert_eq!(chunks[0].0, 0);
            assert_eq!(chunks.last().unwrap().1, n);
            for w in chunks.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
                assert!(w[0].0 < w[0].1, "non-empty");
            }
        }
    }

    #[test]
    fn parallel_range_batch_is_bitwise_equal_to_serial() {
        let a = gen::stencil_2d_5pt(12, 12);
        let n = a.n_rows();
        let x: Vec<f64> = (0..n).map(|i| ((i % 13) as f64 - 6.0) / 7.0).collect();
        let mut serial = vec![0.0; n];
        let mut be = NativeBackend;
        let nnz_serial =
            kernel_step(&a, Recurrence::Power, None, &x, &mut serial, 0, n, &mut be);
        for k in [2usize, 4] {
            let mut inner = InnerExec::new(k, 0, &BackendSpec::Native, None);
            assert!(inner.is_parallel());
            assert_eq!(inner.participants(), k);
            let mut cur = vec![0.0; n];
            let mut tracer = RankRecorder::disabled();
            let nnz = run_split_range(
                &mut inner,
                &a,
                Recurrence::Power,
                None,
                &x,
                &mut cur,
                0,
                n,
                1,
                &mut be,
                &mut tracer,
            );
            assert_eq!(nnz, nnz_serial);
            for (u, v) in serial.iter().zip(&cur) {
                assert_eq!(u.to_bits(), v.to_bits(), "k={k} differs from serial");
            }
            assert!(inner.harvest().iter().all(|(_, ev)| ev.is_empty()), "untraced: no events");
        }
    }

    #[test]
    fn run_split_runs_is_bitwise_equal_to_per_run_serial() {
        let a = gen::stencil_2d_5pt(12, 12);
        let n = a.n_rows();
        let x: Vec<f64> = (0..n).map(|i| ((i % 13) as f64 - 6.0) / 7.0).collect();
        // Non-contiguous runs, as a landed halo segment's class rows look.
        let runs = [(3usize, 9usize), (17, 18), (40, 71)];
        let mut be = NativeBackend;
        let mut serial = vec![0.0; n];
        let mut nnz_serial = 0;
        for &(lo, hi) in &runs {
            nnz_serial +=
                kernel_step(&a, Recurrence::Power, None, &x, &mut serial, lo, hi, &mut be);
        }
        for k in [2usize, 3] {
            let mut inner = InnerExec::new(k, 0, &BackendSpec::Native, None);
            let mut cur = vec![0.0; n];
            let mut tracer = RankRecorder::disabled();
            let nnz = run_split_runs(
                &mut inner,
                &a,
                Recurrence::Power,
                None,
                &x,
                &mut cur,
                &runs,
                1,
                &mut be,
                &mut tracer,
            );
            assert_eq!(nnz, nnz_serial);
            for (u, v) in serial.iter().zip(&cur) {
                assert_eq!(u.to_bits(), v.to_bits(), "k={k} differs from serial");
            }
        }
    }

    #[test]
    fn serial_executor_has_no_pool() {
        let mut e = InnerExec::serial();
        assert!(!e.is_parallel());
        assert_eq!(e.participants(), 1);
        assert!(e.harvest().is_empty());
        let e1 = InnerExec::new(1, 3, &BackendSpec::Native, None);
        assert!(!e1.is_parallel());
    }

    #[test]
    fn workers_record_lane_events_when_traced() {
        let ts = TraceSession::with_capacity(1, 64);
        let a = gen::stencil_2d_5pt(10, 10);
        let n = a.n_rows();
        let x = vec![1.0; n];
        let mut cur = vec![0.0; n];
        let mut inner = InnerExec::new(2, 0, &BackendSpec::Native, Some(&ts));
        let mut be = NativeBackend;
        let mut tracer = ts.recorder(0);
        run_split_range(
            &mut inner,
            &a,
            Recurrence::Power,
            None,
            &x,
            &mut cur,
            0,
            n,
            1,
            &mut be,
            &mut tracer,
        );
        assert!(tracer.buffered() > 0, "caller participant records on the main lane");
        let lanes = inner.harvest();
        assert_eq!(lanes.len(), 1);
        assert_eq!(lanes[0].0, 1);
        assert!(!lanes[0].1.is_empty(), "worker recorded its inner.task span");
    }
}
