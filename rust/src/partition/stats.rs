//! Partition quality metrics: edge cut, imbalance, halo volume.

use crate::matrix::CsrMatrix;
use crate::partition::Partition;

#[derive(Clone, Debug)]
pub struct PartitionStats {
    /// Number of non-zeros whose row and column live on different parts
    /// (directed count — each cut coupling counted once per matrix entry).
    pub edgecut: usize,
    /// max(part rows) / mean(part rows).
    pub row_imbalance: f64,
    /// max(part nnz) / mean(part nnz).
    pub nnz_imbalance: f64,
    /// Total distinct remote x-elements needed across ranks = Σ_i N_{h,i}.
    pub halo_elements: usize,
}

impl PartitionStats {
    pub fn compute(a: &CsrMatrix, p: &Partition) -> Self {
        let n = a.n_rows();
        let mut edgecut = 0usize;
        let mut rows = vec![0usize; p.n_parts];
        let mut nnz = vec![0usize; p.n_parts];
        // distinct remote columns per part
        let mut halo_sets: Vec<std::collections::HashSet<u32>> =
            vec![Default::default(); p.n_parts];
        for r in 0..n {
            let pr = p.part_of[r] as usize;
            rows[pr] += 1;
            for &c in a.row_cols(r) {
                nnz[pr] += 1;
                if p.part_of[c as usize] != pr as u32 {
                    edgecut += 1;
                    halo_sets[pr].insert(c);
                }
            }
        }
        let mean_rows = n as f64 / p.n_parts as f64;
        let mean_nnz = a.nnz() as f64 / p.n_parts as f64;
        PartitionStats {
            edgecut,
            row_imbalance: rows.iter().copied().max().unwrap_or(0) as f64 / mean_rows,
            nnz_imbalance: nnz.iter().copied().max().unwrap_or(0) as f64 / mean_nnz,
            halo_elements: halo_sets.iter().map(|s| s.len()).sum(),
        }
    }

    /// Paper Eq. (1): O_MPI = Σ N_{h,i} / N_r.
    pub fn mpi_overhead(&self, n_rows: usize) -> f64 {
        self.halo_elements as f64 / n_rows as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::partition::{partition, Method};

    #[test]
    fn tridiag_two_blocks_cut_two() {
        let a = gen::tridiag(10);
        let p = partition(&a, 2, Method::Block);
        let st = PartitionStats::compute(&a, &p);
        // exactly one coupling pair crosses: entries (k, k+1) and (k+1, k)
        assert_eq!(st.edgecut, 2);
        assert_eq!(st.halo_elements, 2);
        assert!((st.mpi_overhead(10) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn imbalance_close_to_one_for_uniform() {
        let a = gen::stencil_2d_5pt(20, 20);
        let p = partition(&a, 4, Method::Block);
        let st = PartitionStats::compute(&a, &p);
        assert!(st.row_imbalance < 1.2);
        assert!(st.nnz_imbalance < 1.2);
    }

    #[test]
    fn methods_produce_comparable_cuts_on_grid() {
        let a = gen::stencil_2d_5pt(24, 24);
        for m in [Method::Block, Method::GreedyGrow, Method::RecursiveBisect] {
            let p = partition(&a, 4, m);
            let st = PartitionStats::compute(&a, &p);
            assert!(st.edgecut > 0 && st.edgecut < a.nnz() / 6, "{m:?}: {}", st.edgecut);
        }
    }
}
