//! Row-wise graph partitioners — the METIS stand-in (DESIGN.md
//! §Substitutions).
//!
//! The paper partitions the global matrix row-wise with METIS "to minimize
//! communication and optimize load balance". We provide three methods with
//! the same contract (a rank id per row):
//!
//! * [`Method::Block`] — contiguous row blocks balanced by non-zeros; the
//!   natural choice after BFS reordering of banded matrices.
//! * [`Method::GreedyGrow`] — greedy graph growing: grow each part by BFS
//!   from a far-apart seed until it reaches its vertex share.
//! * [`Method::RecursiveBisect`] — recursive bisection along the BFS level
//!   order followed by boundary Kernighan–Lin refinement; closest to METIS
//!   quality on the banded matrices used here.

pub mod bisect;
pub mod block;
pub mod greedy;
pub mod stats;

pub use stats::PartitionStats;

use crate::matrix::CsrMatrix;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Block,
    GreedyGrow,
    RecursiveBisect,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        match s {
            "block" => Some(Method::Block),
            "greedy" => Some(Method::GreedyGrow),
            "bisect" => Some(Method::RecursiveBisect),
            _ => None,
        }
    }
}

/// A row-wise partition: `part_of[row] = rank`, ranks in `0..n_parts`.
#[derive(Clone, Debug)]
pub struct Partition {
    pub n_parts: usize,
    pub part_of: Vec<u32>,
}

impl Partition {
    /// Rows owned by each part, in ascending row order.
    pub fn rows_of(&self, part: usize) -> Vec<usize> {
        self.part_of
            .iter()
            .enumerate()
            .filter(|&(_, &p)| p as usize == part)
            .map(|(r, _)| r)
            .collect()
    }

    pub fn part_sizes(&self) -> Vec<usize> {
        let mut s = vec![0usize; self.n_parts];
        for &p in &self.part_of {
            s[p as usize] += 1;
        }
        s
    }

    pub fn validate(&self, n_rows: usize) -> Result<(), String> {
        if self.part_of.len() != n_rows {
            return Err("part_of length mismatch".into());
        }
        if self.n_parts == 0 {
            return Err("zero parts".into());
        }
        for (r, &p) in self.part_of.iter().enumerate() {
            if p as usize >= self.n_parts {
                return Err(format!("row {r} assigned to invalid part {p}"));
            }
        }
        // every part non-empty (required by the distributed runtime)
        let sizes = self.part_sizes();
        if let Some(i) = sizes.iter().position(|&s| s == 0) {
            return Err(format!("part {i} is empty"));
        }
        Ok(())
    }
}

/// Partition `a` into `n_parts` using `method`.
pub fn partition(a: &CsrMatrix, n_parts: usize, method: Method) -> Partition {
    assert!(n_parts >= 1 && n_parts <= a.n_rows());
    let p = match method {
        Method::Block => block::block_partition(a, n_parts),
        Method::GreedyGrow => greedy::greedy_grow(a, n_parts),
        Method::RecursiveBisect => bisect::recursive_bisect(a, n_parts),
    };
    debug_assert!(p.validate(a.n_rows()).is_ok(), "{:?}", p.validate(a.n_rows()));
    p
}
