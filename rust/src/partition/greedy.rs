//! Greedy graph growing (Farhat-style): grow each part by BFS from the
//! vertex farthest from already-assigned territory.

use crate::graph::{bfs_levels, Adjacency};
use crate::matrix::CsrMatrix;
use crate::partition::Partition;

pub fn greedy_grow(a: &CsrMatrix, n_parts: usize) -> Partition {
    let g = Adjacency::from_matrix(a);
    let n = g.n;
    let mut part_of = vec![u32::MAX; n];
    let base = n / n_parts;
    let extra = n % n_parts;
    let mut seed = 0usize; // first seed: vertex 0 (RACE's default root)

    for p in 0..n_parts {
        let target = base + usize::from(p < extra);
        // BFS from seed over unassigned vertices only
        let mut taken = 0usize;
        let mut frontier = vec![seed as u32];
        part_of[seed] = p as u32;
        taken += 1;
        let mut next = Vec::new();
        let mut scan = 0usize;
        while taken < target {
            next.clear();
            for &u in &frontier {
                for &v in g.neighbors(u as usize) {
                    if part_of[v as usize] == u32::MAX && taken < target {
                        part_of[v as usize] = p as u32;
                        next.push(v);
                        taken += 1;
                    }
                }
            }
            if next.is_empty() {
                if taken >= target {
                    break;
                }
                // disconnected remainder: jump to next unassigned vertex
                while scan < n && part_of[scan] != u32::MAX {
                    scan += 1;
                }
                if scan == n {
                    break;
                }
                part_of[scan] = p as u32;
                next.push(scan as u32);
                taken += 1;
            }
            std::mem::swap(&mut frontier, &mut next);
        }
        if p + 1 < n_parts {
            // next seed: unassigned vertex farthest from everything assigned
            // (peripheral seed -> compact parts). One BFS from the current
            // part's frontier approximates this well.
            let sources: Vec<u32> = (0..n as u32).filter(|&v| part_of[v as usize] != u32::MAX).collect();
            let dist = crate::graph::distance::multi_source_distances(&g, &sources);
            let far = (0..n)
                .filter(|&v| part_of[v] == u32::MAX)
                .max_by_key(|&v| if dist[v] == u32::MAX { 0 } else { dist[v] });
            seed = match far {
                Some(v) => v,
                None => break, // everything assigned early
            };
        }
    }
    // safety: sweep any unassigned vertices into the nearest assigned part
    for v in 0..n {
        if part_of[v] == u32::MAX {
            let p = g
                .neighbors(v)
                .iter()
                .find_map(|&u| (part_of[u as usize] != u32::MAX).then(|| part_of[u as usize]))
                .unwrap_or(0);
            part_of[v] = p;
        }
    }
    // guarantee non-emptiness (tiny graphs): steal a row for empty parts
    let mut sizes = vec![0usize; n_parts];
    for &p in &part_of {
        sizes[p as usize] += 1;
    }
    for p in 0..n_parts {
        if sizes[p] == 0 {
            let donor = (0..n).find(|&v| sizes[part_of[v] as usize] > 1).unwrap();
            sizes[part_of[donor] as usize] -= 1;
            part_of[donor] = p as u32;
            sizes[p] += 1;
        }
    }
    let _ = bfs_levels; // (referenced for doc parity)
    Partition { n_parts, part_of }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::partition::stats::PartitionStats;

    #[test]
    fn covers_all_vertices_balanced() {
        let a = gen::stencil_2d_5pt(20, 20);
        let p = greedy_grow(&a, 4);
        p.validate(400).unwrap();
        for &s in &p.part_sizes() {
            assert!((80..=120).contains(&s), "size {s}");
        }
    }

    #[test]
    fn parts_are_mostly_connected_and_cut_is_sane() {
        let a = gen::stencil_2d_5pt(24, 24);
        let p = greedy_grow(&a, 4);
        let st = PartitionStats::compute(&a, &p);
        // a 24x24 grid split in 4 should cut far fewer than half the edges
        assert!(st.edgecut < a.nnz() / 8, "edgecut {}", st.edgecut);
    }

    #[test]
    fn handles_more_parts_than_structure() {
        let a = gen::tridiag(12);
        let p = greedy_grow(&a, 6);
        p.validate(12).unwrap();
    }
}
