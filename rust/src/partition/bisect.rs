//! Recursive bisection with boundary Kernighan–Lin/Fiduccia–Mattheyses-style
//! refinement — the highest-quality METIS stand-in in this crate.
//!
//! Each bisection splits a vertex subset in two halves along its BFS level
//! order (a good starting cut for the banded matrices this paper targets),
//! then sweeps boundary vertices with positive KL gain across the cut while
//! balance permits.

use crate::graph::Adjacency;
use crate::matrix::CsrMatrix;
use crate::partition::Partition;

pub fn recursive_bisect(a: &CsrMatrix, n_parts: usize) -> Partition {
    let g = Adjacency::from_matrix(a);
    let mut part_of = vec![0u32; g.n];
    let all: Vec<u32> = (0..g.n as u32).collect();
    let mut next_id = 0u32;
    bisect_rec(&g, &all, n_parts, &mut part_of, &mut next_id);
    Partition { n_parts, part_of }
}

fn bisect_rec(g: &Adjacency, verts: &[u32], parts: usize, part_of: &mut [u32], next_id: &mut u32) {
    if parts == 1 {
        let id = *next_id;
        *next_id += 1;
        for &v in verts {
            part_of[v as usize] = id;
        }
        return;
    }
    let left_parts = parts / 2;
    let right_parts = parts - left_parts;
    // target |left| proportional to its share of parts
    let target_left = verts.len() * left_parts / parts;

    // BFS order within this subset from its first vertex
    let order = local_bfs_order(g, verts);
    let mut side = vec![false; g.n]; // true = right
    for (i, &v) in order.iter().enumerate() {
        side[v as usize] = i >= target_left;
    }
    kl_refine(g, verts, &mut side, target_left);

    let (mut left, mut right) = (Vec::new(), Vec::new());
    for &v in verts {
        if side[v as usize] {
            right.push(v);
        } else {
            left.push(v);
        }
    }
    // degenerate guard: never recurse on an empty side
    if left.is_empty() {
        left.push(right.pop().unwrap());
    }
    if right.is_empty() {
        right.push(left.pop().unwrap());
    }
    bisect_rec(g, &left, left_parts, part_of, next_id);
    bisect_rec(g, &right, right_parts, part_of, next_id);
}

/// BFS order over the induced subgraph (restarting on disconnection).
fn local_bfs_order(g: &Adjacency, verts: &[u32]) -> Vec<u32> {
    let mut in_set = vec![false; g.n];
    for &v in verts {
        in_set[v as usize] = true;
    }
    let mut seen = vec![false; g.n];
    let mut order = Vec::with_capacity(verts.len());
    let mut queue = std::collections::VecDeque::new();
    let mut scan = 0usize;
    while order.len() < verts.len() {
        // find next unvisited vertex of the subset
        while scan < verts.len() && seen[verts[scan] as usize] {
            scan += 1;
        }
        let root = verts[scan];
        seen[root as usize] = true;
        queue.push_back(root);
        while let Some(u) = queue.pop_front() {
            order.push(u);
            for &v in g.neighbors(u as usize) {
                if in_set[v as usize] && !seen[v as usize] {
                    seen[v as usize] = true;
                    queue.push_back(v);
                }
            }
        }
    }
    order
}

/// One KL/FM pass: move boundary vertices with positive gain, keeping the
/// left side within ±5% of `target_left`.
fn kl_refine(g: &Adjacency, verts: &[u32], side: &mut [bool], target_left: usize) {
    let slack = (verts.len() / 20).max(1);
    let mut left_count = verts.iter().filter(|&&v| !side[v as usize]).count();
    for _pass in 0..4 {
        let mut moved = 0usize;
        for &v in verts {
            let vu = v as usize;
            // gain = external - internal edges
            let (mut ext, mut int) = (0isize, 0isize);
            for &u in g.neighbors(vu) {
                // neighbors outside `verts` don't count; side[] defaults are
                // fine because cut edges to other subsets are fixed costs
                if side[u as usize] == side[vu] {
                    int += 1;
                } else {
                    ext += 1;
                }
            }
            if ext > int {
                let to_right = !side[vu];
                let new_left = if to_right { left_count - 1 } else { left_count + 1 };
                if new_left.abs_diff(target_left) <= slack {
                    side[vu] = !side[vu];
                    left_count = new_left;
                    moved += 1;
                }
            }
        }
        if moved == 0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::partition::stats::PartitionStats;

    #[test]
    fn bisect_grid_is_balanced() {
        let a = gen::stencil_2d_5pt(16, 16);
        let p = recursive_bisect(&a, 4);
        p.validate(256).unwrap();
        for &s in &p.part_sizes() {
            assert!((44..=84).contains(&s), "size {s}");
        }
    }

    #[test]
    fn bisect_cut_beats_random() {
        let a = gen::stencil_2d_5pt(24, 24);
        let p = recursive_bisect(&a, 4);
        let st = PartitionStats::compute(&a, &p);
        // random 4-way cut of a grid ≈ 3/4 of edges; we need far better
        assert!(st.edgecut < a.nnz() / 6, "edgecut {}", st.edgecut);
    }

    #[test]
    fn works_for_non_power_of_two() {
        let a = gen::stencil_2d_5pt(15, 14);
        let p = recursive_bisect(&a, 3);
        p.validate(210).unwrap();
        for &s in &p.part_sizes() {
            assert!((50..=90).contains(&s), "size {s}");
        }
    }

    #[test]
    fn one_part() {
        let a = gen::tridiag(7);
        let p = recursive_bisect(&a, 1);
        assert!(p.part_of.iter().all(|&x| x == 0));
    }
}
