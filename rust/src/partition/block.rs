//! Contiguous block partitioning balanced by non-zeros.

use crate::matrix::CsrMatrix;
use crate::partition::Partition;

/// Split rows into `n_parts` contiguous blocks with ~equal nnz (greedy
/// cut: close a block once it reaches its fair share of the remainder).
pub fn block_partition(a: &CsrMatrix, n_parts: usize) -> Partition {
    let n = a.n_rows();
    let total_nnz = a.nnz();
    let mut part_of = vec![0u32; n];
    let mut row = 0usize;
    let mut used_nnz = 0usize;
    for p in 0..n_parts {
        let remaining_parts = n_parts - p;
        let target = (total_nnz - used_nnz) / remaining_parts;
        let mut acc = 0usize;
        let start = row;
        // leave enough rows for the remaining parts
        let row_cap = n - (remaining_parts - 1);
        while row < row_cap && (acc < target || row == start) {
            acc += a.rowptr[row + 1] - a.rowptr[row];
            part_of[row] = p as u32;
            row += 1;
            if acc >= target && row > start {
                break;
            }
        }
        used_nnz += acc;
    }
    // tail rows go to the last part
    for r in row..n {
        part_of[r] = (n_parts - 1) as u32;
    }
    Partition { n_parts, part_of }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    #[test]
    fn blocks_are_contiguous_and_balanced() {
        let a = gen::stencil_2d_5pt(32, 32);
        let p = block_partition(&a, 4);
        p.validate(a.n_rows()).unwrap();
        // contiguity: part ids are non-decreasing
        for w in p.part_of.windows(2) {
            assert!(w[0] <= w[1]);
        }
        // nnz balance within 25%
        let mut nnz = vec![0usize; 4];
        for r in 0..a.n_rows() {
            nnz[p.part_of[r] as usize] += a.row_cols(r).len();
        }
        let avg = a.nnz() / 4;
        for &z in &nnz {
            assert!(z.abs_diff(avg) < avg / 4, "nnz {z} vs avg {avg}");
        }
    }

    #[test]
    fn single_part_takes_all() {
        let a = gen::tridiag(10);
        let p = block_partition(&a, 1);
        assert!(p.part_of.iter().all(|&x| x == 0));
    }

    #[test]
    fn n_parts_equals_rows() {
        let a = gen::tridiag(5);
        let p = block_partition(&a, 5);
        p.validate(5).unwrap();
        assert_eq!(p.part_sizes(), vec![1; 5]);
    }
}
