//! End-to-end driver (paper §7, Fig. 11): Chebyshev time propagation of a
//! wave packet on the Anderson model of localization — the "quantum
//! boomerang" study — through the full three-layer stack.
//!
//! * Layer 1/2: the fused `cheb_step` Pallas/JAX artifact (AOT, PJRT).
//! * Layer 3: the rust coordinator — spectral scaling, Bessel coefficients,
//!   accumulation, observables — plus the cache-blocked DLB-MPK engine for
//!   the performance comparison (TRAD vs DLB).
//!
//! The paper's testbed used L = 3000×100×100 over 832 cores; scaled here to
//! a weakly-coupled-chains lattice of 512×8×8 = 32768 sites (the shape the
//! stock artifact is compiled for). Physics reproduced: with
//! t_perp/t = 0.001 (localized) the packet's center of mass returns toward
//! the origin; with t_perp/t = 0.1 (delocalized) it stays displaced.
//!
//! Run: `cargo run --release --example chebyshev_anderson [-- --fast]`
//! Results are recorded in EXPERIMENTS.md.

use std::f64::consts::FRAC_PI_2;

use dlb_mpk::apps::chebyshev::{wave_packet, ChebyshevConfig, ChebyshevPropagator, State};
use dlb_mpk::apps::observables::center_of_mass;
use dlb_mpk::distsim::DistMatrix;
use dlb_mpk::engine::{EngineConfig, Variant};
use dlb_mpk::exec::ExecutorKind;
use dlb_mpk::matrix::anderson::{anderson, AndersonConfig};
use dlb_mpk::matrix::EllChunk;
use dlb_mpk::mpk::dlb::DlbOptions;
use dlb_mpk::partition::{partition, Method};
use dlb_mpk::perf::median_time;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let steps = if fast { 8 } else { 40 };
    let dt = 2.0;

    // Part 1 — physics: localized vs delocalized center-of-mass motion.
    // (W/t = 2 shortens the localization length so the boomerang return is
    // visible within the scaled lattice/time window; the paper's W/t = 1 at
    // L_x = 3000 and τ ≫ 100 shows the same contrast.)
    println!("== Quantum boomerang (Fig. 11b analogue) ==");
    println!("lattice 512×8×8, W/t = 2, k0 = (π/2)·e_x, dt = {dt}, {steps} steps\n");
    for (label, t_perp) in [("localized   t⊥/t = 0.001", 0.001), ("delocalized t⊥/t = 0.1", 0.1)] {
        let cfg = AndersonConfig { lx: 512, ly: 8, lz: 8, w: 2.0, t: 1.0, t_perp, seed: 20240710 };
        let traj = propagate_native(&cfg, dt, steps)?;
        let first = traj.first().copied().unwrap_or(0.0);
        let last = traj.last().copied().unwrap_or(0.0);
        let peak = traj.iter().cloned().fold(f64::MIN, f64::max);
        println!("{label}: ⟨x⟩ trajectory (every 4th step):");
        let pretty: Vec<String> = traj.iter().step_by(4).map(|v| format!("{v:+.2}")).collect();
        println!("  [{}]", pretty.join(", "));
        println!("  first {first:+.3} → peak {peak:+.3} → final {last:+.3}\n");
    }

    // Part 2 — three-layer XLA path on the 32³ isotropic artifact shape.
    let art_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !cfg!(feature = "xla") {
        println!("(built without the `xla` feature; skipping XLA path)");
    } else if art_dir.join("manifest.json").exists() {
        println!("== Three-layer path: cheb_step artifact (Pallas/JAX → PJRT) ==");
        run_xla_path(&art_dir, if fast { 2 } else { 4 })?;
    } else {
        println!("(artifacts not built; skipping XLA path — run `make artifacts`)");
    }

    // Part 3 — performance: TRAD vs DLB engines on a big lattice, driven
    // through real rank threads (the engine's persistent pool — the
    // recurrence's thousands of sweeps reuse one set of spawned threads).
    println!("\n== Engine comparison (TRAD vs DLB, threads executor) ==");
    let l = if fast { 48 } else { 96 };
    let acfg = AndersonConfig { lx: l * 4, ly: l / 2, lz: l / 2, w: 1.0, t: 1.0, t_perp: 1.0, seed: 7 };
    let h = anderson(&acfg);
    println!(
        "lattice {}×{}×{}: {} sites, CRS {} MiB",
        acfg.lx, acfg.ly, acfg.lz, h.n_rows(), h.crs_bytes() >> 20
    );
    let part = partition(&h, 4, Method::RecursiveBisect);
    let dist = DistMatrix::build(&h, &part);
    let psi0 = wave_packet(&acfg, 6.0, [FRAC_PI_2, 0.0, 0.0]);
    let mut times = Vec::new();
    let variants = [
        ("trad", Variant::Trad),
        ("dlb", Variant::Dlb(DlbOptions { cache_bytes: 24 << 20, s_m: 50, async_remainder: false })),
    ];
    for (name, variant) in variants {
        let ccfg = ChebyshevConfig {
            dt: 0.5,
            p_m: 8,
            engine: EngineConfig {
                variant,
                executor: ExecutorKind::Threads { n: 0 },
                ..EngineConfig::default()
            },
        };
        let mut prop = ChebyshevPropagator::new(&h, &dist, ccfg)?;
        let mut out = State::zeros(0);
        let t = median_time(if fast { 1 } else { 3 }, || {
            out = prop.step(&psi0);
        });
        let pool = prop.engine().pool_stats().expect("threads executor keeps a pool");
        println!(
            "{name}: {:.3}s/step ({} Chebyshev terms), norm² = {:.9}, pool {} threads / {} sweeps",
            t.median_s,
            prop.n_terms,
            out.norm2(),
            pool.threads,
            pool.sweeps
        );
        times.push(t.median_s);
    }
    println!("DLB speedup over TRAD: {:.2}x", times[0] / times[1]);
    Ok(())
}

/// Propagate with the native DLB engine; returns the ⟨x⟩ trajectory.
fn propagate_native(cfg: &AndersonConfig, dt: f64, steps: usize) -> anyhow::Result<Vec<f64>> {
    let h = anderson(cfg);
    let part = partition(&h, 2, Method::Block);
    let dist = DistMatrix::build(&h, &part);
    let ccfg = ChebyshevConfig {
        dt,
        p_m: 6,
        engine: EngineConfig {
            variant: Variant::Dlb(DlbOptions { cache_bytes: 8 << 20, s_m: 50, async_remainder: false }),
            ..EngineConfig::default()
        },
    };
    let mut prop = ChebyshevPropagator::new(&h, &dist, ccfg)?;
    let mut psi = wave_packet(cfg, 10.0, [FRAC_PI_2, 0.0, 0.0]);
    let mut traj = Vec::with_capacity(steps);
    for _ in 0..steps {
        psi = prop.step(&psi);
        traj.push(center_of_mass(cfg, &psi.density())[0]);
    }
    Ok(traj)
}

/// Drive the Chebyshev recurrence entirely through the AOT artifact: rust
/// owns coefficients + accumulation, every `v_{k+1} = 2Hv_k − v_{k−1}` is
/// one PJRT call into the Pallas kernel pair.
fn run_xla_path(art_dir: &std::path::Path, steps: usize) -> anyhow::Result<()> {
    use dlb_mpk::apps::bessel::bessel_j_array;
    use dlb_mpk::runtime::backend::XlaChebStep;
    use dlb_mpk::runtime::Runtime;

    let cfg = AndersonConfig::isotropic(32, 1.0, 99);
    let mut h = anderson(&cfg);
    let a = h.inf_norm();
    h.scale(1.0 / a);
    let n = h.n_rows();
    let ell = EllChunk::from_csr_rows(&h, 0, n, 256, 7);

    let rt = Runtime::load(art_dir)?;
    let stepper = XlaChebStep::new(&rt, n, 7, n)?;
    let dt = 0.5f64;
    let z = a * dt;
    let n_terms = dlb_mpk::apps::bessel::chebyshev_terms(z);
    let coeffs = bessel_j_array(n_terms, z);

    let mut psi = wave_packet(&cfg, 4.0, [FRAC_PI_2, 0.0, 0.0]);
    let t0 = std::time::Instant::now();
    for s in 0..steps {
        // Chebyshev accumulation with the recurrence on the XLA path
        let mut out = State::zeros(n);
        axpy(&mut out.re, coeffs[0], &psi.re);
        axpy(&mut out.im, coeffs[0], &psi.im);
        let mut v_prev = psi.clone();
        // wind-up v1 = H v0 : use cheb_step with vprev = 0 then halve
        // (2Hv − 0 = 2Hv), i.e. v1 = result/2
        let (mut r1, mut i1) = stepper.step(&ell, &psi.re, &psi.im, &vec![0.0; n], &vec![0.0; n])?;
        for v in r1.iter_mut().chain(i1.iter_mut()) {
            *v *= 0.5;
        }
        let mut v_cur = State { re: r1, im: i1 };
        accumulate(&mut out, 1, coeffs[1], &v_cur);
        for k in 2..=n_terms {
            let (r, i) = stepper.step(&ell, &v_cur.re, &v_cur.im, &v_prev.re, &v_prev.im)?;
            v_prev = std::mem::replace(&mut v_cur, State { re: r, im: i });
            accumulate(&mut out, k, coeffs[k], &v_cur);
        }
        psi = out;
        println!(
            "  xla step {:>2}: norm² = {:.12}  ⟨x⟩ = {:+.3}",
            s + 1,
            psi.norm2(),
            center_of_mass(&cfg, &psi.density())[0]
        );
    }
    let dt_wall = t0.elapsed().as_secs_f64() / steps as f64;
    println!("  ({n_terms} PJRT calls/step, {dt_wall:.2}s/step on the interpret-mode kernel)");
    Ok(())
}

fn accumulate(out: &mut State, k: usize, jk: f64, v: &State) {
    let c = 2.0 * jk;
    match k % 4 {
        0 => {
            axpy(&mut out.re, c, &v.re);
            axpy(&mut out.im, c, &v.im);
        }
        1 => {
            axpy(&mut out.re, c, &v.im);
            axpy(&mut out.im, -c, &v.re);
        }
        2 => {
            axpy(&mut out.re, -c, &v.re);
            axpy(&mut out.im, -c, &v.im);
        }
        _ => {
            axpy(&mut out.re, -c, &v.im);
            axpy(&mut out.im, c, &v.re);
        }
    }
}

fn axpy(y: &mut [f64], a: f64, x: &[f64]) {
    for (yv, xv) in y.iter_mut().zip(x) {
        *yv += a * xv;
    }
}
