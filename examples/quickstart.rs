//! Quickstart: the DLB-MPK public API in ~60 lines.
//!
//! 1. Build a sparse matrix (2D 5-point stencil).
//! 2. Partition row-wise and distribute over simulated MPI ranks.
//! 3. Compute y_p = A^p x for p = 1..4 with TRAD and DLB-MPK; compare.
//! 4. Route the same SpMV through the AOT Pallas/JAX artifact via PJRT
//!    (the three-layer path; requires `make artifacts`).
//!
//! Run: `cargo run --release --example quickstart`

use dlb_mpk::distsim::DistMatrix;
use dlb_mpk::matrix::{gen, EllChunk};
use dlb_mpk::mpk::{self, MpkVariant};
use dlb_mpk::partition::{partition, Method};
use dlb_mpk::runtime::{Runtime, XlaSpmv};

fn main() -> anyhow::Result<()> {
    // 64×64 stencil: 4096 rows — matches the demo AOT artifact shape.
    let a = gen::stencil_2d_5pt(64, 64);
    println!(
        "matrix: {} rows, {} nnz, {} KiB CRS",
        a.n_rows(),
        a.nnz(),
        a.crs_bytes() >> 10
    );

    // Partition over 4 simulated ranks and build the distributed form.
    let part = partition(&a, 4, Method::GreedyGrow);
    let dist = DistMatrix::build(&a, &part);
    println!("partitioned over {} ranks, O_MPI = {:.4}", dist.n_ranks(), dist.mpi_overhead());

    // Matrix power kernel: y_p = A^p x, p = 1..=4.
    let x = vec![1.0; a.n_rows()];
    let p_m = 4;
    let trad = mpk::run(&dist, &x, p_m, MpkVariant::Trad);
    let dlb = mpk::run(&dist, &x, p_m, MpkVariant::Dlb { cache_bytes: 1 << 20 });

    let max_diff: f64 = trad
        .powers
        .iter()
        .flatten()
        .zip(dlb.powers.iter().flatten())
        .map(|(u, v)| (u - v).abs())
        .fold(0.0, f64::max);
    println!("TRAD vs DLB: max |Δ| = {max_diff:.2e} over {} powers", p_m);
    println!(
        "comm: TRAD {} B in {} rounds | DLB {} B in {} rounds (identical by design)",
        trad.comm.bytes, trad.comm.rounds, dlb.comm.bytes, dlb.comm.rounds
    );

    // Three-layer path: the same SpMV through the AOT Pallas kernel on PJRT.
    let art_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !cfg!(feature = "xla") {
        println!("(built without the `xla` feature; skipping XLA path)");
    } else if art_dir.join("manifest.json").exists() {
        let rt = Runtime::load(&art_dir)?;
        let ell = EllChunk::from_csr_rows(&a, 0, a.n_rows(), 256, 5);
        let xla = XlaSpmv::new(&rt, ell.rows, ell.width, a.n_rows())?;
        let y_xla = xla.spmv(&ell, &x)?;
        let mut y_native = vec![0.0; a.n_rows()];
        a.spmv(&x, &mut y_native);
        let d: f64 = y_xla
            .iter()
            .zip(&y_native)
            .map(|(u, v)| (u - v).abs())
            .fold(0.0, f64::max);
        println!("XLA (Pallas spmv_ell artifact, platform {}): max |Δ| = {d:.2e}", rt.platform());
    } else {
        println!("artifacts/ not built — run `make artifacts` for the XLA path");
    }
    Ok(())
}
