//! Quickstart: the DLB-MPK public API in ~70 lines.
//!
//! 1. Build a sparse matrix (2D 5-point stencil).
//! 2. Partition row-wise and distribute over simulated MPI ranks.
//! 3. Build one `MpkEngine` per variant — the prepare-once/apply-many
//!    session object — and sweep `y_p = A^p x` for p = 1..4; compare.
//! 4. Rebuild the DLB engine on the threads executor: same numbers, real
//!    OS-thread ranks behind a persistent pool (spawned once, reused by
//!    every sweep).
//! 5. Add the second hierarchy level with `.inner_threads(2)`: each rank
//!    thread row-splits its wavefront over an inner worker pool —
//!    ranks × threads, still bitwise identical.
//! 6. Turn on span tracing and read back aggregated metrics — the same
//!    recorder that `dlb-mpk anderson --trace-out trace.json` uses to
//!    write a Chrome Trace Event file for chrome://tracing / Perfetto.
//! 7. Statically verify the plans with `.verify_plans(true)`: the engine
//!    machine-checks schedule independence, send/recv matching, and the
//!    async partition at prepare time (on by default in debug builds;
//!    standalone: `dlb-mpk verify`).
//! 8. Route the same SpMV through the AOT Pallas/JAX artifact via PJRT
//!    (the three-layer path; requires `make artifacts`).
//!
//! Run: `cargo run --release --example quickstart`

use dlb_mpk::distsim::DistMatrix;
use dlb_mpk::engine::{MpkEngine, Variant};
use dlb_mpk::exec::ExecutorKind;
use dlb_mpk::matrix::{gen, EllChunk};
use dlb_mpk::mpk::dlb::{DlbOptions, Recurrence};
use dlb_mpk::partition::{partition, Method};
use dlb_mpk::runtime::{Runtime, XlaSpmv};

fn main() -> anyhow::Result<()> {
    // 64×64 stencil: 4096 rows — matches the demo AOT artifact shape.
    let a = gen::stencil_2d_5pt(64, 64);
    println!(
        "matrix: {} rows, {} nnz, {} KiB CRS",
        a.n_rows(),
        a.nnz(),
        a.crs_bytes() >> 10
    );

    // Partition over 4 simulated ranks and build the distributed form.
    let part = partition(&a, 4, Method::GreedyGrow);
    let dist = DistMatrix::build(&a, &part);
    println!("partitioned over {} ranks, O_MPI = {:.4}", dist.n_ranks(), dist.mpi_overhead());

    // Matrix power kernel: y_p = A^p x, p = 1..=4, via prepared engines.
    // Building pays for planning (levels, permutation, schedule) once;
    // every sweep after that reuses it.
    let x = vec![1.0; a.n_rows()];
    let p_m = 4;
    let dlb_opts = DlbOptions { cache_bytes: 1 << 20, s_m: 50, async_remainder: false };
    let mut trad_eng = MpkEngine::builder(&dist).p_m(p_m).variant(Variant::Trad).build()?;
    let mut dlb_eng =
        MpkEngine::builder(&dist).p_m(p_m).variant(Variant::Dlb(dlb_opts)).build()?;
    let trad = trad_eng.sweep(&x, None, Recurrence::Power);
    let dlb = dlb_eng.sweep(&x, None, Recurrence::Power);

    let max_diff: f64 = trad
        .powers
        .iter()
        .flatten()
        .zip(dlb.powers.iter().flatten())
        .map(|(u, v)| (u - v).abs())
        .fold(0.0, f64::max);
    println!("TRAD vs DLB: max |Δ| = {max_diff:.2e} over {} powers", p_m);
    println!(
        "comm: TRAD {} B in {} rounds | DLB {} B in {} rounds (identical by design)",
        trad.comm.bytes, trad.comm.rounds, dlb.comm.bytes, dlb.comm.rounds
    );

    // Same engine API on the threads executor: one OS thread per rank,
    // parked in a persistent pool — several sweeps, one spawn.
    let mut thr_eng = MpkEngine::builder(&dist)
        .p_m(p_m)
        .variant(Variant::Dlb(dlb_opts))
        .executor(ExecutorKind::Threads { n: 0 })
        .build()?;
    let t1 = thr_eng.sweep(&x, None, Recurrence::Power);
    let _t2 = thr_eng.sweep(&x, None, Recurrence::Power);
    let pool = thr_eng.pool_stats().expect("threads executor keeps a pool");
    assert_eq!(t1.powers, dlb.powers, "threads executor is bitwise-identical to sim");
    println!(
        "threads executor: {} rank threads spawned once, {} sweeps dispatched, bitwise equal to sim",
        pool.threads, pool.sweeps
    );

    // Hierarchical execution: ranks × inner threads. Each pooled rank
    // thread runs its per-level compute as dependency-free task batches on
    // a 2-worker inner pool (`--inner-threads 2` on the CLI). The batches
    // partition disjoint row ranges per power, so the result stays bitwise
    // identical to serial — assert it.
    let mut hier_eng = MpkEngine::builder(&dist)
        .p_m(p_m)
        .variant(Variant::Dlb(dlb_opts))
        .executor(ExecutorKind::Threads { n: 0 })
        .inner_threads(2)
        .build()?;
    let h1 = hier_eng.sweep(&x, None, Recurrence::Power);
    assert_eq!(h1.powers, dlb.powers, "inner threads are bitwise-identical to serial");
    assert_eq!(h1.comm, dlb.comm, "inner threads never change communication");
    println!(
        "hierarchical: {} ranks x {} inner threads, bitwise equal to serial",
        dist.n_ranks(),
        hier_eng.inner_threads()
    );

    // Observability: the same engine with span tracing on. Results stay
    // bitwise identical; metrics() aggregates per-rank compute/wait/flow
    // totals, and chrome_trace_json() exports the raw timeline (on the
    // CLI: `dlb-mpk anderson --trace-out trace.json`, checked by
    // `dlb-mpk trace-check trace.json`).
    let mut traced_eng = MpkEngine::builder(&dist)
        .p_m(p_m)
        .variant(Variant::Dlb(dlb_opts))
        .executor(ExecutorKind::Threads { n: 0 })
        .trace(true)
        .build()?;
    let traced = traced_eng.sweep(&x, None, Recurrence::Power);
    assert_eq!(traced.powers, dlb.powers, "tracing never changes results");
    let m = traced_eng.metrics().expect("tracing enabled");
    println!(
        "traced sweep: {} ranks | compute {:.3} ms | barrier wait {:.3} ms | {} msgs / {} B",
        m.per_rank.len(),
        m.total_compute_ns as f64 / 1e6,
        m.total_wait_ns as f64 / 1e6,
        m.total_messages,
        m.total_bytes
    );

    // Static verification: `.verify_plans(true)` runs the `verify` module's
    // four analyzers (schedule races, inner-split aliasing, send/recv
    // matching + deadlock, async partition) over the prepared plans before
    // the first sweep — build() fails with rule-tagged diagnostics if any
    // invariant breaks. Default-on in debug builds, explicit here because
    // examples compile in release; nothing runs on the sweep hot path.
    let mut verified_eng = MpkEngine::builder(&dist)
        .p_m(p_m)
        .variant(Variant::Dlb(dlb_opts))
        .verify_plans(true)
        .build()?;
    let v1 = verified_eng.sweep(&x, None, Recurrence::Power);
    assert_eq!(v1.powers, dlb.powers, "verification never changes results");
    println!(
        "static verification: plans checked at prepare time (verify_plans = {})",
        verified_eng.verifies_plans()
    );

    // Three-layer path: the same SpMV through the AOT Pallas kernel on PJRT.
    let art_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !cfg!(feature = "xla") {
        println!("(built without the `xla` feature; skipping XLA path)");
    } else if art_dir.join("manifest.json").exists() {
        let rt = Runtime::load(&art_dir)?;
        let ell = EllChunk::from_csr_rows(&a, 0, a.n_rows(), 256, 5);
        let xla = XlaSpmv::new(&rt, ell.rows, ell.width, a.n_rows())?;
        let y_xla = xla.spmv(&ell, &x)?;
        let mut y_native = vec![0.0; a.n_rows()];
        a.spmv(&x, &mut y_native);
        let d: f64 = y_xla
            .iter()
            .zip(&y_native)
            .map(|(u, v)| (u - v).abs())
            .fold(0.0, f64::max);
        println!("XLA (Pallas spmv_ell artifact, platform {}): max |Δ| = {d:.2e}", rt.platform());
    } else {
        println!("artifacts/ not built — run `make artifacts` for the XLA path");
    }
    Ok(())
}
