//! Scaling demo (paper §6.4 strong scaling / §7 weak scaling, condensed).
//!
//! Strong scaling: fixed matrix over a growing number of simulated ranks —
//! exact O_MPI / O_DLB overheads plus modeled parallel efficiency (the
//! single-core testbed measures per-rank compute sequentially and combines
//! it with the α-β communication model; DESIGN.md §Substitutions).
//!
//! Weak scaling: Anderson lattice grown with the rank count (Table 5
//! ladder), TRAD vs DLB per-rank throughput.
//!
//! Run: `cargo run --release --example scaling [-- --fast]`

use dlb_mpk::coordinator::MatrixSpec;
use dlb_mpk::distsim::costmodel::halo_traffic;
use dlb_mpk::distsim::{CommCostModel, DistMatrix};
use dlb_mpk::matrix::anderson::weak_scaling_configs;
use dlb_mpk::mpk::dlb::{self, DlbOptions};
use dlb_mpk::mpk::{overheads, NativeBackend};
use dlb_mpk::partition::{partition, Method};
use dlb_mpk::perf::median_time;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    strong_scaling(fast)?;
    weak_scaling(fast)?;
    Ok(())
}

fn strong_scaling(fast: bool) -> anyhow::Result<()> {
    println!("== Strong scaling (fixed matrix, growing ranks) ==");
    let spec = if fast {
        MatrixSpec::Banded { n: 120_000, nnzr: 16, band: 800, seed: 3 }
    } else {
        MatrixSpec::Banded { n: 600_000, nnzr: 16, band: 2_000, seed: 3 }
    };
    let a = spec.build()?;
    println!("matrix: {} rows, {} MiB CRS, p_m = 4\n", a.n_rows(), a.crs_bytes() >> 20);
    println!(
        "{:>5} {:>8} {:>8} {:>10} {:>10} {:>8}",
        "ranks", "O_MPI", "O_DLB", "T_model_s", "eff", "comm_us"
    );
    let model = CommCostModel::default();
    let opts = DlbOptions { cache_bytes: 8 << 20, s_m: 50, async_remainder: false };
    let p_m = 4;
    let mut t1 = 0.0f64;
    for np in [1usize, 2, 4, 8, 16] {
        let part = partition(&a, np, Method::RecursiveBisect);
        let dist = DistMatrix::build(&a, &part);
        let plan = dlb::plan(&dist, p_m, &opts);
        let o_dlb = overheads::dlb_overhead_from_plan(&plan);
        let x = vec![1.0; a.n_rows()];

        // per-rank compute measured sequentially; critical path = max
        let t_compute = {
            let t = median_time(if fast { 1 } else { 3 }, || {
                let _ = dlb::execute(&plan, &x, &mut NativeBackend);
            });
            // sequential total / ranks ≈ per-rank (balanced partitions), but
            // take imbalance into account via nnz share
            let max_nnz = plan.dist.ranks.iter().map(|r| r.a.nnz()).max().unwrap() as f64;
            t.median_s * max_nnz / a.nnz() as f64
        };
        let t_comm = (p_m as f64) * model.round_time(&halo_traffic(&plan.dist.ranks));
        let t_model = t_compute + t_comm;
        if np == 1 {
            t1 = t_model;
        }
        let eff = t1 / (np as f64 * t_model) * 1.0_f64.max(1.0);
        println!(
            "{np:>5} {:>8.4} {:>8.4} {:>10.4} {:>10.2} {:>8.1}",
            dist.mpi_overhead(),
            o_dlb,
            t_model,
            eff * np as f64, // ε_strong = T1/(n·Tn) · n = speedup/n·n ... report speedup-normalized
            t_comm * 1e6
        );
    }
    println!("(T_model = max-rank compute + α-β comm; ε reported as T1/Tn)\n");
    Ok(())
}

fn weak_scaling(fast: bool) -> anyhow::Result<()> {
    println!("== Weak scaling (Anderson ladder, Table 5 analogue) ==");
    let base_l = if fast { 24 } else { 48 };
    let domains = if fast { vec![1usize, 2, 4] } else { vec![1usize, 2, 4, 8] };
    let cfgs = weak_scaling_configs(base_l, &domains, 1.0, 11);
    println!(
        "{:>7} {:>14} {:>10} {:>8} {:>10} {:>10} {:>8}",
        "domains", "(Lx,Ly,Lz)", "rows", "MiB", "T_trad_s", "T_dlb_s", "speedup"
    );
    for (d, cfg) in domains.iter().zip(&cfgs) {
        let h = dlb_mpk::matrix::anderson::anderson(cfg);
        let part = partition(&h, *d, Method::RecursiveBisect);
        let dist = DistMatrix::build(&h, &part);
        let x = vec![1.0; h.n_rows()];
        let p_m = 6;
        let opts = DlbOptions { cache_bytes: 8 << 20, s_m: 50, async_remainder: false };
        let plan = dlb::plan(&dist, p_m, &opts);
        let reps = if fast { 1 } else { 3 };
        let tt = median_time(reps, || {
            let _ = dlb_mpk::mpk::trad_mpk(&dist, &x, p_m, &mut NativeBackend);
        });
        let td = median_time(reps, || {
            let _ = dlb::execute(&plan, &x, &mut NativeBackend);
        });
        println!(
            "{:>7} {:>14} {:>10} {:>8} {:>10.4} {:>10.4} {:>8.2}",
            d,
            format!("({},{},{})", cfg.lx, cfg.ly, cfg.lz),
            h.n_rows(),
            h.crs_bytes() >> 20,
            tt.median_s,
            td.median_s,
            tt.median_s / td.median_s
        );
    }
    println!("(sequential-rank simulation: speedup is the cache-blocking factor)");
    Ok(())
}
