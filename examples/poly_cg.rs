//! Polynomial-preconditioned CG on DLB-MPK — the solver pattern the paper's
//! introduction motivates (CA-Krylov, Loe et al. polynomial preconditioning):
//! every preconditioner application is one sweep of a prepared `MpkEngine`,
//! and the CG loop's own `A·p` product runs through the same engine backend.
//!
//! Run: `cargo run --release --example poly_cg`

use dlb_mpk::apps::poly_cg::{pcg, ChebyshevPreconditioner};
use dlb_mpk::distsim::DistMatrix;
use dlb_mpk::engine::{EngineConfig, Variant};
use dlb_mpk::matrix::gen;
use dlb_mpk::mpk::dlb::DlbOptions;
use dlb_mpk::partition::{partition, Method};
use dlb_mpk::perf::median_time;

fn main() -> anyhow::Result<()> {
    let a = gen::stencil_2d_5pt(192, 192); // SPD Laplacian, 36 864 unknowns
    println!("solve A x = b: {} rows, {} nnz ({} MiB)", a.n_rows(), a.nnz(), a.crs_bytes() >> 20);
    let part = partition(&a, 4, Method::RecursiveBisect);
    let dist = DistMatrix::build(&a, &part);
    let b = vec![1.0; a.n_rows()];
    // Exact spectral bounds of the 2D 5-pt Laplacian: the Chebyshev
    // preconditioner must bracket the spectrum or it loses definiteness
    // (CG then stalls — try lmin = lmax/200 to see it).
    let n = 192f64;
    let lmin = 4.0 * ((std::f64::consts::PI / (2.0 * (n + 1.0))).sin().powi(2)) * 2.0;
    let lmax = a.inf_norm();
    let engine_cfg = EngineConfig {
        variant: Variant::Dlb(DlbOptions { cache_bytes: 4 << 20, s_m: 50, async_remainder: false }),
        ..EngineConfig::default()
    };

    println!("\n{:>7} {:>7} {:>10} {:>12}", "degree", "iters", "resid", "time_s");
    for degree in [1usize, 2, 4, 8, 12] {
        let mut pre = ChebyshevPreconditioner::new(&dist, lmin, lmax, degree, &engine_cfg)?;
        let mut result = (vec![], 0usize, 0.0f64);
        let t = median_time(1, || {
            result = pcg(&a, &b, &mut pre, 1e-10, 2000);
        });
        println!("{:>7} {:>7} {:>10.2e} {:>12.3}", degree, result.1, result.2, t.median_s);
    }
    println!("\n(higher-degree Chebyshev preconditioners trade SpMVs-per-apply for");
    println!(" fewer CG iterations; DLB-MPK makes the extra SpMVs nearly free by");
    println!(" keeping the matrix cache-resident across the polynomial sweep)");
    Ok(())
}
