//! Paper Figures 1–4 as terminal art: the graph/matrix correspondence, BFS
//! levels, the Lp-diagram wavefront order, and the execution order of all
//! three distributed MPK variants on the 1D tri-diagonal example.
//!
//! Run: `cargo run --release --example lp_diagram`

use dlb_mpk::distsim::DistMatrix;
use dlb_mpk::graph::levels::bfs_reorder;
use dlb_mpk::graph::Levels;
use dlb_mpk::matrix::gen;
use dlb_mpk::mpk::ca::ca_plan;
use dlb_mpk::mpk::dlb::{self, DlbOptions};
use dlb_mpk::partition::{partition, Method};
use dlb_mpk::race::{group_levels, wavefront};

fn main() {
    fig1_bfs_reordering();
    fig2_lp_diagram();
    fig4_variant_comparison();
}

/// Fig. 1: 5-pt stencil sparsity before/after BFS reordering, with levels.
fn fig1_bfs_reordering() {
    println!("== Figure 1: BFS levels and reordering (modified 5-pt stencil, 4×4) ==\n");
    let a = gen::stencil_2d_5pt(4, 4);
    let lv = Levels::compute(&a, 0);
    println!("levels (vertex: level):");
    for l in 0..lv.n_levels() {
        let verts: Vec<usize> = lv.rows(l).map(|r| lv.perm[r]).collect();
        println!("  L({l}) = {verts:?}");
    }
    let (b, _) = bfs_reorder(&a, 0);
    println!("\nsparsity, original (a) vs BFS-reordered (b):");
    print_two_patterns(&a, &b);
}

fn print_two_patterns(a: &dlb_mpk::matrix::CsrMatrix, b: &dlb_mpk::matrix::CsrMatrix) {
    let n = a.n_rows();
    for r in 0..n {
        let mut left = String::new();
        let mut right = String::new();
        for c in 0..n {
            left.push(if a.row_cols(r).binary_search(&(c as u32)).is_ok() { '■' } else { '·' });
            right.push(if b.row_cols(r).binary_search(&(c as u32)).is_ok() { '■' } else { '·' });
        }
        println!("  {left}    {right}");
    }
}

/// Fig. 2: the Lp diagram for 10 levels, p_m = 5, in diagonal order.
fn fig2_lp_diagram() {
    println!("\n== Figure 2: Lp diagram execution order (10 levels, p_m = 5) ==\n");
    let a = gen::tridiag(10); // exactly 10 single-vertex levels
    let (b, lv) = bfs_reorder(&a, 0);
    let g = group_levels(&b, &lv, 5, 1, 50); // one level per group
    let steps = wavefront(&g, lv.n_levels(), 5);
    // grid[power-1][level] = execution step number
    let mut grid = vec![vec![0usize; 10]; 5];
    for (i, s) in steps.iter().enumerate() {
        grid[s.power - 1][s.group] = i + 1;
    }
    println!("  p\\L |{}", (0..10).map(|l| format!("{l:>4}")).collect::<String>());
    println!("  ----+{}", "-".repeat(40));
    for p in (1..=5).rev() {
        let row: String = (0..10).map(|l| format!("{:>4}", grid[p - 1][l])).collect();
        println!("  p={p} |{row}");
    }
    println!("\n  (diagonals i+p = const execute bottom-right → top-left; a level's");
    println!("   matrix data is re-touched after p_m + 1 = 6 steps — cache reuse)");
}

/// Fig. 4: execution orders of TRAD / CA / DLB on a 1D tri-diagonal matrix
/// over 2 ranks, p_m = 3.
fn fig4_variant_comparison() {
    println!("\n== Figure 4: TRAD vs CA-MPK vs DLB-MPK (1D tridiag n=16, 2 ranks, p_m=3) ==\n");
    let a = gen::tridiag(16);
    let part = partition(&a, 2, Method::Block);
    let d = DistMatrix::build(&a, &part);
    let p_m = 3;

    println!("(a) TRAD: {} halo exchanges, full sweep per power", p_m);
    println!("    per power p: exchange; every rank computes its {} rows", 8);

    let cp = ca_plan(&a, &d, p_m);
    println!("\n(b) CA-MPK: 1 extended exchange, redundant external work:");
    for (r, classes) in cp.ext.iter().enumerate() {
        let desc: Vec<String> = classes
            .iter()
            .enumerate()
            .map(|(k, c)| format!("E_{k}={:?}", c))
            .collect();
        println!("    rank {r}: {}", desc.join("  "));
    }
    println!(
        "    extra halo {} | redundant row-SpMVs {}",
        cp.overheads.extra_halo, cp.overheads.redundant_rows
    );

    let plan = dlb::plan(&d, p_m, &DlbOptions { cache_bytes: 1, s_m: 50, async_remainder: false });
    println!("\n(c) DLB-MPK: TRAD's halos, no redundancy; per-rank phase-2 schedule:");
    for (i, rp) in plan.ranks.iter().enumerate() {
        let steps: Vec<String> = rp
            .schedule
            .iter()
            .map(|s| {
                let (lo, hi) = rp.ranges[s.group];
                format!("rows[{lo}..{hi})→p{}", s.power)
            })
            .collect();
        println!("    rank {i}: {}", steps.join(", "));
        let classes: Vec<String> = rp
            .class_ranges
            .iter()
            .enumerate()
            .map(|(k, &(lo, hi))| format!("I_{}=[{lo}..{hi})", k + 1))
            .collect();
        println!("            classes {} | bulk |M| = {}", classes.join(" "), rp.bulk_rows);
    }
    println!("\n    phase 3: for p = 1..{}: exchange y_p; advance each unfinished I_k one power", p_m - 1);
}
