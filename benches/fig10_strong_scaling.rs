//! Figure 10 reproduction: strong scaling of DLB-MPK — performance, parallel
//! efficiency, and the two overheads (O_MPI, O_DLB) as functions of the
//! rank count, for a Lynx-like (good structure) and an nlpkkt-like (bad
//! structure) matrix at p ∈ {4, 6}.
//!
//! Multi-rank timing = max-rank measured compute + α-β comm model
//! (DESIGN.md §Substitutions). Expected shape: O_MPI constant in p, O_DLB
//! grows with p and ranks; nlpkkt's worse structure costs more.
//!
//! The measured-parallel section times the threads executor both ways —
//! spawn-per-sweep (`exec::trad_threaded`/`dlb_threaded`) vs the engine's
//! persistent rank pool — and writes the results to `BENCH_fig10.json`
//! (variant, ranks, inner threads, mode, median seconds) so the perf
//! trajectory is machine-readable across PRs.
//!
//! The hierarchical section holds the total worker count at 4 and slides
//! the split between ranks and within-rank inner threads
//! (`ranks × inner ∈ {4×1, 2×2, 1×4}`): fewer ranks shrink halo traffic
//! but push more of the parallelism into the wavefront task batches.
//!
//! The async-remainder section runs DLB under threads(4) with the phase-3
//! pipeline off vs on (`DlbOptions::async_remainder`), asserts the powers
//! are bitwise identical, and compares the trace-derived phase-3 wait
//! totals — async drops the intermediate round barriers, so its wait must
//! be strictly lower. Written to the `"async_remainder"` key of
//! `BENCH_fig10.json`.
//!
//! Run: `cargo bench --bench fig10_strong_scaling`

use dlb_mpk::distsim::costmodel::halo_traffic;
use dlb_mpk::distsim::{CommCostModel, DistMatrix};
use dlb_mpk::engine::{MpkEngine, Variant};
use dlb_mpk::exec::{self, ExecutorKind};
use dlb_mpk::matrix::gen;
use dlb_mpk::mpk::dlb::{self, DlbOptions, Recurrence};
use dlb_mpk::mpk::{overheads, NativeBackend};
use dlb_mpk::partition::{partition, Method};
use dlb_mpk::perf::{median_time_warm, roofline};

/// One machine-readable measurement row of the measured-parallel section.
struct Rec {
    matrix: String,
    variant: &'static str,
    ranks: usize,
    /// Within-rank inner threads (1 = serial rank kernels).
    inner: usize,
    /// `spawn` = one OS thread per rank spawned per sweep;
    /// `pool` = the engine's persistent rank pool (spawned once);
    /// `hier` = pool plus a per-rank inner worker pool (ranks × inner).
    mode: &'static str,
    median_s: f64,
}

fn main() {
    let fast = std::env::var("DLB_BENCH_FAST").is_ok();
    let reps = if fast { 1 } else { 3 };
    let warmup = if fast { 0 } else { 1 };
    let matrices: Vec<(&str, dlb_mpk::matrix::CsrMatrix)> = if fast {
        vec![
            ("Lynx-s", gen::stencil_3d_7pt(96, 32, 32)),
            ("nlpkkt-s", gen::stencil_3d_27pt(24, 24, 24)),
        ]
    } else {
        vec![
            ("Lynx-s", gen::stencil_3d_7pt(640, 40, 40)),
            ("nlpkkt-s", gen::stencil_3d_27pt(56, 56, 56)),
        ]
    };
    let ranks: Vec<usize> = if fast { vec![1, 2, 4] } else { vec![1, 2, 4, 8, 16, 32] };
    let model = CommCostModel::default();

    for (name, a) in &matrices {
        println!(
            "\n# Figure 10: strong scaling, {name} ({} rows, {} MiB CRS)",
            a.n_rows(),
            a.crs_bytes() >> 20
        );
        for &p_m in &[4usize, 6] {
            println!("\n## p_m = {p_m}");
            println!(
                "{:>5} {:>9} {:>9} {:>10} {:>10} {:>8}",
                "ranks", "O_MPI", "O_DLB", "Gflop/s", "T_model_s", "eff"
            );
            let mut t1 = 0.0;
            for &np in &ranks {
                let part = partition(a, np, Method::RecursiveBisect);
                let dist = DistMatrix::build(a, &part);
                let opts = DlbOptions { cache_bytes: 8 << 20, s_m: 50, async_remainder: false };
                let plan = dlb::plan(&dist, p_m, &opts);
                let o_dlb = overheads::dlb_overhead_from_plan(&plan);
                let x = vec![1.0; a.n_rows()];
                let mut flops = 0usize;
                let t_seq = median_time_warm(warmup, reps, || {
                    let r = dlb::execute(&plan, &x, &mut NativeBackend);
                    flops = r.flop_nnz;
                });
                // critical-path compute: busiest rank's nnz share of the
                // sequential wall time
                let max_nnz = plan.dist.ranks.iter().map(|r| r.a.nnz()).max().unwrap() as f64;
                let t_comp = t_seq.median_s * max_nnz / a.nnz() as f64;
                let t_comm = p_m as f64 * model.round_time(&halo_traffic(&plan.dist.ranks));
                let t_model = t_comp + t_comm;
                if np == 1 {
                    t1 = t_model;
                }
                println!(
                    "{np:>5} {:>9.4} {:>9.4} {:>10.2} {:>10.4} {:>8.2}",
                    dist.mpi_overhead(),
                    o_dlb,
                    roofline::gflops(flops, t_model),
                    t_model,
                    t1 / (np as f64 * t_model)
                );
            }
        }
    }
    let mut recs = Vec::new();
    measured_parallel(
        &matrices,
        if fast { vec![1, 2, 4] } else { vec![1, 2, 4, 8] },
        warmup,
        reps,
        &mut recs,
    );
    hierarchical(&matrices, warmup, reps, &mut recs);
    let async_recs = async_remainder(&matrices, warmup, reps);
    match write_json(&recs, &async_recs) {
        Ok(path) => println!("\nwrote {} measurement rows to {path}", recs.len()),
        Err(e) => eprintln!("\nfailed to write BENCH_fig10.json: {e}"),
    }

    println!("\n(paper Fig. 10: ε ≥ 1 intra-node from added cache; O_MPI identical");
    println!(" for p = 4 and 6; O_DLB larger at p = 6; nlpkkt structure worse)");
}

/// Measured-parallel mode: true wall-clock of the threads executor, TRAD vs
/// DLB over 1..N ranks, spawn-per-sweep vs the engine's persistent rank
/// pool — no cost model, just elapsed time.
fn measured_parallel(
    matrices: &[(&str, dlb_mpk::matrix::CsrMatrix)],
    ranks: Vec<usize>,
    warmup: usize,
    reps: usize,
    recs: &mut Vec<Rec>,
) {
    let p_m = 4;
    for (name, a) in matrices {
        println!("\n# Measured parallel wall-clock (threads executor), {name}, p_m = {p_m}");
        println!(
            "{:>7} {:>12} {:>12} {:>12} {:>12} {:>11}",
            "threads", "trad_spawn", "trad_pool", "dlb_spawn", "dlb_pool", "pool/spawn"
        );
        let x = vec![1.0; a.n_rows()];
        for &np in &ranks {
            let part = partition(a, np, Method::RecursiveBisect);
            let dist = DistMatrix::build(a, &part);
            let opts = DlbOptions { cache_bytes: 8 << 20, s_m: 50, async_remainder: false };
            let plan = dlb::plan(&dist, p_m, &opts);

            // spawn-per-sweep: every rep pays n_ranks thread spawns + joins
            let t_trad_spawn = median_time_warm(warmup, reps, || {
                exec::trad_threaded(&dist, &x, None, p_m, Recurrence::Power);
            });
            let t_dlb_spawn = median_time_warm(warmup, reps, || {
                exec::dlb_threaded(&plan, &x, None, Recurrence::Power);
            });

            // persistent pool: threads spawned once at engine build
            let mut trad_eng = MpkEngine::builder(&dist)
                .p_m(p_m)
                .variant(Variant::Trad)
                .executor(ExecutorKind::Threads { n: 0 })
                .build()
                .expect("engine builds");
            let t_trad_pool = median_time_warm(warmup, reps, || {
                trad_eng.sweep(&x, None, Recurrence::Power);
            });
            let mut dlb_eng = MpkEngine::builder(&dist)
                .p_m(p_m)
                .variant(Variant::Dlb(opts))
                .executor(ExecutorKind::Threads { n: 0 })
                .build()
                .expect("engine builds");
            let t_dlb_pool = median_time_warm(warmup, reps, || {
                dlb_eng.sweep(&x, None, Recurrence::Power);
            });

            println!(
                "{np:>7} {:>12.4} {:>12.4} {:>12.4} {:>12.4} {:>10.2}x",
                t_trad_spawn.median_s,
                t_trad_pool.median_s,
                t_dlb_spawn.median_s,
                t_dlb_pool.median_s,
                t_dlb_spawn.median_s / t_dlb_pool.median_s,
            );
            for (variant, mode, t) in [
                ("trad", "spawn", t_trad_spawn.median_s),
                ("trad", "pool", t_trad_pool.median_s),
                ("dlb", "spawn", t_dlb_spawn.median_s),
                ("dlb", "pool", t_dlb_pool.median_s),
            ] {
                recs.push(Rec {
                    matrix: name.to_string(),
                    variant,
                    ranks: np,
                    inner: 1,
                    mode,
                    median_s: t,
                });
            }
        }
    }
    println!("\n(pool/spawn = DLB spawn-per-sweep time over persistent-pool time at the");
    println!(" same rank count — the pool amortizes thread/comm setup across sweeps)");
}

/// Hierarchical mode: 4 workers total, split between ranks and within-rank
/// inner threads. All shapes compute bitwise-identical powers (asserted);
/// what changes is where the parallelism lives — halo exchange between
/// ranks vs dependency-free task batches inside each rank's wavefront.
fn hierarchical(
    matrices: &[(&str, dlb_mpk::matrix::CsrMatrix)],
    warmup: usize,
    reps: usize,
    recs: &mut Vec<Rec>,
) {
    let p_m = 4;
    let shapes = [(4usize, 1usize), (2, 2), (1, 4)];
    for (name, a) in matrices {
        println!("\n# Hierarchical ranks x inner threads (4 workers total), {name}, p_m = {p_m}");
        println!("{:>7} {:>7} {:>12} {:>9}", "ranks", "inner", "dlb_hier_s", "halo_B");
        let x = vec![1.0; a.n_rows()];
        let mut baseline: Option<Vec<Vec<f64>>> = None;
        for (np, inner) in shapes {
            let part = partition(a, np, Method::RecursiveBisect);
            let dist = DistMatrix::build(a, &part);
            let opts = DlbOptions { cache_bytes: 8 << 20, s_m: 50, async_remainder: false };
            let mut eng = MpkEngine::builder(&dist)
                .p_m(p_m)
                .variant(Variant::Dlb(opts))
                .executor(ExecutorKind::Threads { n: 0 })
                .inner_threads(inner)
                .build()
                .expect("engine builds");
            let mut out = None;
            let t = median_time_warm(warmup, reps, || {
                out = Some(eng.sweep(&x, None, Recurrence::Power));
            });
            let res = out.unwrap();
            match &baseline {
                None => baseline = Some(res.powers),
                Some(b) => assert_eq!(b, &res.powers, "{name} {np}x{inner} must match 4x1"),
            }
            println!("{np:>7} {inner:>7} {:>12.4} {:>9}", t.median_s, dist.total_halo() * 8);
            recs.push(Rec {
                matrix: name.to_string(),
                variant: "dlb",
                ranks: np,
                inner,
                mode: "hier",
                median_s: t.median_s,
            });
        }
    }
    println!("\n(every shape is bitwise-identical; 1x4 trades all halo traffic for");
    println!(" intra-rank task batches, 4x1 is the flat-MPI baseline)");
}

/// One sync-vs-async row of the async-remainder section.
struct AsyncRec {
    matrix: String,
    sync_s: f64,
    async_s: f64,
    sync_wait_ns: u64,
    async_wait_ns: u64,
}

/// Total traced `comm.wait` time spent in phase-3 round barriers. Each
/// sweep closes exactly `p_m` rounds (phase 1, then `p_m − 1` remainder
/// rounds), so across the accumulated trace the rounds with cumulative
/// index `% p_m != 0` are precisely the remainder ones.
fn phase3_wait_ns(m: &dlb_mpk::trace::Metrics, p_m: usize) -> u64 {
    m.per_rank
        .iter()
        .flat_map(|r| &r.wait_by_round)
        .filter(|(round, _)| *round as usize % p_m != 0)
        .map(|&(_, ns)| ns)
        .sum()
}

/// Sync vs async DLB phase-3 remainder under threads(4): wall-clock plus
/// the trace-derived phase-3 wait totals. The async pipeline replaces the
/// `p_m − 1` remainder barriers per sweep with one (the final round), so
/// its phase-3 wait must be strictly lower; the powers stay bitwise equal.
fn async_remainder(
    matrices: &[(&str, dlb_mpk::matrix::CsrMatrix)],
    warmup: usize,
    reps: usize,
) -> Vec<AsyncRec> {
    let p_m = 4;
    let np = 4;
    let mut out = Vec::new();
    for (name, a) in matrices {
        println!("\n# Async remainder pipelining, threads({np}), {name}, p_m = {p_m}");
        println!(
            "{:>7} {:>12} {:>14} {:>12}",
            "mode", "median_s", "p3_wait_ms", "wait ratio"
        );
        let x = vec![1.0; a.n_rows()];
        let part = partition(a, np, Method::RecursiveBisect);
        let dist = DistMatrix::build(a, &part);
        let mut run = |on: bool| {
            let opts = DlbOptions { cache_bytes: 8 << 20, s_m: 50, async_remainder: on };
            let mut eng = MpkEngine::builder(&dist)
                .p_m(p_m)
                .variant(Variant::Dlb(opts))
                .executor(ExecutorKind::Threads { n: 0 })
                .trace(true)
                .build()
                .expect("engine builds");
            let mut res = None;
            let t = median_time_warm(warmup, reps, || {
                res = Some(eng.sweep(&x, None, Recurrence::Power));
            });
            let m = eng.metrics().expect("tracing is on");
            // per-sweep average so warmup/rep counts don't skew the ratio
            let wait = phase3_wait_ns(&m, p_m) / eng.sweeps_run().max(1) as u64;
            (t.median_s, wait, res.unwrap().powers)
        };
        let (sync_s, sync_wait, sync_pow) = run(false);
        let (async_s, async_wait, async_pow) = run(true);
        assert_eq!(sync_pow, async_pow, "{name}: async remainder must be bitwise neutral");
        assert!(
            async_wait < sync_wait,
            "{name}: async phase-3 wait ({async_wait} ns) must undercut sync ({sync_wait} ns)"
        );
        let ratio = async_wait as f64 / sync_wait.max(1) as f64;
        println!("{:>7} {sync_s:>12.4} {:>14.3} {:>12}", "sync", sync_wait as f64 / 1e6, "-");
        println!(
            "{:>7} {async_s:>12.4} {:>14.3} {ratio:>11.2}x",
            "async",
            async_wait as f64 / 1e6
        );
        out.push(AsyncRec {
            matrix: name.to_string(),
            sync_s,
            async_s,
            sync_wait_ns: sync_wait,
            async_wait_ns: async_wait,
        });
    }
    println!("\n(phase-3 wait = traced comm.wait in remainder rounds, per sweep; async");
    println!(" keeps only the final-round barrier, overlapping the rest with compute)");
    out
}

/// Emit the measured rows as `BENCH_fig10.json` so the perf trajectory is
/// machine-comparable across PRs.
fn write_json(recs: &[Rec], async_recs: &[AsyncRec]) -> std::io::Result<&'static str> {
    let mut s = String::from("{\n  \"bench\": \"fig10\",\n  \"p_m\": 4,\n  \"results\": [\n");
    for (i, r) in recs.iter().enumerate() {
        let sep = if i + 1 < recs.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"matrix\": \"{}\", \"variant\": \"{}\", \"ranks\": {}, \"inner\": {}, \
             \"mode\": \"{}\", \"median_s\": {}}}{sep}\n",
            r.matrix, r.variant, r.ranks, r.inner, r.mode, r.median_s
        ));
    }
    s.push_str("  ],\n  \"async_remainder\": [\n");
    for (i, r) in async_recs.iter().enumerate() {
        let sep = if i + 1 < async_recs.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"matrix\": \"{}\", \"ranks\": 4, \"sync_s\": {}, \"async_s\": {}, \
             \"sync_p3_wait_ns\": {}, \"async_p3_wait_ns\": {}, \"wait_ratio\": {}}}{sep}\n",
            r.matrix,
            r.sync_s,
            r.async_s,
            r.sync_wait_ns,
            r.async_wait_ns,
            r.async_wait_ns as f64 / r.sync_wait_ns.max(1) as f64
        ));
    }
    s.push_str("  ]\n}\n");
    let path = "BENCH_fig10.json";
    std::fs::write(path, s)?;
    Ok(path)
}
