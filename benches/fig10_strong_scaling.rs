//! Figure 10 reproduction: strong scaling of DLB-MPK — performance, parallel
//! efficiency, and the two overheads (O_MPI, O_DLB) as functions of the
//! rank count, for a Lynx-like (good structure) and an nlpkkt-like (bad
//! structure) matrix at p ∈ {4, 6}.
//!
//! Multi-rank timing = max-rank measured compute + α-β comm model
//! (DESIGN.md §Substitutions). Expected shape: O_MPI constant in p, O_DLB
//! grows with p and ranks; nlpkkt's worse structure costs more.
//!
//! Run: `cargo bench --bench fig10_strong_scaling`

use dlb_mpk::distsim::costmodel::halo_traffic;
use dlb_mpk::distsim::{CommCostModel, DistMatrix};
use dlb_mpk::exec;
use dlb_mpk::matrix::gen;
use dlb_mpk::mpk::dlb::{self, DlbOptions, Recurrence};
use dlb_mpk::mpk::{overheads, trad_mpk, NativeBackend};
use dlb_mpk::partition::{partition, Method};
use dlb_mpk::perf::{median_time, roofline};

fn main() {
    let fast = std::env::var("DLB_BENCH_FAST").is_ok();
    let reps = if fast { 1 } else { 3 };
    let matrices: Vec<(&str, dlb_mpk::matrix::CsrMatrix)> = if fast {
        vec![
            ("Lynx-s", gen::stencil_3d_7pt(96, 32, 32)),
            ("nlpkkt-s", gen::stencil_3d_27pt(24, 24, 24)),
        ]
    } else {
        vec![
            ("Lynx-s", gen::stencil_3d_7pt(640, 40, 40)),
            ("nlpkkt-s", gen::stencil_3d_27pt(56, 56, 56)),
        ]
    };
    let ranks: Vec<usize> = if fast { vec![1, 2, 4] } else { vec![1, 2, 4, 8, 16, 32] };
    let model = CommCostModel::default();

    for (name, a) in &matrices {
        println!(
            "\n# Figure 10: strong scaling, {name} ({} rows, {} MiB CRS)",
            a.n_rows(),
            a.crs_bytes() >> 20
        );
        for &p_m in &[4usize, 6] {
            println!("\n## p_m = {p_m}");
            println!(
                "{:>5} {:>9} {:>9} {:>10} {:>10} {:>8}",
                "ranks", "O_MPI", "O_DLB", "Gflop/s", "T_model_s", "eff"
            );
            let mut t1 = 0.0;
            for &np in &ranks {
                let part = partition(a, np, Method::RecursiveBisect);
                let dist = DistMatrix::build(a, &part);
                let opts = DlbOptions { cache_bytes: 8 << 20, s_m: 50 };
                let plan = dlb::plan(&dist, p_m, &opts);
                let o_dlb = overheads::dlb_overhead_from_plan(&plan);
                let x = vec![1.0; a.n_rows()];
                let mut flops = 0usize;
                let t_seq = median_time(reps, || {
                    let r = dlb::execute(&plan, &x, &mut NativeBackend);
                    flops = r.flop_nnz;
                });
                // critical-path compute: busiest rank's nnz share of the
                // sequential wall time
                let max_nnz = plan.dist.ranks.iter().map(|r| r.a.nnz()).max().unwrap() as f64;
                let t_comp = t_seq.median_s * max_nnz / a.nnz() as f64;
                let t_comm = p_m as f64 * model.round_time(&halo_traffic(&plan.dist.ranks));
                let t_model = t_comp + t_comm;
                if np == 1 {
                    t1 = t_model;
                }
                println!(
                    "{np:>5} {:>9.4} {:>9.4} {:>10.2} {:>10.4} {:>8.2}",
                    dist.mpi_overhead(),
                    o_dlb,
                    roofline::gflops(flops, t_model),
                    t_model,
                    t1 / (np as f64 * t_model)
                );
            }
        }
    }
    measured_parallel(&matrices, if fast { vec![1, 2, 4] } else { vec![1, 2, 4, 8] }, reps);

    println!("\n(paper Fig. 10: ε ≥ 1 intra-node from added cache; O_MPI identical");
    println!(" for p = 4 and 6; O_DLB larger at p = 6; nlpkkt structure worse)");
}

/// Measured-parallel mode: true wall-clock of the threaded executor (one
/// OS thread per rank, real channel halo exchange), TRAD vs DLB over
/// 1..N threads — no cost model, just elapsed time.
fn measured_parallel(
    matrices: &[(&str, dlb_mpk::matrix::CsrMatrix)],
    ranks: Vec<usize>,
    reps: usize,
) {
    let p_m = 4;
    for (name, a) in matrices {
        println!("\n# Measured parallel wall-clock (threads executor), {name}, p_m = {p_m}");
        println!(
            "{:>7} {:>12} {:>12} {:>10} {:>10} {:>9}",
            "threads", "T_trad_s", "T_dlb_s", "S_trad", "S_dlb", "dlb/trad"
        );
        let x = vec![1.0; a.n_rows()];
        let (mut t_trad1, mut t_dlb1) = (0.0f64, 0.0f64);
        for &np in &ranks {
            let part = partition(a, np, Method::RecursiveBisect);
            let dist = DistMatrix::build(a, &part);
            let opts = DlbOptions { cache_bytes: 8 << 20, s_m: 50 };
            let plan = dlb::plan(&dist, p_m, &opts);
            let t_trad = if np == 1 {
                // single rank: the sequential kernel IS the measured run
                // (no channel/barrier overhead in the baseline)
                median_time(reps, || {
                    trad_mpk(&dist, &x, p_m, &mut NativeBackend);
                })
            } else {
                median_time(reps, || {
                    exec::trad_threaded(&dist, &x, None, p_m, Recurrence::Power);
                })
            };
            let t_dlb = if np == 1 {
                median_time(reps, || {
                    dlb::execute(&plan, &x, &mut NativeBackend);
                })
            } else {
                median_time(reps, || {
                    exec::dlb_threaded(&plan, &x, None, Recurrence::Power);
                })
            };
            if np == 1 {
                t_trad1 = t_trad.median_s;
                t_dlb1 = t_dlb.median_s;
            }
            println!(
                "{np:>7} {:>12.4} {:>12.4} {:>9.2}x {:>9.2}x {:>8.2}x",
                t_trad.median_s,
                t_dlb.median_s,
                t_trad1 / t_trad.median_s,
                t_dlb1 / t_dlb.median_s,
                t_trad.median_s / t_dlb.median_s,
            );
        }
    }
    println!("\n(S_* = wall-clock speed-up over 1 thread; dlb/trad = measured DLB");
    println!(" advantage at the same thread count — comm overlapped with the wavefront)");
}
