//! Figure 12 reproduction: weak scaling of Chebyshev time propagation with
//! TRAD vs DLB-MPK on the Anderson ladder (Table 5), ~constant matrix bytes
//! per domain.
//!
//! Reported per domain count: per-domain performance (Gflop/s) of both
//! engines, DLB speedup, and the two overheads. Expected shape (paper §7):
//! speedup sustained as domains grow (paper: 2–4×).
//!
//! Run: `cargo bench --bench fig12_weak_scaling`

use dlb_mpk::apps::chebyshev::{wave_packet, ChebyshevConfig, ChebyshevPropagator};
use dlb_mpk::distsim::DistMatrix;
use dlb_mpk::engine::{EngineConfig, Variant};
use dlb_mpk::matrix::anderson::{anderson, weak_scaling_configs};
use dlb_mpk::mpk::dlb::DlbOptions;
use dlb_mpk::mpk::overheads;
use dlb_mpk::partition::{partition, Method};
use dlb_mpk::perf::median_time;
use std::f64::consts::FRAC_PI_2;

fn main() {
    let fast = std::env::var("DLB_BENCH_FAST").is_ok();
    let base_l = if fast { 24 } else { 160 };
    let domains: Vec<usize> = if fast { vec![1, 2] } else { vec![1, 2, 4] };
    let reps = if fast { 1 } else { 3 };
    let p_m = 8;
    let cfgs = weak_scaling_configs(base_l, &domains, 1.0, 7);

    println!("# Figure 12: weak scaling, Chebyshev + Anderson (base L = {base_l}, p_m = {p_m})");
    println!(
        "{:>7} {:>10} {:>8} {:>11} {:>11} {:>8} {:>8} {:>8}",
        "domains", "rows", "MiB/dom", "T_trad_s", "T_dlb_s", "speedup", "O_MPI", "O_DLB"
    );
    let mut speedups = Vec::new();
    for (d, cfg) in domains.iter().zip(&cfgs) {
        let h = anderson(cfg);
        let part = partition(&h, *d, Method::RecursiveBisect);
        let dist = DistMatrix::build(&h, &part);
        let o_mpi = dist.mpi_overhead();
        let o_dlb = overheads::dlb_overhead(&dist, p_m, &DlbOptions { cache_bytes: 8 << 20, s_m: 50, async_remainder: false });
        let psi0 = wave_packet(cfg, base_l as f64 / 6.0, [FRAC_PI_2, 0.0, 0.0]);

        let mut times = [0.0f64; 2];
        let variants = [
            Variant::Trad,
            Variant::Dlb(DlbOptions { cache_bytes: 8 << 20, s_m: 50, async_remainder: false }),
        ];
        for (i, variant) in variants.into_iter().enumerate() {
            let ccfg = ChebyshevConfig {
                dt: 0.5,
                p_m,
                engine: EngineConfig { variant, ..EngineConfig::default() },
            };
            let mut prop = ChebyshevPropagator::new(&h, &dist, ccfg).expect("engine builds");
            let t = median_time(reps, || {
                let _ = prop.step(&psi0);
            });
            times[i] = t.median_s;
        }
        let speedup = times[0] / times[1];
        speedups.push(speedup);
        println!(
            "{:>7} {:>10} {:>8} {:>11.4} {:>11.4} {:>8.2} {:>8.4} {:>8.4}",
            d,
            h.n_rows(),
            (h.crs_bytes() >> 20) / d,
            times[0],
            times[1],
            speedup,
            o_mpi,
            o_dlb
        );
    }
    let geo = (speedups.iter().map(|s| s.ln()).sum::<f64>() / speedups.len() as f64).exp();
    println!("\ngeomean speedup {geo:.2}x (paper: 2.8× at 1–2 domains, 2–4× multi-node)");
}
