//! Figure 7 reproduction: load-only bandwidth vs. working-set size — the
//! likwid-bench `load` analogue that locates this host's cache plateaus.
//!
//! Output: one row per working-set size, plus estimated cache / memory
//! bandwidths and the residual-caching boundary used to interpret Fig. 9.
//!
//! Run: `cargo bench --bench fig7_bandwidth`  (DLB_BENCH_FAST=1 for CI)

use dlb_mpk::perf::bandwidth::load_bandwidth;

fn main() {
    let fast = std::env::var("DLB_BENCH_FAST").is_ok();
    let max = if fast { 256usize << 20 } else { 1usize << 30 };
    println!("# Figure 7: load-only bandwidth ladder (this host)");
    println!("{:>14} {:>10}", "bytes", "GB/s");
    let mut points = Vec::new();
    let mut b = 32usize << 10;
    while b <= max {
        let p = load_bandwidth(b, if b > 64 << 20 { 0.25 } else { 0.1 });
        println!("{:>14} {:>10.2}", p.bytes, p.gb_per_s);
        points.push(p);
        b *= 2;
    }
    // cache bandwidth: max over small sets; memory: min over large sets
    let cache_bw = points.iter().map(|p| p.gb_per_s).fold(f64::MIN, f64::max);
    let mem_bw = points
        .iter()
        .rev()
        .take(2)
        .map(|p| p.gb_per_s)
        .fold(f64::INFINITY, f64::min);
    // residual-cache boundary: largest size still well above memory speed
    let boundary = points
        .iter()
        .filter(|p| p.gb_per_s >= 1.5 * mem_bw)
        .map(|p| p.bytes)
        .max()
        .unwrap_or(max);
    println!("\ncache-plateau bandwidth ≈ {cache_bw:.1} GB/s");
    println!("memory bandwidth        ≈ {mem_bw:.1} GB/s");
    println!("residual-cache boundary ≈ {} MiB", boundary >> 20);
    println!("(paper Fig. 7: ICL 452/180, SPR 826/241, MIL 2642/179 GB/s L3/mem)");
}
