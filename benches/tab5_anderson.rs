//! Table 5 reproduction: the Anderson weak-scaling matrix ladder
//! (per-domain CRS size held constant by doubling one dimension per step,
//! innermost x last).
//!
//! Run: `cargo bench --bench tab5_anderson`

use dlb_mpk::matrix::anderson::{anderson, weak_scaling_configs};
use dlb_mpk::util::mib;

fn main() {
    let fast = std::env::var("DLB_BENCH_FAST").is_ok();
    let base_l = if fast { 16 } else { 40 };
    let domains: Vec<usize> = if fast { vec![1, 2, 4] } else { vec![1, 2, 4, 8, 16] };
    let cfgs = weak_scaling_configs(base_l, &domains, 1.0, 42);
    println!("# Table 5 (Anderson ladder, base L = {base_l}; paper base L = 160)");
    println!(
        "{:>8} {:>16} {:>12} {:>14} {:>7} {:>9} {:>12}",
        "domains", "(Lx,Ly,Lz)", "N_r", "N_nz", "N_nzr", "CRS MiB", "MiB/domain"
    );
    for (d, cfg) in domains.iter().zip(&cfgs) {
        let a = anderson(cfg);
        println!(
            "{:>8} {:>16} {:>12} {:>14} {:>7.1} {:>9} {:>12}",
            d,
            format!("({},{},{})", cfg.lx, cfg.ly, cfg.lz),
            a.n_rows(),
            a.nnz(),
            a.nnzr(),
            mib(a.crs_bytes()),
            mib(a.crs_bytes()) / d,
        );
    }
    println!("\n(paper: 342 MiB per ccNUMA domain held constant up to 64 domains)");
}
