//! Table 5 reproduction: the Anderson weak-scaling matrix ladder
//! (per-domain CRS size held constant by doubling one dimension per step,
//! innermost x last) — plus a measured MPK sweep per rung through a
//! prepared `MpkEngine` on the threads executor (one rank thread per
//! domain, persistent pool), so the ladder is exercised end-to-end rather
//! than only sized.
//!
//! Run: `cargo bench --bench tab5_anderson`

use dlb_mpk::distsim::DistMatrix;
use dlb_mpk::engine::{MpkEngine, Variant};
use dlb_mpk::exec::ExecutorKind;
use dlb_mpk::matrix::anderson::{anderson, weak_scaling_configs};
use dlb_mpk::mpk::dlb::{DlbOptions, Recurrence};
use dlb_mpk::partition::{partition, Method};
use dlb_mpk::perf::median_time;
use dlb_mpk::util::mib;

fn main() {
    let fast = std::env::var("DLB_BENCH_FAST").is_ok();
    let base_l = if fast { 16 } else { 40 };
    let domains: Vec<usize> = if fast { vec![1, 2, 4] } else { vec![1, 2, 4, 8, 16] };
    let reps = if fast { 1 } else { 3 };
    let p_m = 4;
    let cfgs = weak_scaling_configs(base_l, &domains, 1.0, 42);
    println!("# Table 5 (Anderson ladder, base L = {base_l}; paper base L = 160)");
    println!(
        "{:>8} {:>16} {:>12} {:>14} {:>7} {:>9} {:>12} {:>11}",
        "domains", "(Lx,Ly,Lz)", "N_r", "N_nz", "N_nzr", "CRS MiB", "MiB/domain", "T_dlb_s"
    );
    for (d, cfg) in domains.iter().zip(&cfgs) {
        let a = anderson(cfg);
        // one DLB sweep per rung on the threads executor (one rank thread
        // per domain, spawned once into the engine's pool)
        let part = partition(&a, *d, Method::RecursiveBisect);
        let dist = DistMatrix::build(&a, &part);
        let mut eng = MpkEngine::builder(&dist)
            .p_m(p_m)
            .variant(Variant::Dlb(DlbOptions { cache_bytes: 8 << 20, s_m: 50, async_remainder: false }))
            .executor(ExecutorKind::Threads { n: 0 })
            .build()
            .expect("engine builds");
        let x = vec![1.0; a.n_rows()];
        let t = median_time(reps, || {
            eng.sweep(&x, None, Recurrence::Power);
        });
        println!(
            "{:>8} {:>16} {:>12} {:>14} {:>7.1} {:>9} {:>12} {:>11.4}",
            d,
            format!("({},{},{})", cfg.lx, cfg.ly, cfg.lz),
            a.n_rows(),
            a.nnz(),
            a.nnzr(),
            mib(a.crs_bytes()),
            mib(a.crs_bytes()) / d,
            t.median_s,
        );
    }
    println!("\n(paper: 342 MiB per ccNUMA domain held constant up to 64 domains;");
    println!(" T_dlb = p_m = {p_m} powers per sweep, persistent rank pool)");
}
