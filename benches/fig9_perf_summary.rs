//! Figure 9 reproduction: node-level TRAD vs DLB-MPK performance across the
//! benchmark suite, ordered by matrix size, with the Eq.-4 roofline bound.
//!
//! Expected shape (paper §6.3): no DLB benefit for cache-resident matrices
//! (left of the boundary); for in-memory matrices DLB beats TRAD (paper:
//! avg 1.6–1.7×, max 2.4–2.7×) and can exceed the memory roofline thanks to
//! cache blocking.
//!
//! This host (benches/fig7_bandwidth.rs): L2 2 MiB @ ~53 GB/s, effective
//! LLC share ~32 MiB @ ~21 GB/s, memory ~7.8 GB/s, with residual caching
//! (nominal L3 260 MiB) up to ~260 MiB — so "in-memory" means ≳ 300 MiB
//! here, mirroring the paper's 2400 MiB residual-caching boundary on SPR.
//!
//! Every measured point also lands in `BENCH_fig9.json` (matrix, variant,
//! median/min/max seconds, Gflop/s) so the perf trajectory is
//! machine-comparable across PRs, like fig10's BENCH_fig10.json.
//!
//! Run: `cargo bench --bench fig9_perf_summary`   (~20 min full)
//!      DLB_BENCH_FAST=1 for a reduced sweep.

use dlb_mpk::distsim::DistMatrix;
use dlb_mpk::matrix::gen;
use dlb_mpk::mpk::dlb::{self, DlbOptions, Recurrence, Workspace};
use dlb_mpk::mpk::{trad_mpk, NativeBackend};
use dlb_mpk::partition::{partition, Method};
use dlb_mpk::perf::{median_time_warm, roofline, Timed};

/// One machine-readable measurement row (`variant` = `trad` or tuned `dlb`).
struct Rec {
    matrix: String,
    variant: &'static str,
    crs_mib: usize,
    time: Timed,
    gflops: f64,
}

/// Measured memory bandwidth of this host (benches/fig7_bandwidth.rs).
const MEM_BW_GBS: f64 = 7.8;
/// Residual-caching boundary (nominal L3).
const RESIDENT_MIB: usize = 260;

fn main() {
    let fast = std::env::var("DLB_BENCH_FAST").is_ok();
    let reps = if fast { 1 } else { 3 };
    let warmup = if fast { 0 } else { 1 };
    let entries = gen::suite();
    // full mode: every matrix targeted to ~340 MiB (in-memory), plus four
    // small cache-resident points to show the "no benefit" regime
    let target = 340usize << 20;
    let selection: Vec<(usize, f64)> = if fast {
        vec![(4, 0.05), (4, entries[4].scale_for_bytes(target))]
    } else {
        let mut v: Vec<(usize, f64)> = (0..entries.len())
            .map(|i| (i, entries[i].scale_for_bytes(target)))
            .collect();
        v.push((0, entries[0].scale_for_bytes(8 << 20)));
        v.push((4, entries[4].scale_for_bytes(16 << 20)));
        v.push((7, entries[7].scale_for_bytes(24 << 20)));
        v.push((10, entries[10].scale_for_bytes(96 << 20)));
        v.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        v
    };

    let p_candidates: Vec<usize> = if fast { vec![4] } else { vec![2, 4, 6, 8, 12] };
    let c_candidates_mib: Vec<usize> = if fast { vec![16] } else { vec![8, 16, 32] };

    println!("# Figure 9: TRAD vs DLB-MPK, tuned p and C (this host; mem bw {MEM_BW_GBS} GB/s)");
    println!(
        "{:<18} {:>8} {:>9} {:>9} {:>9} {:>9} {:>5} {:>6} {:>9}",
        "matrix", "CRS_MiB", "roofline", "TRAD", "DLB", "speedup", "p*", "C*MiB", "regime"
    );

    let mut inmem_speedups: Vec<f64> = Vec::new();
    let mut recs: Vec<Rec> = Vec::new();
    for &(idx, scale) in &selection {
        let e = &entries[idx];
        let a = (e.build)(scale);
        let part = partition(&a, 1, Method::Block);
        let dist = DistMatrix::build(&a, &part);
        let x = vec![1.0; a.n_rows()];

        // TRAD at p_m = 4 (per-SpMV rate is p-independent)
        let mut tflops = 0usize;
        let tt = median_time_warm(warmup, reps, || {
            let r = trad_mpk(&dist, &x, 4, &mut NativeBackend);
            tflops = r.flop_nnz;
        });
        let trad_gf = roofline::gflops(tflops, tt.median_s);

        // DLB tuned over p × C with shared preprocessing
        let pre = dlb::preprocess(&dist);
        let mut ws = Workspace::default();
        let mut best = (0.0f64, 0usize, 0usize);
        let mut best_t = tt;
        for &p in &p_candidates {
            for &c in &c_candidates_mib {
                let opts = DlbOptions { cache_bytes: c << 20, s_m: 50, async_remainder: false };
                let plan = dlb::plan_from_pre(&pre, p, &opts);
                let mut flops = 0usize;
                let t = median_time_warm(warmup, reps, || {
                    let r = dlb::execute_recurrence_with(
                        &plan, &x, None, Recurrence::Power, &mut NativeBackend, &mut ws,
                    );
                    flops = r.flop_nnz;
                });
                let gf = roofline::gflops(flops, t.median_s);
                if gf > best.0 {
                    best = (gf, p, c);
                    best_t = t;
                }
            }
        }
        let roof = roofline::spmv_roofline_gflops(MEM_BW_GBS, a.nnzr());
        let mib = a.crs_bytes() >> 20;
        let regime = if mib < 40 {
            "resident"
        } else if mib < RESIDENT_MIB {
            "residual"
        } else {
            "in-mem"
        };
        let speedup = best.0 / trad_gf;
        if regime == "in-mem" {
            inmem_speedups.push(speedup);
        }
        println!(
            "{:<18} {:>8} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>5} {:>6} {:>9}",
            e.name, mib, roof, trad_gf, best.0, speedup, best.1, best.2, regime
        );
        recs.push(Rec {
            matrix: e.name.to_string(),
            variant: "trad",
            crs_mib: mib,
            time: tt,
            gflops: trad_gf,
        });
        recs.push(Rec {
            matrix: e.name.to_string(),
            variant: "dlb",
            crs_mib: mib,
            time: best_t,
            gflops: best.0,
        });
    }

    match write_json(&recs) {
        Ok(path) => println!("\nwrote {} measurement rows to {path}", recs.len()),
        Err(e) => eprintln!("\nfailed to write BENCH_fig9.json: {e}"),
    }

    if !inmem_speedups.is_empty() {
        let geo = (inmem_speedups.iter().map(|s| s.ln()).sum::<f64>()
            / inmem_speedups.len() as f64)
            .exp();
        let max = inmem_speedups.iter().cloned().fold(f64::MIN, f64::max);
        println!(
            "\nin-memory speedup: geomean {geo:.2}x, max {max:.2}x over {} matrices",
            inmem_speedups.len()
        );
        println!("(paper: avg 1.6×/1.7×/1.6×, max 2.5×/2.4×/2.7× on ICL/SPR/MIL)");
    }
}

/// Emit the measured rows as `BENCH_fig9.json` (median/min/max seconds per
/// matrix × variant) for cross-PR comparison.
fn write_json(recs: &[Rec]) -> std::io::Result<&'static str> {
    let mut s = String::from("{\n  \"bench\": \"fig9\",\n  \"results\": [\n");
    for (i, r) in recs.iter().enumerate() {
        let sep = if i + 1 < recs.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"matrix\": \"{}\", \"variant\": \"{}\", \"crs_mib\": {}, \
             \"median_s\": {}, \"min_s\": {}, \"max_s\": {}, \"reps\": {}, \"gflops\": {}}}{sep}\n",
            r.matrix, r.variant, r.crs_mib, r.time.median_s, r.time.min_s, r.time.max_s,
            r.time.reps, r.gflops
        ));
    }
    s.push_str("  ]\n}\n");
    let path = "BENCH_fig9.json";
    std::fs::write(path, s)?;
    Ok(path)
}
