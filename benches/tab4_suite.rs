//! Table 4 reproduction: the benchmark-matrix suite — synthetic analogues of
//! the paper's SuiteSparse/Lynx selection (DESIGN.md §Substitutions), with
//! the paper's N_nzr for comparison.
//!
//! Run: `cargo bench --bench tab4_suite`  (DLB_BENCH_FAST=1 shrinks scale)

use dlb_mpk::matrix::gen::suite;
use dlb_mpk::util::mib;

fn main() {
    let fast = std::env::var("DLB_BENCH_FAST").is_ok();
    let scale = if fast { 0.05 } else { 1.0 };
    println!("# Table 4 (synthetic analogues, scale {scale})");
    println!(
        "{:<16} {:>10} {:>12} {:>7} {:>11} {:>9} {:>10}",
        "matrix", "N_r", "N_nz", "N_nzr", "paper_nzr", "CRS MiB", "bandwidth"
    );
    for e in suite() {
        let a = (e.build)(scale);
        println!(
            "{:<16} {:>10} {:>12} {:>7.1} {:>11.1} {:>9} {:>10}",
            e.name,
            a.n_rows(),
            a.nnz(),
            a.nnzr(),
            e.paper_nnzr,
            mib(a.crs_bytes()),
            a.bandwidth(),
        );
    }
    println!("\n(paper sizes 423 MiB – 22.6 GiB on cluster nodes; scaled to this");
    println!(" host so the suite straddles its ~32 MiB effective LLC share)");
}
