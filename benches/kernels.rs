//! Kernel microbenchmarks: native SpMV rate vs the Eq.-4 roofline, the halo
//! exchange, and DLB plan construction cost — the per-layer numbers behind
//! EXPERIMENTS.md §Perf.
//!
//! Run: `cargo bench --bench kernels`

use dlb_mpk::distsim::{exchange_halo, CommStats, DistMatrix};
use dlb_mpk::matrix::gen;
use dlb_mpk::mpk::dlb::{self, DlbOptions};
use dlb_mpk::partition::{partition, Method};
use dlb_mpk::perf::{median_time, roofline};

fn main() {
    let fast = std::env::var("DLB_BENCH_FAST").is_ok();
    let scale = if fast { 0.1 } else { 1.0 };
    let reps = if fast { 2 } else { 5 };

    // --- SpMV rate vs roofline, one cache-resident + one in-memory matrix
    println!("# kernel: native CRS SpMV vs roofline (mem bw 7.8 GB/s)");
    println!("{:<22} {:>8} {:>9} {:>9} {:>6}", "matrix", "MiB", "Gflop/s", "roofline", "frac");
    for (name, a) in [
        ("stencil3d 7pt (small)", gen::stencil_3d_7pt(48, 48, 48)),
        ("banded nnzr=46", gen::random_banded_sym((160_000 as f64 * scale) as usize * 8, 46, 2000, 5)),
    ] {
        let x = vec![1.0; a.n_rows()];
        let mut y = vec![0.0; a.n_rows()];
        let t = median_time(reps, || a.spmv(&x, &mut y));
        let gf = roofline::gflops(a.nnz(), t.median_s);
        let roof = roofline::spmv_roofline_gflops(7.8, a.nnzr());
        println!(
            "{:<22} {:>8} {:>9.2} {:>9.2} {:>6.2}",
            name,
            a.crs_bytes() >> 20,
            gf,
            roof,
            gf / roof
        );
    }

    // --- halo exchange throughput
    println!("\n# kernel: halo exchange (simulated MPI copy path)");
    let a = gen::stencil_3d_7pt(96, 48, 48);
    let part = partition(&a, 8, Method::RecursiveBisect);
    let dist = DistMatrix::build(&a, &part);
    let mut xs = dist.scatter(&vec![1.0; a.n_rows()]);
    let mut stats = CommStats::default();
    let t = median_time(reps * 10, || {
        exchange_halo(&dist.ranks, &mut xs, &mut stats);
    });
    let bytes_per_round = dist.total_halo() * 8;
    println!(
        "{} ranks, {} halo B/round: {:.1} µs/round ({:.2} GB/s)",
        dist.n_ranks(),
        bytes_per_round,
        t.median_s * 1e6,
        bytes_per_round as f64 / t.median_s / 1e9
    );

    // --- DLB plan construction (preprocessing cost, amortized in practice)
    println!("\n# kernel: DLB plan construction");
    let t = median_time(reps.min(3), || {
        let _ = dlb::plan(&dist, 6, &DlbOptions { cache_bytes: 8 << 20, s_m: 50, async_remainder: false });
    });
    println!(
        "plan({} rows, 8 ranks, p_m=6): {:.3}s ({:.1}x one TRAD p_m=6 run)",
        a.n_rows(),
        t.median_s,
        {
            let x = vec![1.0; a.n_rows()];
            let tt = median_time(reps.min(3), || {
                let _ = dlb_mpk::mpk::trad_mpk(&dist, &x, 6, &mut dlb_mpk::mpk::NativeBackend);
            });
            t.median_s / tt.median_s
        }
    );
}
