//! Ablation studies for the design choices DESIGN.md calls out:
//!
//! 1. partitioner choice (block / greedy / bisect) → O_MPI, O_DLB, edge cut
//! 2. BFS level reordering on/off → matrix bandwidth, DLB feasibility
//! 3. s_m recursion cap → group count and window size under tight C
//!
//! Run: `cargo bench --bench ablation`

use dlb_mpk::distsim::DistMatrix;
use dlb_mpk::graph::levels::bfs_reorder;
use dlb_mpk::matrix::{gen, rcm};
use dlb_mpk::mpk::dlb::{self, DlbOptions};
use dlb_mpk::mpk::overheads;
use dlb_mpk::partition::{partition, Method, PartitionStats};
use dlb_mpk::race::group_levels;
use dlb_mpk::util::rng::Rng;

fn main() {
    let fast = std::env::var("DLB_BENCH_FAST").is_ok();
    let scale = if fast { 0.05 } else { 0.4 };

    // --- 1. partitioner ablation
    let e = gen::suite().into_iter().find(|e| e.name == "Serena-s").unwrap();
    let a = (e.build)(scale);
    println!("# Ablation 1: partitioner choice (Serena-s, {} rows, 8 ranks, p_m = 4)", a.n_rows());
    println!("{:<8} {:>10} {:>9} {:>9} {:>9} {:>9}", "method", "edgecut", "rows_imb", "nnz_imb", "O_MPI", "O_DLB");
    for m in [Method::Block, Method::GreedyGrow, Method::RecursiveBisect] {
        let p = partition(&a, 8, m);
        let st = PartitionStats::compute(&a, &p);
        let d = DistMatrix::build(&a, &p);
        let o_dlb = overheads::dlb_overhead(&d, 4, &DlbOptions { cache_bytes: 8 << 20, s_m: 50, async_remainder: false });
        println!(
            "{:<8} {:>10} {:>9.3} {:>9.3} {:>9.4} {:>9.4}",
            format!("{m:?}").chars().take(8).collect::<String>(),
            st.edgecut, st.row_imbalance, st.nnz_imbalance, d.mpi_overhead(), o_dlb
        );
    }

    // --- 2. reordering ablation: shuffled matrix vs BFS vs RCM+BFS
    println!("\n# Ablation 2: reordering (shuffled stencil 128x128)");
    let base = gen::stencil_2d_5pt(128, 128);
    let mut perm: Vec<usize> = (0..base.n_rows()).collect();
    Rng::new(9).shuffle(&mut perm);
    let shuffled = base.permute_symmetric(&perm);
    let (bfs_b, lv) = bfs_reorder(&shuffled, 0);
    let (rcm_b, _) = rcm::rcm_reorder(&shuffled);
    let (rcm_bfs, lv2) = bfs_reorder(&rcm_b, 0);
    println!("{:<14} {:>10} {:>8}", "ordering", "bandwidth", "levels");
    println!("{:<14} {:>10} {:>8}", "shuffled", shuffled.bandwidth(), "-");
    println!("{:<14} {:>10} {:>8}", "BFS", bfs_b.bandwidth(), lv.n_levels());
    println!("{:<14} {:>10} {:>8}", "RCM", rcm_b.bandwidth(), "-");
    println!("{:<14} {:>10} {:>8}", "RCM+BFS", rcm_bfs.bandwidth(), lv2.n_levels());

    // --- 3. s_m recursion cap under a tight budget
    println!("\n# Ablation 3: s_m recursion cap (tight C = 256 KiB, p_m = 4)");
    let (b, lv) = bfs_reorder(&gen::stencil_2d_5pt(256, 256), 0);
    println!("{:<6} {:>8} {:>14}", "s_m", "groups", "max_window_B");
    for s_m in [1usize, 2, 8, 50, 200] {
        let g = group_levels(&b, &lv, 4, 256 << 10, s_m);
        println!("{:<6} {:>8} {:>14}", s_m, g.n_groups(), g.max_window_bytes(5));
    }

    // --- 4. DLB preprocessing amortization
    println!("\n# Ablation 4: preprocess vs per-(p,C) plan cost");
    let part = partition(&a, 4, Method::RecursiveBisect);
    let d = DistMatrix::build(&a, &part);
    let t0 = std::time::Instant::now();
    let pre = dlb::preprocess(&d);
    let t_pre = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let _p = dlb::plan_from_pre(&pre, 8, &DlbOptions { cache_bytes: 8 << 20, s_m: 50, async_remainder: false });
    let t_plan = t1.elapsed().as_secs_f64();
    println!("preprocess (BFS+permute): {t_pre:.3}s; plan_from_pre (group+schedule): {t_plan:.4}s");
}
