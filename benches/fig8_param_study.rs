//! Figure 8 reproduction: parameter study scanning the power p and the
//! cache budget C for DLB-MPK on an ML_Geer-like matrix.
//!
//! Expected shape (paper §6.2): a ridge of good performance at moderate
//! (p, C); degradation for C beyond the physical cache share; p = 1 flat in
//! C (no reuse to block for).
//!
//! Run: `cargo bench --bench fig8_param_study`

use dlb_mpk::distsim::DistMatrix;
use dlb_mpk::matrix::gen;
use dlb_mpk::mpk::dlb::{self, DlbOptions};
use dlb_mpk::mpk::NativeBackend;
use dlb_mpk::partition::{partition, Method};
use dlb_mpk::perf::{median_time, roofline};

fn main() {
    let fast = std::env::var("DLB_BENCH_FAST").is_ok();
    let entry = gen::suite().into_iter().find(|e| e.name == "ML_Geer-s").unwrap();
    // in-memory size on this host (see fig9 notes): ~340 MiB
    let scale = if fast { 0.1 } else { entry.scale_for_bytes(340 << 20) };
    let a = (entry.build)(scale);
    println!(
        "# Figure 8: p × C parameter study, ML_Geer-s ({} rows, {} MiB CRS)",
        a.n_rows(),
        a.crs_bytes() >> 20
    );
    // one rank per "ccNUMA domain"; this host has one domain
    let part = partition(&a, 1, Method::Block);
    let dist = DistMatrix::build(&a, &part);
    let x = vec![1.0; a.n_rows()];
    let reps = if fast { 1 } else { 3 };

    let p_values: Vec<usize> = if fast { vec![1, 2, 4] } else { vec![1, 2, 3, 4, 5, 6, 7, 8, 10] };
    let c_values_mib: Vec<usize> = if fast { vec![4, 16] } else { vec![2, 4, 8, 16, 32, 64] };
    let pre = dlb::preprocess(&dist);
    let mut ws = dlb_mpk::mpk::dlb::Workspace::default();

    print!("{:>4}", "p\\C");
    for c in &c_values_mib {
        print!(" {:>9}", format!("{c}MiB"));
    }
    println!("   (Gflop/s per SpMV)");
    let mut best = (0.0f64, 0usize, 0usize);
    for &p in &p_values {
        print!("{:>4}", p);
        for &c in &c_values_mib {
            let opts = DlbOptions { cache_bytes: c << 20, s_m: 50, async_remainder: false };
            let plan = dlb::plan_from_pre(&pre, p, &opts);
            let mut flops = 0usize;
            let t = median_time(reps, || {
                let r = dlb::execute_recurrence_with(
                    &plan, &x, None, dlb_mpk::mpk::dlb::Recurrence::Power,
                    &mut NativeBackend, &mut ws,
                );
                flops = r.flop_nnz;
            });
            let gf = roofline::gflops(flops, t.median_s);
            if gf > best.0 {
                best = (gf, p, c);
            }
            print!(" {:>9.2}", gf);
        }
        println!();
    }
    println!(
        "\nbest: {:.2} Gflop/s at p = {}, C = {} MiB (paper ICL: optimum at p = 7, C = 50 MiB)",
        best.0, best.1, best.2
    );
    let roof = roofline::spmv_roofline_gflops(7.8, a.nnzr());
    println!("memory roofline (Eq. 4, b_s = 7.8 GB/s): {roof:.2} Gflop/s");
}
