//! Figure 5 reproduction: CA-MPK overheads vs power for a Serena-like
//! matrix partitioned over 10 and 15 ranks.
//!
//! Left subplot:  additional halo elements relative to N_r.
//! Right subplot: recomputed elements relative to N_nz.
//! Both must grow with p and with the rank count; DLB's corresponding
//! overheads are zero by construction (printed for contrast).
//!
//! Run: `cargo bench --bench fig5_ca_overheads`

use dlb_mpk::distsim::DistMatrix;
use dlb_mpk::matrix::gen;
use dlb_mpk::mpk::ca::ca_plan;
use dlb_mpk::partition::{partition, Method};

fn main() {
    let fast = std::env::var("DLB_BENCH_FAST").is_ok();
    let scale = if fast { 0.05 } else { 0.4 };
    let entry = gen::suite().into_iter().find(|e| e.name == "Serena-s").unwrap();
    let a = (entry.build)(scale);
    println!(
        "# Figure 5: CA-MPK overheads, Serena-s ({} rows, {} nnz), METIS-substitute partitioner",
        a.n_rows(),
        a.nnz()
    );
    let powers: Vec<usize> = (1..=12).collect();
    for np in [10usize, 15] {
        let part = partition(&a, np, Method::RecursiveBisect);
        let dist = DistMatrix::build(&a, &part);
        println!("\n## {np} ranks (TRAD/DLB halo = {} elements, O_MPI = {:.4})", dist.total_halo(), dist.mpi_overhead());
        println!("{:>4} {:>16} {:>14} {:>16} {:>14}", "p", "extra_halo", "Δhalo/N_r", "redundant_nnz", "redo/N_nz");
        for &p in &powers {
            let plan = ca_plan(&a, &dist, p);
            let ov = &plan.overheads;
            println!(
                "{:>4} {:>16} {:>14.4} {:>16} {:>14.4}",
                p,
                ov.extra_halo,
                ov.rel_extra_halo(a.n_rows()),
                ov.redundant_nnz,
                ov.rel_redundant(a.nnz())
            );
        }
    }
    println!("\n(DLB-MPK: extra halo = 0, redundant = 0 for every p — paper §5)");
}
