"""Kernel vs. oracle — the core correctness signal for Layer 1.

hypothesis sweeps shapes, dtypes, panel sizes, and padding patterns of the
Pallas ELL SpMV against the pure-jnp oracle; dedicated cases cover the fused
Chebyshev step and axpby kernels.
"""

import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)

from hypothesis import given, settings, strategies as st

from compile.kernels.axpby import axpby
from compile.kernels.chebyshev import cheb_step, _pick_tile
from compile.kernels.spmv_ell import spmv_ell
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand_ell(rng, rows, width, xlen, dtype):
    vals = rng.standard_normal((rows, width)).astype(dtype)
    cols = rng.integers(0, xlen, (rows, width)).astype(np.int32)
    x = rng.standard_normal(xlen).astype(dtype)
    return vals, cols, x


def _tol(dtype):
    return dict(rtol=1e-12, atol=1e-12) if dtype == np.float64 else dict(rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- spmv_ell
@given(
    rows_panels=st.integers(1, 6),
    panel=st.sampled_from([32, 64, 128, 256]),
    width=st.integers(1, 16),
    extra_x=st.integers(0, 100),
    dtype=st.sampled_from([np.float32, np.float64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_spmv_matches_ref(rows_panels, panel, width, extra_x, dtype, seed):
    rng = np.random.default_rng(seed)
    rows = rows_panels * panel
    xlen = rows + extra_x
    vals, cols, x = _rand_ell(rng, rows, width, xlen, dtype)
    got = np.asarray(spmv_ell(vals, cols, x, panel_rows=panel))
    want = np.asarray(ref.spmv_ell_ref(vals, cols, x))
    np.testing.assert_allclose(got, want, **_tol(dtype))


def test_spmv_zero_padding_is_inert():
    """Rows padded with (0.0, col=0) must contribute nothing."""
    rng = np.random.default_rng(7)
    rows, width, xlen = 256, 5, 256
    vals, cols, x = _rand_ell(rng, rows, width, xlen, np.float64)
    vals[:, -2:] = 0.0  # pad tail
    cols_padded = cols.copy()
    cols_padded[:, -2:] = 0
    got_a = np.asarray(spmv_ell(vals, cols, x))
    got_b = np.asarray(spmv_ell(vals, cols_padded, x))
    np.testing.assert_allclose(got_a, got_b, rtol=0, atol=0)


def test_spmv_identity_matrix():
    n = 512
    vals = np.ones((n, 1))
    cols = np.arange(n, dtype=np.int32)[:, None]
    x = np.random.default_rng(3).standard_normal(n)
    np.testing.assert_allclose(np.asarray(spmv_ell(vals, cols, x)), x, rtol=0, atol=0)


def test_spmv_rejects_unaligned_rows():
    with pytest.raises(ValueError, match="not divisible"):
        spmv_ell(np.ones((100, 3)), np.zeros((100, 3), np.int32), np.ones(100), panel_rows=256)


def test_spmv_stencil_5pt_row_sums():
    """5pt stencil with all-ones x: interior rows sum their 5 coefficients."""
    k = 16
    n = k * k
    vals = np.zeros((n, 5))
    cols = np.zeros((n, 5), np.int32)
    for r in range(n):
        i, j = divmod(r, k)
        nz = [(r, 4.0)]
        if i > 0: nz.append((r - k, -1.0))
        if i < k - 1: nz.append((r + k, -1.0))
        if j > 0: nz.append((r - 1, -1.0))
        if j < k - 1: nz.append((r + 1, -1.0))
        for w, (c, v) in enumerate(nz):
            cols[r, w], vals[r, w] = c, v
    x = np.ones(n)
    y = np.asarray(spmv_ell(vals, cols, x, panel_rows=256))
    want = np.asarray(ref.spmv_ell_ref(vals, cols, x))
    np.testing.assert_allclose(y, want, rtol=0, atol=0)
    # interior rows: 4 - 4*1 = 0
    interior = np.array([i * k + j for i in range(1, k - 1) for j in range(1, k - 1)])
    np.testing.assert_allclose(y[interior], 0.0, atol=1e-14)


# ------------------------------------------------------------------ axpby
@given(
    n_tiles=st.integers(1, 8),
    tile=st.sampled_from([64, 256, 1024]),
    a=st.floats(-5, 5, allow_nan=False),
    b=st.floats(-5, 5, allow_nan=False),
    seed=st.integers(0, 2**31 - 1),
)
def test_axpby_matches_ref(n_tiles, tile, a, b, seed):
    rng = np.random.default_rng(seed)
    n = n_tiles * tile
    x = rng.standard_normal(n)
    y = rng.standard_normal(n)
    got = np.asarray(axpby(a, b, x, y, tile=tile))
    np.testing.assert_allclose(got, np.asarray(ref.axpby_ref(a, b, x, y)), rtol=1e-13, atol=1e-13)


def test_axpby_rejects_unaligned():
    with pytest.raises(ValueError, match="not divisible"):
        axpby(1.0, 1.0, np.ones(100), np.ones(100), tile=64)


def test_pick_tile_divides():
    for n in [256, 1024, 4096, 32768, 512, 64]:
        t = _pick_tile(n)
        assert n % t == 0 and t <= 1024


# -------------------------------------------------------------- cheb_step
@given(
    panels=st.integers(1, 4),
    width=st.integers(1, 9),
    extra=st.sampled_from([0, 17, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_cheb_step_matches_ref(panels, width, extra, seed):
    rng = np.random.default_rng(seed)
    rows = panels * 256
    xlen = rows + extra
    vals, cols, _ = _rand_ell(rng, rows, width, xlen, np.float64)
    vr, vi, pr, pi = (rng.standard_normal(xlen) for _ in range(4))
    got = cheb_step(vals, cols, vr, vi, pr, pi, panel_rows=256)
    want = ref.cheb_step_ref(vals, cols, vr, vi, pr, pi)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-12, atol=1e-12)


def test_cheb_step_is_2hx_minus_prev():
    """Laplacian-free check: H = I => v_next = 2 v - v_prev exactly."""
    n = 256
    vals = np.ones((n, 1))
    cols = np.arange(n, dtype=np.int32)[:, None]
    rng = np.random.default_rng(5)
    vr, vi, pr, pi = (rng.standard_normal(n) for _ in range(4))
    gr, gi = cheb_step(vals, cols, vr, vi, pr, pi)
    np.testing.assert_allclose(np.asarray(gr), 2 * vr - pr, rtol=1e-14, atol=1e-14)
    np.testing.assert_allclose(np.asarray(gi), 2 * vi - pi, rtol=1e-14, atol=1e-14)


# ------------------------------------------------------ csr_to_ell contract
@given(
    n=st.integers(1, 40),
    density=st.floats(0.05, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_csr_to_ell_roundtrip(n, density, seed):
    rng = np.random.default_rng(seed)
    dense = rng.standard_normal((n, n)) * (rng.random((n, n)) < density)
    # ensure no all-zero width-0 edge case surprises: allow it, ref handles W>=1
    rowptr = [0]
    colidx, values = [], []
    for r in range(n):
        nz = np.nonzero(dense[r])[0]
        colidx.extend(nz.tolist())
        values.extend(dense[r, nz].tolist())
        rowptr.append(len(colidx))
    vals, cols = ref.csr_to_ell(np.array(rowptr), np.array(colidx, np.int32), np.array(values))
    x = rng.standard_normal(n)
    got = np.asarray(ref.spmv_ell_ref(vals, cols, x))
    np.testing.assert_allclose(got, dense @ x, rtol=1e-12, atol=1e-12)
