"""Layer-2 model graphs vs. oracle + AOT artifact sanity."""

import json
import os

import numpy as np
import jax

jax.config.update("jax_enable_x64", True)

import pytest

from compile import model
from compile.kernels import ref
from compile import aot


def _rand(rng, rows, width, xlen):
    vals = rng.standard_normal((rows, width))
    cols = rng.integers(0, xlen, (rows, width)).astype(np.int32)
    x = rng.standard_normal(xlen)
    return vals, cols, x


def test_model_spmv_tuple():
    rng = np.random.default_rng(0)
    vals, cols, x = _rand(rng, 512, 7, 600)
    (y,) = model.spmv(vals, cols, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref.spmv_ell_ref(vals, cols, x)),
                               rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("p_m", [1, 2, 4, 6])
def test_model_mpk_matches_repeated_spmv(p_m):
    rng = np.random.default_rng(p_m)
    vals, cols, x = _rand(rng, 256, 5, 256)
    vals *= 0.1  # keep powers bounded
    (ys,) = model.mpk(vals, cols, x, p_m=p_m)
    ys = np.asarray(ys)
    assert ys.shape == (p_m, 256)
    y = x
    for p in range(p_m):
        y = np.asarray(ref.spmv_ell_ref(vals, cols, y))
        np.testing.assert_allclose(ys[p], y, rtol=1e-11, atol=1e-11)


def test_model_mpk_rejects_nonsquare():
    with pytest.raises(ValueError, match="square"):
        model.mpk(np.ones((256, 3)), np.zeros((256, 3), np.int32), np.ones(300), p_m=2)


def test_model_vec_axpby():
    rng = np.random.default_rng(9)
    x, y = rng.standard_normal(2048), rng.standard_normal(2048)
    (z,) = model.vec_axpby(0.25, -1.5, x, y)
    np.testing.assert_allclose(np.asarray(z), 0.25 * x - 1.5 * y, rtol=1e-13, atol=1e-13)


def test_model_chebyshev_step():
    rng = np.random.default_rng(11)
    vals, cols, _ = _rand(rng, 256, 7, 300)
    vecs = [rng.standard_normal(300) for _ in range(4)]
    got = model.chebyshev_step(vals, cols, *vecs)
    want = ref.cheb_step_ref(vals, cols, *vecs)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-12, atol=1e-12)


# --------------------------------------------------------------- artifacts
ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_lowering_roundtrip():
    """The exporter path produces parseable, entry-bearing HLO text."""
    text = aot.to_hlo_text(aot.lower_spmv(256, 3, 256, 256))
    assert "ENTRY" in text and "HloModule" in text


@pytest.mark.skipif(not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_consistent_with_files():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        manifest = json.load(f)
    assert len(manifest) >= 5
    for name, meta in manifest.items():
        path = os.path.join(ART_DIR, meta["file"])
        assert os.path.exists(path), f"missing artifact {path}"
        head = open(path).read(2000)
        assert "HloModule" in head
        assert meta["kind"] in {"spmv", "mpk", "cheb_step", "axpby"}
