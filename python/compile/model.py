"""Layer-2: the MPK compute graphs exported to the rust coordinator.

Each public function here is a jit-able, fixed-shape computation built on the
Layer-1 Pallas kernels.  ``aot.py`` lowers these once to HLO text; the rust
runtime (rust/src/runtime) loads and executes them via PJRT.  Python never
runs on the request path.

Conventions shared with the rust side (runtime/artifacts.rs):

* matrices arrive as padded ELL chunks: ``vals f64[R, W]``, ``cols i32[R, W]``
* the RHS vector ``x f64[N]`` covers local rows + halo tail (N >= R)
* all functions return tuples (lowered with return_tuple=True)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.axpby import axpby
from .kernels.chebyshev import cheb_step
from .kernels.spmv_ell import spmv_ell

jax.config.update("jax_enable_x64", True)


@functools.partial(jax.jit, static_argnames=("panel_rows",))
def spmv(vals, cols, x, *, panel_rows: int = 256):
    """Single SpMV chunk: y = A @ x (the DLB-MPK level/chunk work unit)."""
    return (spmv_ell(vals, cols, x, panel_rows=panel_rows),)


@functools.partial(jax.jit, static_argnames=("p_m", "panel_rows"))
def mpk(vals, cols, x, *, p_m: int, panel_rows: int = 256):
    """Local traditional MPK: stack of y_p = A^p x for p = 1..p_m.

    Only valid when the chunk is a whole square local matrix (R == N, no
    halo): each power feeds the previous output back in.  Used by the
    quickstart example and as an XLA-side cross-check of the rust TRAD loop.
    """
    rows, _ = vals.shape
    if x.shape[0] != rows:
        raise ValueError("mpk requires a square chunk (R == N)")
    ys = []
    y = x
    for _ in range(p_m):
        y = spmv_ell(vals, cols, y, panel_rows=panel_rows)
        ys.append(y)
    return (jnp.stack(ys, axis=0),)


@functools.partial(jax.jit, static_argnames=("panel_rows",))
def chebyshev_step(vals, cols, v_re, v_im, vprev_re, vprev_im, *, panel_rows: int = 256):
    """One Chebyshev recurrence step (paper Eq. 6) on complex planes."""
    return cheb_step(vals, cols, v_re, v_im, vprev_re, vprev_im, panel_rows=panel_rows)


@jax.jit
def vec_axpby(a, b, x, y):
    """z = a*x + b*y — the Chebyshev accumulation primitive (Eq. 5)."""
    from .kernels.chebyshev import _pick_tile

    return (axpby(a, b, x, y, tile=_pick_tile(x.shape[0])),)
