"""AOT exporter: lower the Layer-2 graphs to HLO *text* artifacts.

Run once at build time (``make artifacts``); rust loads the text via
``HloModuleProto::from_text_file``.  HLO text — NOT ``.serialize()`` — is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids that
the crate's xla_extension 0.5.1 rejects; the text parser reassigns ids.

Every artifact is recorded in ``artifacts/manifest.json`` with its operand
shapes/dtypes so the rust runtime can validate inputs before execution.

Usage:
    python -m compile.aot --out-dir ../artifacts            # default set
    python -m compile.aot --out-dir ../artifacts \
        --spmv rows=512,width=9,xlen=640                    # extra variant
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

F64 = jnp.float64
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO module -> XlaComputation -> HLO text (id-safe round trip)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_spmv(rows: int, width: int, xlen: int, panel_rows: int):
    return jax.jit(
        lambda v, c, x: model.spmv(v, c, x, panel_rows=panel_rows)
    ).lower(_spec((rows, width), F64), _spec((rows, width), I32), _spec((xlen,), F64))


def lower_mpk(rows: int, width: int, p_m: int, panel_rows: int):
    return jax.jit(
        lambda v, c, x: model.mpk(v, c, x, p_m=p_m, panel_rows=panel_rows)
    ).lower(_spec((rows, width), F64), _spec((rows, width), I32), _spec((rows,), F64))


def lower_cheb_step(rows: int, width: int, xlen: int, panel_rows: int):
    vec = _spec((xlen,), F64)
    return jax.jit(
        lambda v, c, a, b, p, q: model.chebyshev_step(v, c, a, b, p, q, panel_rows=panel_rows)
    ).lower(_spec((rows, width), F64), _spec((rows, width), I32), vec, vec, vec, vec)


def lower_axpby(n: int):
    s = _spec((), F64)
    vec = _spec((n,), F64)
    return jax.jit(model.vec_axpby).lower(s, s, vec, vec)


def _panel(rows: int) -> int:
    """Largest power-of-two panel <= 256 dividing rows."""
    p = 256
    while p > 1 and rows % p != 0:
        p //= 2
    return p


def default_artifacts():
    """(name, builder) pairs for the stock artifact set.

    * demo_*      — 64x64 2D 5-point stencil (quickstart / integration tests)
    * and32_*     — 32^3 Anderson lattice, ELL width 7 (Fig. 11 E2E driver)
    """
    arts = []
    # Quickstart demo: whole-matrix SpMV + local MPK on a 4096-row chunk.
    arts.append(("demo_spmv_4096x5", lambda: lower_spmv(4096, 5, 4096, 256),
                 dict(kind="spmv", rows=4096, width=5, xlen=4096)))
    arts.append(("demo_mpk_p4_4096x5", lambda: lower_mpk(4096, 5, 4, 256),
                 dict(kind="mpk", rows=4096, width=5, xlen=4096, p_m=4)))
    # Anderson 32^3 lattice for the end-to-end Chebyshev driver.
    n = 32 * 32 * 32
    arts.append((f"and32_spmv_{n}x7", lambda: lower_spmv(n, 7, n, 256),
                 dict(kind="spmv", rows=n, width=7, xlen=n)))
    arts.append((f"and32_cheb_{n}x7", lambda: lower_cheb_step(n, 7, n, 256),
                 dict(kind="cheb_step", rows=n, width=7, xlen=n)))
    arts.append((f"axpby_{n}", lambda: lower_axpby(n),
                 dict(kind="axpby", xlen=n)))
    return arts


def parse_kv(spec: str) -> dict:
    return {k: int(v) for k, v in (item.split("=") for item in spec.split(","))}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--spmv", action="append", default=[],
                    help="extra spmv artifact: rows=R,width=W,xlen=N")
    ap.add_argument("--cheb", action="append", default=[],
                    help="extra cheb_step artifact: rows=R,width=W,xlen=N")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    arts = default_artifacts()
    for spec in args.spmv:
        kv = parse_kv(spec)
        r, w, n = kv["rows"], kv["width"], kv["xlen"]
        arts.append((f"spmv_{r}x{w}_x{n}",
                     lambda r=r, w=w, n=n: lower_spmv(r, w, n, _panel(r)),
                     dict(kind="spmv", rows=r, width=w, xlen=n)))
    for spec in args.cheb:
        kv = parse_kv(spec)
        r, w, n = kv["rows"], kv["width"], kv["xlen"]
        arts.append((f"cheb_{r}x{w}_x{n}",
                     lambda r=r, w=w, n=n: lower_cheb_step(r, w, n, _panel(r)),
                     dict(kind="cheb_step", rows=r, width=w, xlen=n)))

    manifest = {}
    for name, build, meta in arts:
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(build())
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = dict(meta, file=f"{name}.hlo.txt", chars=len(text))
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {args.out_dir}/manifest.json ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
