"""Layer-1/2 fused Chebyshev recurrence step (paper Eq. 6).

One step of the Chebyshev time-propagation recurrence for a *real* sparse
Hamiltonian H acting on a complex state carried as (re, im) planes:

    v_{k+1} = 2 * (H @ v_k) - v_{k-1}

Both component SpMVs reuse the Pallas ELL row-panel kernel; the 2*h - v_prev
combine is a fused axpby.  Lowered as ONE HLO module so XLA fuses the
gather/multiply/reduce with the update, and the rust hot loop makes a single
PJRT call per recurrence step.
"""

from __future__ import annotations

import functools

import jax

from .axpby import axpby
from .spmv_ell import spmv_ell


@functools.partial(jax.jit, static_argnames=("panel_rows",))
def cheb_step(vals, cols, v_re, v_im, vprev_re, vprev_im, *, panel_rows: int = 256):
    """Returns (vnext_re, vnext_im) = 2*H@v - vprev on both planes.

    ``v_*`` may carry a halo tail (len N >= R); the recurrence only updates
    the R local rows, so ``vprev_*`` is sliced to match the SpMV output.
    """
    h_re = spmv_ell(vals, cols, v_re, panel_rows=panel_rows)
    h_im = spmv_ell(vals, cols, v_im, panel_rows=panel_rows)
    rows = vals.shape[0]
    two = vals.dtype.type(2.0)
    neg1 = vals.dtype.type(-1.0)
    tile = _pick_tile(rows)
    vnext_re = axpby(two, neg1, h_re, vprev_re[:rows], tile=tile)
    vnext_im = axpby(two, neg1, h_im, vprev_im[:rows], tile=tile)
    return vnext_re, vnext_im


def _pick_tile(n: int) -> int:
    """Largest power-of-two tile <= 1024 dividing n (n is pre-padded)."""
    t = 1024
    while t > 1 and n % t != 0:
        t //= 2
    return t
