"""Pure-jnp oracles for every Pallas kernel (the build-time correctness bar).

These are deliberately boring: no Pallas, no tiling, just the textbook
definition of each operation.  pytest asserts the Pallas kernels (and the
lowered HLO artifacts, transitively) match these to tight tolerances.
"""

from __future__ import annotations

import jax.numpy as jnp


def spmv_ell_ref(vals, cols, x):
    """y[r] = sum_w vals[r, w] * x[cols[r, w]] — padded-ELL SpMV."""
    return jnp.sum(vals * x[cols], axis=1)


def axpby_ref(a, b, x, y):
    return a * x + b * y


def cheb_step_ref(vals, cols, v_re, v_im, vprev_re, vprev_im):
    """(2*H@v - vprev) on both complex planes (vprev sliced to local rows)."""
    rows = vals.shape[0]
    h_re = spmv_ell_ref(vals, cols, v_re)
    h_im = spmv_ell_ref(vals, cols, v_im)
    return 2.0 * h_re - vprev_re[:rows], 2.0 * h_im - vprev_im[:rows]


def csr_to_ell(rowptr, colidx, values, n_cols=None):
    """Reference CRS→padded-ELL conversion (mirrors rust matrix::ell).

    Returns (vals[R, W], cols[R, W]) with W = max row length, padded with
    (0.0, 0).  Used by tests to cross-check the rust converter's contract.
    """
    import numpy as np

    rowptr = np.asarray(rowptr)
    n_rows = len(rowptr) - 1
    lens = rowptr[1:] - rowptr[:-1]
    width = int(lens.max()) if n_rows else 0
    vals = np.zeros((n_rows, max(width, 1)), dtype=np.float64)
    cols = np.zeros((n_rows, max(width, 1)), dtype=np.int32)
    for r in range(n_rows):
        lo, hi = rowptr[r], rowptr[r + 1]
        vals[r, : hi - lo] = values[lo:hi]
        cols[r, : hi - lo] = colidx[lo:hi]
    return vals, cols
