"""Layer-1 Pallas kernel: fused z = a*x + b*y (the MPK/Chebyshev vector op).

Scalars ``a``/``b`` arrive as rank-0 operands so one AOT artifact serves every
coefficient (Bessel weights change every Chebyshev term; re-lowering per
coefficient would defeat AOT).  The grid streams tile-sized slabs; on real
hardware this is a pure VPU stream kernel, here ``interpret=True``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 1024


def _axpby_kernel(a_ref, b_ref, x_ref, y_ref, z_ref):
    z_ref[...] = a_ref[0] * x_ref[...] + b_ref[0] * y_ref[...]


@functools.partial(jax.jit, static_argnames=("tile",))
def axpby(a, b, x, y, *, tile: int = DEFAULT_TILE):
    """z = a*x + b*y elementwise; ``len(x)`` must be divisible by ``tile``."""
    (n,) = x.shape
    if n % tile != 0:
        raise ValueError(f"n={n} not divisible by tile={tile}")
    a = jnp.asarray(a, x.dtype).reshape((1,))
    b = jnp.asarray(b, x.dtype).reshape((1,))
    return pl.pallas_call(
        _axpby_kernel,
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(a, b, x, y)
