"""Layer-1 Pallas kernel: row-panel SpMV over a padded ELL matrix chunk.

The MPK hot spot is the sparse matrix-vector product ``y[r] = sum_j A[r,j] x[j]``.
For the AOT path the matrix chunk is stored in padded ELLPACK layout:

* ``vals  : f64[R, W]`` — non-zero values, rows padded with ``0.0``
* ``cols  : i32[R, W]`` — column indices, rows padded with ``0``
  (padding is harmless: ``0.0 * x[0] == 0.0``)
* ``x     : f64[N]``    — the (local + halo) right-hand-side vector

The Pallas grid walks row panels of ``TR`` rows.  On a real TPU the panel of
``vals``/``cols`` streams HBM→VMEM via the BlockSpec index map while ``x``
stays resident (memory space ANY); the gather + multiply + row-reduce runs on
the VPU.  ``interpret=True`` is mandatory on this CPU testbed — real TPU
lowering would emit a Mosaic custom-call that the CPU PJRT plugin cannot run.

Hardware adaptation note (DESIGN.md §Hardware-Adaptation): the paper's
AVX-512 CRS inner loop becomes a dense (TR, W) panel contraction, which is
the TPU-friendly way to express short-row SpMV (ELL width W plays the role
of the SIMD-friendly inner dimension).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default row-panel height. 256 rows x ELL width 7 in f64 is a ~14 KiB
# panel — comfortably VMEM-sized with double buffering on real hardware.
DEFAULT_PANEL_ROWS = 256


def _spmv_ell_kernel(x_ref, vals_ref, cols_ref, y_ref):
    """One row panel: gather x at cols, multiply by vals, reduce rows."""
    vals = vals_ref[...]  # (TR, W)
    cols = cols_ref[...]  # (TR, W) int32
    xg = x_ref[cols]  # gathered RHS, (TR, W)
    y_ref[...] = jnp.sum(vals * xg, axis=1)


@functools.partial(jax.jit, static_argnames=("panel_rows",))
def spmv_ell(vals, cols, x, *, panel_rows: int = DEFAULT_PANEL_ROWS):
    """y = A @ x with A in padded-ELL layout, as a Pallas row-panel kernel.

    ``vals.shape[0]`` must be divisible by ``panel_rows`` (the AOT exporter
    pads chunks; see aot.py).
    """
    rows, width = vals.shape
    if rows % panel_rows != 0:
        raise ValueError(f"rows={rows} not divisible by panel_rows={panel_rows}")
    grid = (rows // panel_rows,)
    return pl.pallas_call(
        _spmv_ell_kernel,
        grid=grid,
        in_specs=[
            # x: whole vector visible to every panel (gather source).
            pl.BlockSpec(x.shape, lambda i: (0,) * x.ndim),
            pl.BlockSpec((panel_rows, width), lambda i: (i, 0)),
            pl.BlockSpec((panel_rows, width), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((panel_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), vals.dtype),
        interpret=True,  # CPU-PJRT compatible lowering; see module docstring
    )(x, vals, cols)
